//! Run one LLaMEA evolution: generate an optimization algorithm for a
//! target application (with search-space information), then evaluate the
//! winner on a held-out test-GPU space.
//!
//! Run: `cargo run --release --example evolve_optimizer`

use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::llamea::{evolve, EvolutionConfig, GenomeOptimizer, MockLlm, SpaceInfo};
use llamea_kt::methodology::{run_many, FnFactory, SpaceSetup};
use llamea_kt::searchspace::Application;
use llamea_kt::tuning::Cache;
use llamea_kt::util::stats;

fn main() {
    let app = Application::Dedispersion;
    // Training set: the target application on the three training GPUs.
    let space = std::sync::Arc::new(app.build_space());
    let caches: Vec<Cache> = llamea_kt::kernels::gpu::TRAIN_GPUS
        .iter()
        .map(|g| {
            Cache::build_with_space(app, GpuSpec::by_name(g).unwrap(), std::sync::Arc::clone(&space))
        })
        .collect();
    let setups: Vec<SpaceSetup> = caches.iter().map(SpaceSetup::new).collect();
    let info = SpaceInfo::from_cache(&caches[0], &setups[0]);
    println!(
        "evolving an optimizer for {} (dims {}, {} valid configs, ~{:.0} evals/budget)",
        app.name(),
        info.dims,
        info.constrained_size,
        info.expected_evals
    );

    let mut config = EvolutionConfig::paper_defaults(app.name(), Some(info));
    config.llm_call_budget = 60; // trimmed from the paper's 100 for demo speed
    let mut llm = MockLlm::new(7);
    let t0 = std::time::Instant::now();
    let result = evolve(&config, &mut llm, &caches, 7);
    println!(
        "evolved '{}' in {:?}: train fitness {:.3}, {} LLM calls, {} broken candidates, {} tokens",
        result.best.genome.name,
        t0.elapsed(),
        result.best.fitness,
        result.llm_calls,
        result.failures,
        result.tokens.total()
    );
    println!("  {}", result.best.genome.summary());
    println!("  fitness per generation: {:?}", result.fitness_history);

    // Held-out evaluation: same application, unseen GPU (W7800).
    let test_cache = Cache::build_with_space(
        app,
        GpuSpec::by_name("W7800").unwrap(),
        std::sync::Arc::clone(&space),
    );
    let test_setup = SpaceSetup::new(&test_cache);
    let genome = result.best.genome.clone();
    let name = result.best.genome.name.clone();
    let factory = FnFactory {
        f: move || {
            Box::new(GenomeOptimizer::new(genome.clone()))
                as Box<dyn llamea_kt::optimizers::Optimizer>
        },
        name,
    };
    let curves = run_many(&test_cache, &test_setup, &factory, 30, 11);
    let score = stats::mean(&stats::mean_curve(&curves));
    println!(
        "held-out {}: P = {:+.3} over 30 runs (0 = random search, 1 = optimum)",
        test_cache.id(),
        score
    );
}
