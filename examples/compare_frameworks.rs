//! Compare the generated algorithms against the human-designed baselines
//! of both frameworks (Kernel Tuner's tuned GA + SA, pyATF's DE) on a
//! training-GPU slice of the benchmark — a fast preview of Fig. 8.
//!
//! Run: `cargo run --release --example compare_frameworks`

use llamea_kt::methodology::{evaluate_all, NamedFactory, OptimizerFactory};

fn main() {
    let t0 = std::time::Instant::now();
    let caches = llamea_kt::tuning::build_caches_for(&["A100", "A4000"]);
    println!("built {} evaluation caches in {:?}", caches.len(), t0.elapsed());

    let names = ["hybrid_vndx", "atgw", "ga", "sa", "de", "random"];
    let factories: Vec<NamedFactory> =
        names.iter().map(|n| NamedFactory(n.to_string())).collect();
    let refs: Vec<&dyn OptimizerFactory> = factories.iter().map(|f| f as _).collect();

    let results = evaluate_all(&caches, &refs, 20, 1234);
    println!("\n{:14} {:>8} {:>8}   (20 runs x {} spaces)", "algorithm", "P", "±std", caches.len());
    for (name, agg) in &results {
        println!("{:14} {:+8.3} {:8.3}", name, agg.score, agg.score_std);
    }
    let best_gen = results
        .iter()
        .filter(|(n, _)| n == "hybrid_vndx" || n == "atgw")
        .map(|(_, a)| a.score)
        .fold(f64::NEG_INFINITY, f64::max);
    let best_human = results
        .iter()
        .filter(|(n, _)| ["ga", "sa", "de"].contains(&n.as_str()))
        .map(|(_, a)| a.score)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nbest generated {:+.3} vs best human-designed {:+.3} (paper: generated wins)",
        best_gen, best_human
    );
    println!("total {:?}", t0.elapsed());
}
