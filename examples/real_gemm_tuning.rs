//! End-to-end driver over the REAL stack: AOT-compiled Pallas GEMM variants
//! (L1 kernels lowered through the L2 JAX graph into HLO text) are loaded,
//! compiled and *measured* through PJRT by the Rust coordinator (L3); the
//! measured runtimes form a real search space on which the paper's
//! methodology and optimizers run unchanged.
//!
//! Requires `make artifacts` first.
//! Run: `cargo run --release --example real_gemm_tuning`

use std::path::Path;

use llamea_kt::methodology::{run_many, NamedFactory, SpaceSetup};
use llamea_kt::runtime::{gemm_reference, measure_kernel, ArtifactSet, PjrtRuntime};
use llamea_kt::util::stats;

const M: usize = 256;
const FLOPS: f64 = 2.0 * 256.0 * 256.0 * 256.0;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.tsv").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let set = ArtifactSet::load(dir).expect("manifest");
    let runtime = PjrtRuntime::new().expect("PJRT CPU client");
    println!("PJRT platform: {}", runtime.platform());

    // --- Correctness gate: a variant must agree with the rust-side
    //     reference (alpha=1.5, beta=0.5 baked in model.py). ---
    let gemms = set.for_kernel("gemm");
    let (variant, inputs) = runtime.prepare(gemms[0], 7).expect("prepare");
    let out = variant.run_f32(&inputs).expect("execute");
    let a = inputs[0].to_vec::<f32>().unwrap();
    let b = inputs[1].to_vec::<f32>().unwrap();
    let c = inputs[2].to_vec::<f32>().unwrap();
    let want = gemm_reference(&a, &b, &c, M, M, M, 1.5, 0.5);
    let max_err = out
        .iter()
        .zip(&want)
        .map(|(x, y)| (x - y).abs() as f64)
        .fold(0.0f64, f64::max);
    println!("correctness: max |err| vs reference = {:.2e} (gate: < 1e-2)", max_err);
    assert!(max_err < 1e-2);

    // --- Exhaustively measure all variants (the "pre-explored cachefile"
    //     of the real space). ---
    let t0 = std::time::Instant::now();
    let measured = measure_kernel(&runtime, &set, "gemm", 2, 9, 42).expect("measure");
    println!(
        "measured {} GEMM variants in {:?}",
        measured.measurements.len(),
        t0.elapsed()
    );
    let mut by_time = measured.measurements.clone();
    by_time.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
    println!("\n  {:46} {:>10} {:>12}", "variant", "mean ms", "GFLOP/s");
    for (name, ms, _) in by_time.iter().take(5) {
        println!("  {:46} {:10.3} {:12.2}", name, ms, FLOPS / (ms * 1e-3) / 1e9);
    }
    println!("  ...");
    let (wname, wms, _) = by_time.last().unwrap();
    println!("  {:46} {:10.3} {:12.2}", wname, wms, FLOPS / (wms * 1e-3) / 1e9);
    let speedup = by_time.last().unwrap().1 / by_time[0].1;
    println!("\ntuning headroom on this host: {:.2}x (worst/best variant)", speedup);

    // --- Run the paper's methodology on the REAL measured cache. ---
    let cache = &measured.cache;
    let setup = SpaceSetup::new(cache);
    println!(
        "\nmethodology budget on the measured space: {:.1}s ({} variants)",
        setup.budget_s,
        cache.len()
    );
    for name in ["random", "hybrid_vndx", "atgw"] {
        let factory = NamedFactory(name.to_string());
        let curves = run_many(cache, &setup, &factory, 20, 99);
        let score = stats::mean(&stats::mean_curve(&curves));
        println!("  {:12} P = {:+.3} over 20 runs (real measurements)", name, score);
    }
    println!("\nE2E OK: Pallas kernel -> JAX -> HLO text -> PJRT -> tuned by L3.");
}
