//! Quickstart: build a benchmark search space, tune it with the paper's
//! best generated optimizer, and score the run with the methodology.
//!
//! Run: `cargo run --release --example quickstart`

use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::methodology::{Baseline, SpaceSetup};
use llamea_kt::searchspace::Application;
use llamea_kt::tuning::{Cache, TuningContext};

fn main() {
    // 1. Pick an application and a device; build the constrained space and
    //    its pre-explored evaluation cache (simulation mode).
    let app = Application::Gemm;
    let gpu = GpuSpec::by_name("A100").unwrap();
    let cache = Cache::build(app, gpu);
    println!(
        "space {}: {} valid of {} cartesian configurations ({} dims)",
        cache.id(),
        cache.len(),
        cache.space.cartesian_size(),
        cache.space.dims()
    );

    // 2. The methodology assigns the space a budget: the time random search
    //    needs to get 95% of the way from the median to the optimum.
    let setup = SpaceSetup::new(&cache);
    println!(
        "budget: {:.0} simulated seconds (~{:.0} evaluations)",
        setup.budget_s,
        setup.budget_s / cache.mean_eval_cost_s
    );

    // 3. Tune with HybridVNDX (the paper's Algorithm 1).
    let mut opt = llamea_kt::optimizers::by_name("hybrid_vndx").unwrap();
    let mut ctx = TuningContext::new(&cache, setup.budget_s, 42);
    opt.run(&mut ctx);
    let (best_i, best_ms) = ctx.best().unwrap();
    println!(
        "hybrid_vndx found {:.3} ms (global optimum {:.3} ms) in {} unique evaluations",
        best_ms,
        cache.optimum_ms,
        ctx.unique_evals()
    );
    println!(
        "best configuration: {}",
        cache.space.params.describe(cache.space.config(best_i))
    );

    // 4. Score the run against the calculated random-search baseline.
    let baseline = Baseline::from_cache(&cache);
    let best_at_end = ctx.trajectory.last().map(|&(_, v)| v).unwrap();
    let b_end = baseline.value_at(setup.budget_s);
    let p = (b_end - best_at_end) / (b_end - baseline.optimum());
    println!("end-of-budget performance score P = {:.3} (0 = random search, 1 = optimum)", p);
}
