"""Tunable tiled 2D convolution Pallas kernel (L1).

The paper's convolution search space (van Werkhoven et al. 2014) tiles the
output image over threadblocks, with each thread computing ``tile_x x tile_y``
output pixels and the input staged through shared memory. The Pallas
adaptation expresses the same schedule with the grid iterating over output
tiles and the (overlapping) input window loaded from the full array with
dynamic slices — the interpret-mode equivalent of the HBM->VMEM halo load.

Tunables: ``tile_h``, ``tile_w`` (output tile shape) and ``unroll`` (how many
filter rows are unrolled per accumulation step, the analogue of the paper's
loop-unroll factors).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def conv2d(image: jnp.ndarray, filt: jnp.ndarray,
           *, tile_h: int, tile_w: int, unroll: int = 1) -> jnp.ndarray:
    """Direct 2D convolution of a padded ``image`` with ``filt``.

    ``image`` has shape ``(H + Fh - 1, W + Fw - 1)`` (pre-padded border, as
    in the BAT/convolution benchmark); output is ``(H, W)``. ``tile_h`` and
    ``tile_w`` must divide ``H`` and ``W``; ``unroll`` must divide ``Fh``.
    """
    fh, fw = filt.shape
    h = image.shape[0] - fh + 1
    w = image.shape[1] - fw + 1
    assert h % tile_h == 0, f"tile_h={tile_h} !| H={h}"
    assert w % tile_w == 0, f"tile_w={tile_w} !| W={w}"
    assert fh % unroll == 0, f"unroll={unroll} !| Fh={fh}"

    def kernel(x_ref, f_ref, o_ref):
        i = pl.program_id(0)
        j = pl.program_id(1)
        # Halo load: (tile_h + fh - 1, tile_w + fw - 1) input window.
        win = x_ref[pl.dslice(i * tile_h, tile_h + fh - 1),
                    pl.dslice(j * tile_w, tile_w + fw - 1)]
        f = f_ref[...]
        acc = jnp.zeros((tile_h, tile_w), dtype=jnp.float32)
        # Filter loops fully unrolled in groups of `unroll` rows — mirrors
        # the paper's partial loop unrolling tunable.
        for a0 in range(0, fh, unroll):
            for a in range(a0, a0 + unroll):
                for b in range(fw):
                    acc = acc + win[a:a + tile_h, b:b + tile_w] * f[a, b]
        o_ref[...] = acc

    return pl.pallas_call(
        kernel,
        grid=(h // tile_h, w // tile_w),
        in_specs=[
            # Full input resident (interpret mode); the index_map pins the
            # whole array so the kernel can take overlapping halo windows.
            pl.BlockSpec(image.shape, lambda i, j: (0, 0)),
            pl.BlockSpec(filt.shape, lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(image, filt)
