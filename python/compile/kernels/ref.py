"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package is checked against these references by
``python/tests/``: the kernels must agree (up to float tolerance) with the
oracle for *every* tunable configuration, because the auto-tuner treats all
configurations as functionally equivalent program variants.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
             alpha: float = 1.0, beta: float = 0.0) -> jnp.ndarray:
    """GEMM oracle: ``alpha * A @ B + beta * C`` (CLBlast semantics)."""
    return alpha * jnp.dot(a, b, preferred_element_type=jnp.float32) + beta * c


def conv2d_ref(image: jnp.ndarray, filt: jnp.ndarray) -> jnp.ndarray:
    """2D convolution oracle (van Werkhoven et al. 2014 semantics).

    ``image`` is the *padded* input of shape ``(H + Fh - 1, W + Fw - 1)``;
    the output is ``(H, W)`` with
    ``O(x, y) = sum_j sum_i I(x + i, y + j) * F(i, j)``.
    """
    fh, fw = filt.shape
    h = image.shape[0] - fh + 1
    w = image.shape[1] - fw + 1
    out = jnp.zeros((h, w), dtype=jnp.float32)
    for i in range(fh):
        for j in range(fw):
            out = out + image[i:i + h, j:j + w] * filt[i, j]
    return out


def dedispersion_ref(samples: jnp.ndarray, delays: jnp.ndarray,
                     n_time_out: int) -> jnp.ndarray:
    """Dedispersion oracle (AMBER semantics).

    ``samples``  — (n_channels, n_time_in) frequency-channel time series.
    ``delays``   — (n_dms, n_channels) integer sample delays per DM/channel.
    Output (n_dms, n_time_out):
    ``D(dm, t) = sum_c S(c, t + delays[dm, c])``.
    """
    n_dms = delays.shape[0]
    n_chan = samples.shape[0]
    rows = []
    for dm in range(n_dms):
        acc = jnp.zeros((n_time_out,), dtype=jnp.float32)
        for c in range(n_chan):
            d = int(delays[dm, c])
            acc = acc + samples[c, d:d + n_time_out]
        rows.append(acc)
    return jnp.stack(rows)


def hotspot_ref(temp: jnp.ndarray, power: jnp.ndarray,
                coeffs: tuple, steps: int = 1) -> jnp.ndarray:
    """Hotspot thermal stencil oracle (Rodinia semantics, simplified 2D).

    One step:
    ``T'[y,x] = T + cap*(P + cx*(T[y,x-1]+T[y,x+1]-2T) +
                          cy*(T[y-1,x]+T[y+1,x]-2T) + cz*(amb - T))``
    with clamped (edge-replicated) boundaries; ``coeffs = (cap, cx, cy, cz)``
    and ambient temperature 80.0 (Rodinia default).
    """
    cap, cx, cy, cz = coeffs
    amb = 80.0
    t = temp
    for _ in range(steps):
        left = jnp.concatenate([t[:, :1], t[:, :-1]], axis=1)
        right = jnp.concatenate([t[:, 1:], t[:, -1:]], axis=1)
        up = jnp.concatenate([t[:1, :], t[:-1, :]], axis=0)
        down = jnp.concatenate([t[1:, :], t[-1:, :]], axis=0)
        t = t + cap * (power + cx * (left + right - 2.0 * t)
                       + cy * (up + down - 2.0 * t) + cz * (amb - t))
    return t
