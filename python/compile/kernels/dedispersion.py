"""Tunable dedispersion Pallas kernel (L1).

AMBER's GPU dedispersion assigns thread blocks to (DM, time) tiles, each
thread summing frequency channels at per-(DM, channel) sample delays. The
Pallas adaptation runs the grid over DMs, loads the delay row for the current
DM as a blocked operand, and strides through the channel loop with a tunable
``channel_unroll`` factor — the analogue of the paper's partial loop unrolling
over frequency channels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def dedisperse(samples: jnp.ndarray, delays: jnp.ndarray,
               *, n_time_out: int, channel_unroll: int = 1) -> jnp.ndarray:
    """Dedisperse ``samples`` for every DM row of ``delays``.

    ``samples`` — (n_channels, n_time_in) f32
    ``delays``  — (n_dms, n_channels) i32, with
                  ``delays[dm, c] + n_time_out <= n_time_in``.
    Output: (n_dms, n_time_out) f32 where
    ``out[dm, t] = sum_c samples[c, t + delays[dm, c]]``.

    ``channel_unroll`` must divide ``n_channels``.
    """
    n_chan, n_time_in = samples.shape
    n_dms = delays.shape[0]
    assert delays.shape[1] == n_chan
    assert n_chan % channel_unroll == 0, \
        f"channel_unroll={channel_unroll} !| channels={n_chan}"

    def kernel(s_ref, d_ref, o_ref):
        acc = jnp.zeros((1, n_time_out), dtype=jnp.float32)
        # Channel loop unrolled in groups — the tunable schedule knob.
        for c0 in range(0, n_chan, channel_unroll):
            part = jnp.zeros((1, n_time_out), dtype=jnp.float32)
            for c in range(c0, c0 + channel_unroll):
                d = d_ref[0, c]
                part = part + s_ref[pl.dslice(c, 1), pl.dslice(d, n_time_out)]
            acc = acc + part
        o_ref[...] = acc

    return pl.pallas_call(
        kernel,
        grid=(n_dms,),
        in_specs=[
            pl.BlockSpec(samples.shape, lambda dm: (0, 0)),
            pl.BlockSpec((1, n_chan), lambda dm: (dm, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_time_out), lambda dm: (dm, 0)),
        out_shape=jax.ShapeDtypeStruct((n_dms, n_time_out), jnp.float32),
        interpret=True,
    )(samples, delays)
