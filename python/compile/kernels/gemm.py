"""Tunable tiled GEMM Pallas kernel (L1).

The paper's GEMM search space comes from CLBlast, whose CUDA/OpenCL kernel
tiles the computation over threadblocks and per-thread work items. On the
Pallas side the same insight maps to the HBM->VMEM block schedule:

  * CLBlast ``MWG x NWG`` workgroup tile  -> BlockSpec block shape
    ``(block_m, block_n)`` of the output,
  * the ``KWG`` k-loop staging tile       -> ``block_k`` grid dimension with
    an accumulate-in-place output block,
  * vector widths ``VWM/VWN``             -> lane-dimension alignment of the
    block shapes (multiples of 8 sublanes x 128 lanes target the MXU).

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute (see DESIGN.md
§Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref, *, k_steps: int,
                 alpha: float, beta: float, c_ref=None):
    """One (i, j, k) grid step: accumulate a (bm, bk) @ (bk, bn) product.

    The output block is revisited for every k step (its index map ignores
    ``k``), so it doubles as the accumulator — the standard Pallas matmul
    pattern that avoids scratch memory and works under interpret mode.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        if c_ref is None or beta == 0.0:
            o_ref[...] = jnp.zeros_like(o_ref)
        else:
            o_ref[...] = beta * c_ref[...]

    o_ref[...] += alpha * jnp.dot(a_ref[...], b_ref[...],
                                  preferred_element_type=jnp.float32)


def gemm(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
         *, block_m: int, block_n: int, block_k: int,
         alpha: float = 1.0, beta: float = 0.0) -> jnp.ndarray:
    """Compute ``alpha * A @ B + beta * C`` with a tiled Pallas kernel.

    Tunable parameters (the auto-tuning search space of this kernel):
      block_m, block_n — output tile shape staged in VMEM
      block_k          — reduction staging depth

    All three must divide the corresponding GEMM dimensions; the auto-tuner's
    constraint system guarantees this for every configuration it emits.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % block_m == 0, f"block_m={block_m} !| M={m}"
    assert n % block_n == 0, f"block_n={block_n} !| N={n}"
    assert k % block_k == 0, f"block_k={block_k} !| K={k}"
    k_steps = k // block_k

    grid = (m // block_m, n // block_n, k_steps)
    kernel = functools.partial(_gemm_kernel, k_steps=k_steps,
                               alpha=alpha, beta=beta)

    if beta != 0.0:
        def kernel_c(a_ref, b_ref, c_ref, o_ref):
            _gemm_kernel(a_ref, b_ref, o_ref, k_steps=k_steps,
                         alpha=alpha, beta=beta, c_ref=c_ref)

        return pl.pallas_call(
            kernel_c,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
                pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(a, b, c)

    def kernel_nc(a_ref, b_ref, o_ref):
        kernel(a_ref, b_ref, o_ref)

    return pl.pallas_call(
        kernel_nc,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def vmem_footprint_bytes(block_m: int, block_n: int, block_k: int,
                         with_c: bool) -> int:
    """Estimated per-step VMEM residency of a configuration (f32).

    Used by DESIGN.md §Perf to rank configurations for real-TPU viability:
    A-block + B-block + output accumulator (+ C-block when beta != 0).
    """
    f32 = 4
    total = (block_m * block_k + block_k * block_n + block_m * block_n) * f32
    if with_c:
        total += block_m * block_n * f32
    return total
