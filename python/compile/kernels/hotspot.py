"""Tunable hotspot thermal-stencil Pallas kernel (L1).

Rodinia's hotspot tiles the chip grid over threadblocks and optionally fuses
several stencil iterations per kernel launch (temporal tiling) to improve
locality. The Pallas adaptation grids over output tiles, loads a halo window
whose width grows with the temporal tiling factor, and applies ``t_tile``
fused stencil steps in registers — the exact locality trade-off the paper's
``temporal_tiling_factor`` tunable controls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

AMBIENT = 80.0


def _step(t, p, cap, cx, cy, cz):
    """One clamped-boundary stencil step over an arbitrary 2D tile."""
    left = jnp.concatenate([t[:, :1], t[:, :-1]], axis=1)
    right = jnp.concatenate([t[:, 1:], t[:, -1:]], axis=1)
    up = jnp.concatenate([t[:1, :], t[:-1, :]], axis=0)
    down = jnp.concatenate([t[1:, :], t[-1:, :]], axis=0)
    return t + cap * (p + cx * (left + right - 2.0 * t)
                      + cy * (up + down - 2.0 * t) + cz * (AMBIENT - t))


def hotspot(temp: jnp.ndarray, power: jnp.ndarray,
            coeffs, *, tile_h: int, tile_w: int, t_tile: int = 1
            ) -> jnp.ndarray:
    """Run ``t_tile`` fused hotspot steps, tiled ``tile_h x tile_w``.

    The halo needed for ``t_tile`` fused steps is ``t_tile`` cells on each
    side; interior tiles compute exactly, boundary tiles use clamped
    replication, matching the single-tile oracle only when the tile grid is
    1x1 *or* t_tile == 1 for interior-exact semantics. Tests exercise both.
    """
    cap, cx, cy, cz = (float(c) for c in coeffs)
    h, w = temp.shape
    assert h % tile_h == 0 and w % tile_w == 0
    halo = t_tile
    # The clamped halo window must fit inside the grid; the auto-tuner's
    # constraint system enforces this for every emitted configuration.
    assert h >= tile_h + 2 * halo and w >= tile_w + 2 * halo, \
        f"halo window ({tile_h}+2*{halo}) exceeds grid ({h}x{w})"

    def kernel(t_ref, p_ref, o_ref):
        i = pl.program_id(0)
        j = pl.program_id(1)
        # Clamped halo window offsets (interpret mode: plain dynamic slices
        # with jnp.clip emulating edge replication of the global border).
        y0 = jnp.clip(i * tile_h - halo, 0, h - (tile_h + 2 * halo))
        x0 = jnp.clip(j * tile_w - halo, 0, w - (tile_w + 2 * halo))
        t = t_ref[pl.dslice(y0, tile_h + 2 * halo),
                  pl.dslice(x0, tile_w + 2 * halo)]
        p = p_ref[pl.dslice(y0, tile_h + 2 * halo),
                  pl.dslice(x0, tile_w + 2 * halo)]
        for _ in range(t_tile):
            t = _step(t, p, cap, cx, cy, cz)
        # Write back the interior of the halo window that maps onto our tile.
        oy = i * tile_h - y0
        ox = j * tile_w - x0
        o_ref[...] = jax.lax.dynamic_slice(t, (oy, ox), (tile_h, tile_w))

    return pl.pallas_call(
        kernel,
        grid=(h // tile_h, w // tile_w),
        in_specs=[
            pl.BlockSpec(temp.shape, lambda i, j: (0, 0)),
            pl.BlockSpec(power.shape, lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(temp, power)
