"""L2 — JAX compute graphs for the auto-tunable applications.

Each function here is the *program variant generator* of one of the paper's
four benchmark applications: a jitted JAX function, parameterized by the
tunable configuration, that calls the L1 Pallas kernel so that the kernel
lowers into the same HLO module. ``aot.py`` lowers a grid of configurations
to HLO text; the Rust coordinator (L3) loads, compiles and *measures* them
via PJRT — the real compile-and-measure path of the auto-tuner.

Python never runs at tuning time; these functions exist only on the
build/compile path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import conv2d as conv2d_k
from .kernels import dedispersion as dedispersion_k
from .kernels import gemm as gemm_k
from .kernels import hotspot as hotspot_k

# Problem sizes for the AOT variant grid. Small enough that interpret-mode
# Pallas lowers and runs in reasonable time on CPU-PJRT, large enough that
# configuration choice changes measured runtime.
GEMM_M, GEMM_N, GEMM_K = 256, 256, 256
GEMM_ALPHA, GEMM_BETA = 1.5, 0.5

CONV_H, CONV_W = 256, 256
CONV_FH, CONV_FW = 7, 7

DEDISP_CHANNELS = 64
DEDISP_DMS = 32
DEDISP_TIME_OUT = 256
DEDISP_MAX_DELAY = 64  # n_time_in = TIME_OUT + MAX_DELAY

HOTSPOT_H, HOTSPOT_W = 128, 128
HOTSPOT_COEFFS = (0.5, 0.1, 0.1, 0.05)


def gemm_variant(block_m: int, block_n: int, block_k: int):
    """Return the jittable GEMM program variant for one configuration."""

    def fn(a, b, c):
        return (gemm_k.gemm(a, b, c, block_m=block_m, block_n=block_n,
                            block_k=block_k,
                            alpha=GEMM_ALPHA, beta=GEMM_BETA),)

    return fn, (
        jax.ShapeDtypeStruct((GEMM_M, GEMM_K), jnp.float32),
        jax.ShapeDtypeStruct((GEMM_K, GEMM_N), jnp.float32),
        jax.ShapeDtypeStruct((GEMM_M, GEMM_N), jnp.float32),
    )


def conv2d_variant(tile_h: int, tile_w: int, unroll: int = 1):
    """Return the jittable conv2d program variant for one configuration."""

    def fn(image, filt):
        return (conv2d_k.conv2d(image, filt, tile_h=tile_h, tile_w=tile_w,
                                unroll=unroll),)

    return fn, (
        jax.ShapeDtypeStruct((CONV_H + CONV_FH - 1, CONV_W + CONV_FW - 1),
                             jnp.float32),
        jax.ShapeDtypeStruct((CONV_FH, CONV_FW), jnp.float32),
    )


def dedispersion_variant(channel_unroll: int):
    """Return the jittable dedispersion program variant."""

    def fn(samples, delays):
        return (dedispersion_k.dedisperse(
            samples, delays, n_time_out=DEDISP_TIME_OUT,
            channel_unroll=channel_unroll),)

    return fn, (
        jax.ShapeDtypeStruct(
            (DEDISP_CHANNELS, DEDISP_TIME_OUT + DEDISP_MAX_DELAY),
            jnp.float32),
        jax.ShapeDtypeStruct((DEDISP_DMS, DEDISP_CHANNELS), jnp.int32),
    )


def hotspot_variant(tile_h: int, tile_w: int, t_tile: int = 1):
    """Return the jittable hotspot program variant."""

    def fn(temp, power):
        return (hotspot_k.hotspot(temp, power, HOTSPOT_COEFFS,
                                  tile_h=tile_h, tile_w=tile_w,
                                  t_tile=t_tile),)

    return fn, (
        jax.ShapeDtypeStruct((HOTSPOT_H, HOTSPOT_W), jnp.float32),
        jax.ShapeDtypeStruct((HOTSPOT_H, HOTSPOT_W), jnp.float32),
    )


# The AOT variant grids: every entry must satisfy the kernels' divisibility
# constraints (mirrored by the L3 constraint engine for the measured space).
GEMM_VARIANTS = [
    dict(block_m=bm, block_n=bn, block_k=bk)
    for bm in (32, 64, 128)
    for bn in (32, 64, 128)
    for bk in (32, 64, 128)
]

CONV_VARIANTS = [
    dict(tile_h=th, tile_w=tw, unroll=u)
    for th in (8, 16, 32)
    for tw in (8, 16, 32)
    for u in (1, 7)
]

DEDISP_VARIANTS = [dict(channel_unroll=u) for u in (1, 2, 4, 8, 16)]

HOTSPOT_VARIANTS = [
    dict(tile_h=th, tile_w=tw, t_tile=tt)
    for th in (16, 32, 64)
    for tw in (16, 32, 64)
    for tt in (1, 2, 4)
    if HOTSPOT_H >= th + 2 * tt and HOTSPOT_W >= tw + 2 * tt
]

VARIANT_BUILDERS = {
    "gemm": (gemm_variant, GEMM_VARIANTS),
    "conv2d": (conv2d_variant, CONV_VARIANTS),
    "dedispersion": (dedispersion_variant, DEDISP_VARIANTS),
    "hotspot": (hotspot_variant, HOTSPOT_VARIANTS),
}
