"""AOT bridge: lower every program variant to HLO *text* for the Rust L3.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/load_hlo.

Outputs, under ``--out-dir`` (default ``../artifacts``):
  <kernel>__<param>-<value>__...hlo.txt   one per variant
  manifest.tsv                            index the Rust runtime parses

Manifest columns (tab-separated):
  kernel  name  file  params(k=v;k=v)  inputs(dtype:d0xd1;...)  n_outputs
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variant_name(kernel: str, params: dict) -> str:
    parts = [f"{k}-{v}" for k, v in sorted(params.items())]
    return f"{kernel}__" + "__".join(parts)


def spec_str(spec) -> str:
    dims = "x".join(str(d) for d in spec.shape)
    return f"{spec.dtype}:{dims}"


def lower_variant(kernel: str, params: dict, out_dir: str) -> tuple:
    """Lower one configuration; returns its manifest row."""
    builder, _ = model.VARIANT_BUILDERS[kernel]
    fn, specs = builder(**params)
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    name = variant_name(kernel, params)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    params_s = ";".join(f"{k}={v}" for k, v in sorted(params.items()))
    inputs_s = ";".join(spec_str(s) for s in specs)
    return (kernel, name, fname, params_s, inputs_s, "1")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--kernels", default="all",
                    help="comma-separated subset of %s" %
                         ",".join(model.VARIANT_BUILDERS))
    args = ap.parse_args(argv)

    kernels = (list(model.VARIANT_BUILDERS) if args.kernels == "all"
               else args.kernels.split(","))
    os.makedirs(args.out_dir, exist_ok=True)

    rows = []
    t0 = time.time()
    for kernel in kernels:
        _, variants = model.VARIANT_BUILDERS[kernel]
        for params in variants:
            t1 = time.time()
            rows.append(lower_variant(kernel, params, args.out_dir))
            print(f"  lowered {rows[-1][1]} ({time.time() - t1:.2f}s)",
                  file=sys.stderr)

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# kernel\tname\tfile\tparams\tinputs\tn_outputs\n")
        for row in rows:
            f.write("\t".join(row) + "\n")
    print(f"wrote {len(rows)} variants + manifest to {args.out_dir} "
          f"in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
