"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

These are the core correctness signal of the compile path: the auto-tuner
assumes all configurations of a kernel are functionally equivalent, so every
tunable configuration exercised here must match the oracle.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gemm import gemm, vmem_footprint_bytes
from compile.kernels.conv2d import conv2d
from compile.kernels.dedispersion import dedisperse
from compile.kernels.hotspot import hotspot

RNG = np.random.default_rng(42)


def rand(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------- GEMM ----

GEMM_CFGS = [(bm, bn, bk) for bm in (16, 32, 64) for bn in (16, 32, 64)
             for bk in (16, 32, 64)]


@pytest.mark.parametrize("bm,bn,bk", GEMM_CFGS)
def test_gemm_all_tile_configs(bm, bn, bk):
    m, n, k = 64, 64, 64
    a, b, c = rand(m, k), rand(k, n), rand(m, n)
    got = gemm(a, b, c, block_m=bm, block_n=bn, block_k=bk,
               alpha=1.5, beta=0.5)
    want = ref.gemm_ref(a, b, c, alpha=1.5, beta=0.5)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gemm_beta_zero_skips_c():
    a, b, c = rand(32, 32), rand(32, 32), rand(32, 32)
    got = gemm(a, b, c, block_m=16, block_n=16, block_k=16,
               alpha=2.0, beta=0.0)
    want = ref.gemm_ref(a, b, c, alpha=2.0, beta=0.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gemm_rectangular():
    a, b, c = rand(64, 32), rand(32, 128), rand(64, 128)
    got = gemm(a, b, c, block_m=32, block_n=64, block_k=16,
               alpha=1.0, beta=1.0)
    want = ref.gemm_ref(a, b, c, alpha=1.0, beta=1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gemm_rejects_nondividing_tiles():
    a, b, c = rand(64, 64), rand(64, 64), rand(64, 64)
    with pytest.raises(AssertionError):
        gemm(a, b, c, block_m=48, block_n=16, block_k=16)


def test_gemm_vmem_footprint_monotone():
    small = vmem_footprint_bytes(32, 32, 32, with_c=False)
    large = vmem_footprint_bytes(128, 128, 128, with_c=False)
    assert small < large
    assert vmem_footprint_bytes(32, 32, 32, True) > small


@settings(max_examples=20, deadline=None)
@given(
    mi=st.integers(1, 4), ni=st.integers(1, 4), ki=st.integers(1, 4),
    bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    alpha=st.floats(-2, 2, allow_nan=False),
    beta=st.floats(-2, 2, allow_nan=False),
)
def test_gemm_hypothesis_shapes(mi, ni, ki, bm, bn, bk, alpha, beta):
    """Hypothesis sweep: arbitrary multiples of the tile in every dim."""
    m, n, k = mi * bm, ni * bn, ki * bk
    a, b, c = rand(m, k), rand(k, n), rand(m, n)
    got = gemm(a, b, c, block_m=bm, block_n=bn, block_k=bk,
               alpha=alpha, beta=beta)
    want = ref.gemm_ref(a, b, c, alpha=alpha, beta=beta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- conv2d ----

@pytest.mark.parametrize("th,tw", [(8, 8), (8, 16), (16, 8), (16, 16),
                                   (32, 32), (8, 32)])
@pytest.mark.parametrize("unroll", [1, 7])
def test_conv2d_tile_configs(th, tw, unroll):
    h, w, fh, fw = 32, 32, 7, 7
    img = rand(h + fh - 1, w + fw - 1)
    filt = rand(fh, fw)
    got = conv2d(img, filt, tile_h=th, tile_w=tw, unroll=unroll)
    want = ref.conv2d_ref(img, filt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_identity_filter():
    img = rand(34, 34)
    filt = jnp.zeros((3, 3), jnp.float32).at[1, 1].set(1.0)
    got = conv2d(img, filt, tile_h=16, tile_w=16)
    np.testing.assert_allclose(got, img[1:33, 1:33], rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    ti=st.integers(1, 3), tj=st.integers(1, 3),
    th=st.sampled_from([4, 8]), tw=st.sampled_from([4, 8]),
    fh=st.sampled_from([3, 5]), fw=st.sampled_from([3, 5]),
)
def test_conv2d_hypothesis(ti, tj, th, tw, fh, fw):
    h, w = ti * th, tj * tw
    img = rand(h + fh - 1, w + fw - 1)
    filt = rand(fh, fw)
    got = conv2d(img, filt, tile_h=th, tile_w=tw)
    want = ref.conv2d_ref(img, filt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------- dedispersion ----

def make_delays(n_dms, n_chan, max_delay):
    # Quadratic-in-frequency delay curve like the real DM sweep.
    dms = np.arange(n_dms)[:, None]
    chans = np.arange(n_chan)[None, :]
    d = (max_delay * (dms / max(n_dms - 1, 1))
         * (1.0 - chans / max(n_chan, 1)) ** 2).astype(np.int32)
    return jnp.asarray(d)


@pytest.mark.parametrize("unroll", [1, 2, 4, 8])
def test_dedispersion_unroll_configs(unroll):
    n_chan, n_dms, t_out, max_d = 16, 8, 32, 8
    samples = rand(n_chan, t_out + max_d)
    delays = make_delays(n_dms, n_chan, max_d)
    got = dedisperse(samples, delays, n_time_out=t_out,
                     channel_unroll=unroll)
    want = ref.dedispersion_ref(samples, delays, t_out)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dedispersion_zero_delays_is_channel_sum():
    samples = rand(8, 16)
    delays = jnp.zeros((4, 8), jnp.int32)
    got = dedisperse(samples, delays, n_time_out=16, channel_unroll=2)
    want = jnp.tile(samples.sum(axis=0), (4, 1))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    n_chan=st.sampled_from([4, 8, 16]),
    n_dms=st.integers(1, 6),
    t_out=st.sampled_from([8, 16]),
    unroll=st.sampled_from([1, 2, 4]),
)
def test_dedispersion_hypothesis(n_chan, n_dms, t_out, unroll):
    max_d = 4
    samples = rand(n_chan, t_out + max_d)
    delays = make_delays(n_dms, n_chan, max_d)
    got = dedisperse(samples, delays, n_time_out=t_out,
                     channel_unroll=unroll)
    want = ref.dedispersion_ref(samples, delays, t_out)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- hotspot ----

COEFFS = (0.5, 0.1, 0.1, 0.05)


@pytest.mark.parametrize("th,tw", [(8, 8), (16, 16), (8, 16), (32, 32)])
def test_hotspot_single_step_tiles(th, tw):
    h = w = 64
    temp = jnp.asarray(RNG.uniform(60, 100, (h, w)).astype(np.float32))
    power = jnp.asarray(RNG.uniform(0, 1, (h, w)).astype(np.float32))
    got = hotspot(temp, power, COEFFS, tile_h=th, tile_w=tw, t_tile=1)
    want = ref.hotspot_ref(temp, power, COEFFS, steps=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t_tile", [1, 2, 4])
def test_hotspot_temporal_tiling_exact(t_tile):
    """Temporal tiling with halo == t_tile must be exact everywhere."""
    h = w = 64
    temp = jnp.asarray(RNG.uniform(60, 100, (h, w)).astype(np.float32))
    power = jnp.asarray(RNG.uniform(0, 1, (h, w)).astype(np.float32))
    got = hotspot(temp, power, COEFFS, tile_h=16, tile_w=16, t_tile=t_tile)
    want = ref.hotspot_ref(temp, power, COEFFS, steps=t_tile)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_hotspot_equilibrium_fixed_point():
    """Uniform ambient temperature with zero power stays put."""
    h = w = 32
    temp = jnp.full((h, w), 80.0, jnp.float32)
    power = jnp.zeros((h, w), jnp.float32)
    got = hotspot(temp, power, COEFFS, tile_h=16, tile_w=16, t_tile=2)
    np.testing.assert_allclose(got, temp, rtol=1e-6, atol=1e-6)
