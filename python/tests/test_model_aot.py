"""L2/AOT tests: variant builders produce correct graphs and valid HLO text.

Checks that (a) every declared AOT variant satisfies the kernel constraints,
(b) the jitted variant output matches the oracle, and (c) lowering to HLO
text yields a parseable module with an ENTRY computation (the format the
Rust runtime's ``HloModuleProto::from_text_file`` consumes).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_variant_grids_nonempty():
    for kernel, (_, variants) in model.VARIANT_BUILDERS.items():
        assert len(variants) >= 4, kernel


def test_gemm_variants_satisfy_divisibility():
    for v in model.GEMM_VARIANTS:
        assert model.GEMM_M % v["block_m"] == 0
        assert model.GEMM_N % v["block_n"] == 0
        assert model.GEMM_K % v["block_k"] == 0


def test_conv_variants_satisfy_divisibility():
    for v in model.CONV_VARIANTS:
        assert model.CONV_H % v["tile_h"] == 0
        assert model.CONV_W % v["tile_w"] == 0
        assert model.CONV_FH % v["unroll"] == 0


def test_hotspot_variants_satisfy_halo():
    for v in model.HOTSPOT_VARIANTS:
        assert model.HOTSPOT_H >= v["tile_h"] + 2 * v["t_tile"]
        assert model.HOTSPOT_W >= v["tile_w"] + 2 * v["t_tile"]


def test_gemm_variant_matches_ref():
    rng = np.random.default_rng(0)
    fn, specs = model.gemm_variant(64, 64, 64)
    args = [jnp.asarray(rng.standard_normal(s.shape).astype(np.float32))
            for s in specs]
    (got,) = jax.jit(fn)(*args)
    want = ref.gemm_ref(*args, alpha=model.GEMM_ALPHA, beta=model.GEMM_BETA)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_variant_name_roundtrip():
    name = aot.variant_name("gemm", dict(block_m=64, block_k=32, block_n=16))
    # Sorted parameter order => deterministic artifact names.
    assert name == "gemm__block_k-32__block_m-64__block_n-16"


def test_lowered_hlo_text_has_entry():
    fn, specs = model.gemm_variant(128, 128, 128)
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    # return_tuple=True => tuple-shaped root, which the Rust side unwraps.
    assert "(f32[256,256]" in text.replace(" ", "")


def test_lowered_dedispersion_hlo(tmp_path):
    row = aot.lower_variant("dedispersion", dict(channel_unroll=16),
                            str(tmp_path))
    kernel, name, fname, params_s, inputs_s, n_out = row
    assert kernel == "dedispersion"
    assert params_s == "channel_unroll=16"
    assert inputs_s.startswith("float32:64x320;int32:32x64")
    text = (tmp_path / fname).read_text()
    assert "ENTRY" in text


def test_manifest_write(tmp_path):
    aot.main(["--out-dir", str(tmp_path), "--kernels", "dedispersion"])
    manifest = (tmp_path / "manifest.tsv").read_text().strip().splitlines()
    assert manifest[0].startswith("#")
    rows = [l.split("\t") for l in manifest[1:]]
    assert len(rows) == len(model.DEDISP_VARIANTS)
    for r in rows:
        assert len(r) == 6
        assert (tmp_path / r[2]).exists()
