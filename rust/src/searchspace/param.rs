//! Tunable parameters and their value domains.
//!
//! Mirrors Kernel Tuner's `tune_params`: an ordered dict of parameter name →
//! list of allowed values. Configurations are stored as *value indices*
//! (`u16` per dimension) for compactness — the hot loops of the simulator
//! and optimizers never touch the actual values, only the constraint engine
//! and performance models do.

use std::fmt;

/// A single tunable value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(&'static str),
}

impl Value {
    /// Numeric view used by the constraint engine and performance models
    /// (bools become 0/1; strings are hashed to a stable small integer).
    pub fn as_f64(&self) -> f64 {
        match self {
            Value::Int(i) => *i as f64,
            Value::Float(x) => *x,
            Value::Bool(b) => {
                if *b {
                    1.0
                } else {
                    0.0
                }
            }
            Value::Str(s) => crate::util::rng::fnv1a(s.as_bytes()) as u32 as f64,
        }
    }

    pub fn as_i64(&self) -> i64 {
        match self {
            Value::Int(i) => *i,
            Value::Float(x) => *x as i64,
            Value::Bool(b) => *b as i64,
            Value::Str(_) => self.as_f64() as i64,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(i) => write!(f, "{}", i),
            Value::Float(x) => write!(f, "{}", x),
            Value::Bool(b) => write!(f, "{}", *b as u8),
            Value::Str(s) => write!(f, "{}", s),
        }
    }
}

/// A tunable parameter: a name and its ordered value domain.
#[derive(Debug, Clone)]
pub struct Param {
    pub name: String,
    pub values: Vec<Value>,
}

impl Param {
    pub fn ints(name: &str, values: &[i64]) -> Param {
        Param {
            name: name.to_string(),
            values: values.iter().map(|&v| Value::Int(v)).collect(),
        }
    }

    pub fn bools(name: &str) -> Param {
        Param {
            name: name.to_string(),
            values: vec![Value::Bool(false), Value::Bool(true)],
        }
    }

    /// Fixed (single-valued) parameter — BAT pins several CLBlast tunables.
    pub fn fixed(name: &str, value: i64) -> Param {
        Param::ints(name, &[value])
    }

    /// Float-valued parameter (hyperparameter domains in `crate::hypertune`
    /// meta-spaces; the kernel spaces themselves are integer-valued).
    pub fn floats(name: &str, values: &[f64]) -> Param {
        Param {
            name: name.to_string(),
            values: values.iter().map(|&v| Value::Float(v)).collect(),
        }
    }

    pub fn cardinality(&self) -> usize {
        self.values.len()
    }
}

/// An ordered parameter set; owns the name → dimension resolution.
#[derive(Debug, Clone, Default)]
pub struct ParamSet {
    pub params: Vec<Param>,
}

impl ParamSet {
    pub fn new(params: Vec<Param>) -> ParamSet {
        ParamSet { params }
    }

    pub fn dims(&self) -> usize {
        self.params.len()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Cartesian (unconstrained) size of the space.
    pub fn cartesian_size(&self) -> u64 {
        self.params.iter().map(|p| p.cardinality() as u64).product()
    }

    /// Numeric value of dimension `dim` at value-index `vi`.
    #[inline]
    pub fn value_f64(&self, dim: usize, vi: u16) -> f64 {
        self.params[dim].values[vi as usize].as_f64()
    }

    /// Render a config (value indices) as `name=value` pairs.
    pub fn describe(&self, cfg: &[u16]) -> String {
        cfg.iter()
            .enumerate()
            .map(|(d, &vi)| {
                format!("{}={}", self.params[d].name, self.params[d].values[vi as usize])
            })
            .collect::<Vec<_>>()
            .join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_size_is_product() {
        let ps = ParamSet::new(vec![
            Param::ints("a", &[1, 2, 3]),
            Param::bools("b"),
            Param::fixed("c", 32),
        ]);
        assert_eq!(ps.cartesian_size(), 6);
        assert_eq!(ps.dims(), 3);
    }

    #[test]
    fn name_resolution() {
        let ps = ParamSet::new(vec![Param::ints("x", &[0]), Param::ints("y", &[0])]);
        assert_eq!(ps.index_of("y"), Some(1));
        assert_eq!(ps.index_of("z"), None);
    }

    #[test]
    fn value_views() {
        assert_eq!(Value::Int(7).as_f64(), 7.0);
        assert_eq!(Value::Bool(true).as_f64(), 1.0);
        assert_eq!(Value::Float(2.5).as_i64(), 2);
        assert_eq!(format!("{}", Value::Bool(false)), "0");
    }

    #[test]
    fn describe_config() {
        let ps = ParamSet::new(vec![
            Param::ints("a", &[8, 16]),
            Param::bools("pad"),
        ]);
        assert_eq!(ps.describe(&[1, 0]), "a=16, pad=0");
    }
}
