//! Builders for the paper's four benchmark search spaces (Table 1).
//!
//! Parameter grids follow the BAT benchmark suite definitions (Tørring et
//! al. 2023): dedispersion from the AMBER pipeline, 2D convolution from
//! van Werkhoven et al. 2014, hotspot from Rodinia, GEMM from CLBlast.
//! Value lists are chosen so the Cartesian sizes match the paper's Table 1
//! exactly where the factorization allows (convolution 10,240; GEMM 663,552)
//! and within a few percent elsewhere; constrained sizes are *emergent* from
//! the constraint systems below and are compared against the paper by
//! `llamea-kt experiment table1` (see EXPERIMENTS.md).

use super::param::{Param, ParamSet};
use super::space::SearchSpace;

/// The four benchmark applications.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Application {
    Dedispersion,
    Convolution,
    Hotspot,
    Gemm,
}

impl Application {
    pub const ALL: [Application; 4] = [
        Application::Dedispersion,
        Application::Convolution,
        Application::Hotspot,
        Application::Gemm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Application::Dedispersion => "dedispersion",
            Application::Convolution => "convolution",
            Application::Hotspot => "hotspot",
            Application::Gemm => "gemm",
        }
    }

    pub fn from_name(name: &str) -> Option<Application> {
        Application::ALL.iter().copied().find(|a| a.name() == name)
    }

    /// Paper Table 1 reference values (cartesian, constrained, dims).
    pub fn paper_table1(&self) -> (u64, u64, usize) {
        match self {
            Application::Dedispersion => (22_272, 11_130, 8),
            Application::Convolution => (10_240, 4_362, 10),
            Application::Hotspot => (22_200_000, 349_853, 11),
            Application::Gemm => (663_552, 116_928, 17),
        }
    }

    /// The declarative space specification (name, parameter grid,
    /// constraint sources) *without* enumerating it. This is the seam the
    /// persistent store (`crate::persist`) builds on: the spec both seeds
    /// the build fingerprint (any edit to a parameter list or constraint
    /// string changes the fingerprint and invalidates stored arenas) and
    /// reconstitutes params/constraints when a space is loaded from disk.
    pub fn space_spec(&self) -> SpaceSpec {
        match self {
            Application::Dedispersion => dedispersion_spec(),
            Application::Convolution => convolution_spec(),
            Application::Hotspot => hotspot_spec(),
            Application::Gemm => gemm_spec(),
        }
    }

    pub fn build_space(&self) -> SearchSpace {
        let spec = self.space_spec();
        SearchSpace::build(spec.name, spec.params, spec.constraints)
            .unwrap_or_else(|e| panic!("{} space: {e}", spec.name))
    }
}

/// A search space's declarative definition, prior to enumeration.
pub struct SpaceSpec {
    pub name: &'static str,
    pub params: ParamSet,
    pub constraints: &'static [&'static str],
}

/// Dedispersion (AMBER / ARTS survey): 8 tunables.
///
/// Cartesian: 6*2*4*4*2*2*7*4 = 21,504 (paper: 22,272, -3.4%).
fn dedispersion_spec() -> SpaceSpec {
    let params = ParamSet::new(vec![
        Param::ints("block_size_x", &[1, 2, 4, 8, 16, 32]),
        Param::ints("block_size_y", &[8, 16]),
        Param::ints("tile_size_x", &[1, 2, 3, 4]),
        Param::ints("tile_size_y", &[1, 2, 3, 4]),
        Param::ints("tile_stride_x", &[0, 1]),
        Param::ints("tile_stride_y", &[0, 1]),
        // 0 delegates unrolling to the compiler; others divide 1536 channels.
        Param::ints("loop_unroll_factor_channel", &[0, 1, 2, 4, 8, 16, 32]),
        Param::ints("blocks_per_sm", &[0, 1, 2, 3]),
    ]);
    SpaceSpec {
        name: "dedispersion",
        params,
        constraints: &[
            // Thread block shape limits.
            "block_size_x * block_size_y >= 32",
            "block_size_x * block_size_y <= 1024",
            // A stride choice is only meaningful with more than one tile.
            "tile_size_x > 1 || tile_stride_x == 0",
            "tile_size_y > 1 || tile_stride_y == 0",
            // Register pressure: total work items per thread bounded.
            "tile_size_x * tile_size_y <= 12",
        ],
    }
}

pub fn build_dedispersion() -> SearchSpace {
    Application::Dedispersion.build_space()
}

/// 2D convolution (van Werkhoven et al. 2014): 10 tunables.
///
/// Cartesian: 8*4*5*4*2*2*2*2*1*1 = 10,240 (paper: 10,240, exact).
/// filter_height/filter_width are fixed 15x15 as in the BAT scenario.
fn convolution_spec() -> SpaceSpec {
    let params = ParamSet::new(vec![
        Param::ints("block_size_x", &[16, 32, 48, 64, 80, 96, 112, 128]),
        Param::ints("block_size_y", &[1, 2, 4, 8]),
        Param::ints("tile_size_x", &[1, 2, 3, 4, 5]),
        Param::ints("tile_size_y", &[1, 2, 3, 4]),
        Param::ints("use_padding", &[0, 1]),
        Param::ints("read_only", &[0, 1]),
        Param::ints("use_shmem", &[0, 1]),
        Param::ints("vector", &[1, 4]),
        Param::fixed("filter_height", 15),
        Param::fixed("filter_width", 15),
    ]);
    SpaceSpec {
        name: "convolution",
        params,
        constraints: &[
            "block_size_x * block_size_y >= 32",
            "block_size_x * block_size_y <= 1024",
            // Padding only exists for the shared-memory path, and only helps
            // when the block width is not a multiple of the 32 memory banks.
            "use_padding == 0 || use_shmem == 1",
            "use_padding == 0 || (block_size_x % 32 != 0)",
            // Shared-memory tile (input staging incl. filter halo) must fit
            // 48 KiB of f32 values.
            "use_shmem == 0 || (block_size_x*tile_size_x + filter_width - 1) * (block_size_y*tile_size_y + filter_height - 1) * 4 <= 49152",
            // Vectorized loads require the block width to stay lane aligned.
            "vector == 1 || block_size_x % (vector * 8) == 0",
        ],
    }
}

pub fn build_convolution() -> SearchSpace {
    Application::Convolution.build_space()
}

/// Hotspot (Rodinia): 11 tunables.
///
/// Cartesian: 11*11*8*8*10*9*2*2*2*2*2 = 22,302,720 (paper: 22,200,000,
/// +0.46%).
fn hotspot_spec() -> SpaceSpec {
    let pow2: Vec<i64> = (0..11).map(|i| 1i64 << i).collect(); // 1..1024
    let params = ParamSet::new(vec![
        Param::ints("block_size_x", &pow2),
        Param::ints("block_size_y", &pow2),
        Param::ints("tile_size_x", &[1, 2, 3, 4, 5, 6, 7, 8]),
        Param::ints("tile_size_y", &[1, 2, 3, 4, 5, 6, 7, 8]),
        Param::ints("temporal_tiling_factor", &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]),
        Param::ints("loop_unroll_factor_t", &[1, 2, 3, 4, 5, 6, 7, 8, 9]),
        Param::ints("sh_power", &[0, 1]),
        Param::ints("blocks_per_sm", &[0, 1]),
        Param::ints("vector", &[1, 2]),
        Param::ints("reorder", &[0, 1]),
        Param::ints("double_buffer", &[0, 1]),
    ]);
    SpaceSpec {
        name: "hotspot",
        params,
        constraints: &[
            "block_size_x * block_size_y >= 32",
            "block_size_x * block_size_y <= 1024",
            // The time unroll must divide the temporal tiling factor.
            "temporal_tiling_factor % loop_unroll_factor_t == 0",
            // Shared-memory tile incl. the temporal halo must fit 40 KiB of
            // two f32 grids (temperature + power).
            "(block_size_x*tile_size_x + temporal_tiling_factor*2) * (block_size_y*tile_size_y + temporal_tiling_factor*2) * 8 <= 36864",
            // The halo must not exceed the tile extent it wraps.
            "temporal_tiling_factor * 2 <= block_size_x * tile_size_x",
            "temporal_tiling_factor * 2 <= block_size_y * tile_size_y",
            // Double buffering requires the shared-memory path.
            "double_buffer == 0 || sh_power == 1",
        ],
    }
}

pub fn build_hotspot() -> SearchSpace {
    Application::Hotspot.build_space()
}

/// GEMM (CLBlast): 17 tunables (three pinned by BAT's scenario).
///
/// Cartesian: 4*4*1*3*3*3*3*2*4*4*2*2*2*2*1*1*1 = 663,552 (paper: exact).
fn gemm_spec() -> SpaceSpec {
    let params = ParamSet::new(vec![
        Param::ints("MWG", &[16, 32, 64, 128]),
        Param::ints("NWG", &[16, 32, 64, 128]),
        Param::fixed("KWG", 32),
        Param::ints("MDIMC", &[8, 16, 32]),
        Param::ints("NDIMC", &[8, 16, 32]),
        Param::ints("MDIMA", &[8, 16, 32]),
        Param::ints("NDIMB", &[8, 16, 32]),
        Param::ints("KWI", &[2, 8]),
        Param::ints("VWM", &[1, 2, 4, 8]),
        Param::ints("VWN", &[1, 2, 4, 8]),
        Param::ints("STRM", &[0, 1]),
        Param::ints("STRN", &[0, 1]),
        Param::ints("SA", &[0, 1]),
        Param::ints("SB", &[0, 1]),
        Param::fixed("PRECISION", 32),
        Param::fixed("GEMMK", 0),
        Param::fixed("KREG", 1),
    ]);
    SpaceSpec {
        name: "gemm",
        params,
        constraints: &[
            // The canonical CLBlast xgemm restrictions.
            "KWG % KWI == 0",
            "MWG % (MDIMC * VWM) == 0",
            "NWG % (NDIMC * VWN) == 0",
            "MWG % (MDIMA * VWM) == 0",
            "NWG % (NDIMB * VWN) == 0",
            "KWG % ((MDIMC * NDIMC) / MDIMA) == 0",
            "KWG % ((MDIMC * NDIMC) / NDIMB) == 0",
            // Work-group size cap (occupancy viability).
            "MDIMC * NDIMC <= 512",
            // Strided access is only distinct for vectorized loads of A.
            "STRM == 0 || VWM > 1",
        ],
    }
}

pub fn build_gemm() -> SearchSpace {
    Application::Gemm.build_space()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match_paper() {
        for app in Application::ALL {
            let (_, _, dims) = app.paper_table1();
            let space = app.build_space();
            assert_eq!(space.dims(), dims, "{}", app.name());
        }
    }

    #[test]
    fn gemm_cartesian_exact() {
        assert_eq!(build_gemm().cartesian_size(), 663_552);
    }

    #[test]
    fn convolution_cartesian_exact() {
        assert_eq!(build_convolution().cartesian_size(), 10_240);
    }

    #[test]
    fn cartesian_within_5pct_of_paper() {
        for app in Application::ALL {
            let (paper, _, _) = app.paper_table1();
            let ours = app.build_space().cartesian_size();
            let rel = (ours as f64 - paper as f64).abs() / paper as f64;
            assert!(rel < 0.05, "{}: ours {} vs paper {}", app.name(), ours, paper);
        }
    }

    #[test]
    fn spaces_nonempty_and_sane() {
        for app in [Application::Dedispersion, Application::Convolution, Application::Gemm] {
            let s = app.build_space();
            assert!(s.len() > 100, "{}: {}", app.name(), s.len());
            assert!((s.len() as u64) < s.cartesian_size());
        }
    }
}
