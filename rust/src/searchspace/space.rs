//! Search space construction and navigation.
//!
//! The space is constructed once per (kernel, GPU) pair by depth-first
//! enumeration of the Cartesian grid with *early constraint evaluation*: a
//! constraint is checked as soon as its deepest referenced dimension is
//! assigned, pruning entire subtrees (the approach of Willemsen et al. 2025a
//! which the paper builds on). Valid configurations are stored in a flat
//! arena (`u16` value indices) plus a hash index for O(1) membership tests —
//! the primitive behind the neighbor operations that Kernel Tuner's
//! `SearchSpace` object exposes to generated optimizers:
//!   * `get_neighbors` (Hamming / adjacent / strictly-adjacent)
//!   * `get_random_sample`
//!   * `repair` of infeasible configurations

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use super::constraint::Constraint;
use super::param::ParamSet;
use crate::util::rng::Rng;

/// FxHash-style hasher (no SipHash overhead on the hot membership path).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        const K: u64 = 0x517cc1b727220a95;
        for &b in bytes {
            self.hash = (self.hash.rotate_left(5) ^ b as u64).wrapping_mul(K);
        }
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        const K: u64 = 0x517cc1b727220a95;
        self.hash = (self.hash.rotate_left(5) ^ i as u64).wrapping_mul(K);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Neighborhood definitions, mirroring Kernel Tuner's options.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NeighborKind {
    /// Differ in exactly one dimension, any other value of that dimension.
    Hamming,
    /// Differ in exactly one dimension by ±1 value-index step.
    Adjacent,
    /// Differ in any number of dimensions, each by at most ±1 value-index;
    /// restricted here to single-dim ±1 plus diagonal two-dim moves kept
    /// tractable (Kernel Tuner's "strictly-adjacent" cube, sampled).
    StrictlyAdjacent,
}

/// A fully constructed, constraint-filtered search space.
pub struct SearchSpace {
    pub name: String,
    pub params: ParamSet,
    pub constraints: Vec<Constraint>,
    /// Flat arena: config i occupies `[i*dims, (i+1)*dims)`.
    data: Vec<u16>,
    dims: usize,
    index: HashMap<Box<[u16]>, u32, FxBuildHasher>,
}

impl SearchSpace {
    /// Enumerate all valid configurations (DFS with early pruning).
    pub fn build(name: &str, params: ParamSet, constraint_srcs: &[&str]) -> Result<SearchSpace, String> {
        let constraints: Vec<Constraint> = constraint_srcs
            .iter()
            .map(|s| Constraint::parse(s, &params).map_err(|e| format!("{}: {}", s, e)))
            .collect::<Result<_, _>>()?;
        Ok(Self::build_parsed(name, params, constraints))
    }

    pub fn build_parsed(name: &str, params: ParamSet, constraints: Vec<Constraint>) -> SearchSpace {
        let dims = params.dims();
        // Bucket constraints by the dimension at which they become checkable.
        let mut by_depth: Vec<Vec<&Constraint>> = vec![Vec::new(); dims];
        for c in &constraints {
            by_depth[c.max_dim].push(c);
        }

        let mut data: Vec<u16> = Vec::new();
        let mut cfg: Vec<u16> = vec![0; dims];
        let mut vals: Vec<f64> = vec![0.0; dims];

        // Iterative DFS over dimensions.
        fn dfs(
            d: usize,
            dims: usize,
            params: &ParamSet,
            by_depth: &[Vec<&Constraint>],
            cfg: &mut [u16],
            vals: &mut [f64],
            data: &mut Vec<u16>,
        ) {
            if d == dims {
                data.extend_from_slice(cfg);
                return;
            }
            for vi in 0..params.params[d].cardinality() {
                cfg[d] = vi as u16;
                vals[d] = params.value_f64(d, vi as u16);
                if by_depth[d].iter().all(|c| c.holds(vals)) {
                    dfs(d + 1, dims, params, by_depth, cfg, vals, data);
                }
            }
        }
        dfs(0, dims, &params, &by_depth, &mut cfg, &mut vals, &mut data);

        let n = data.len() / dims.max(1);
        let mut index: HashMap<Box<[u16]>, u32, FxBuildHasher> =
            HashMap::with_capacity_and_hasher(n, FxBuildHasher::default());
        for i in 0..n {
            index.insert(data[i * dims..(i + 1) * dims].into(), i as u32);
        }

        SearchSpace {
            name: name.to_string(),
            params,
            constraints,
            data,
            dims,
            index,
        }
    }

    /// Number of valid configurations ("constrained size", Table 1).
    #[inline]
    pub fn len(&self) -> usize {
        if self.dims == 0 {
            0
        } else {
            self.data.len() / self.dims
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn dims(&self) -> usize {
        self.dims
    }

    pub fn cartesian_size(&self) -> u64 {
        self.params.cartesian_size()
    }

    /// The configuration at a valid index.
    #[inline]
    pub fn config(&self, i: u32) -> &[u16] {
        let i = i as usize;
        &self.data[i * self.dims..(i + 1) * self.dims]
    }

    /// Index of a configuration if it is valid.
    #[inline]
    pub fn index_of(&self, cfg: &[u16]) -> Option<u32> {
        self.index.get(cfg).copied()
    }

    /// Whether value-index assignment `cfg` satisfies all constraints
    /// (independent of enumeration — used by property tests and repair).
    pub fn satisfies_constraints(&self, cfg: &[u16]) -> bool {
        let vals: Vec<f64> = cfg
            .iter()
            .enumerate()
            .map(|(d, &vi)| self.params.value_f64(d, vi))
            .collect();
        self.constraints.iter().all(|c| c.holds(&vals))
    }

    /// Numeric parameter values of a valid config, by dimension.
    pub fn values_f64(&self, i: u32) -> Vec<f64> {
        self.config(i)
            .iter()
            .enumerate()
            .map(|(d, &vi)| self.params.value_f64(d, vi))
            .collect()
    }

    /// A uniformly random valid configuration index.
    #[inline]
    pub fn random_valid(&self, rng: &mut Rng) -> u32 {
        rng.below(self.len()) as u32
    }

    /// Distinct random valid configuration indices (initial populations).
    pub fn random_sample(&self, rng: &mut Rng, k: usize) -> Vec<u32> {
        rng.sample_indices(self.len(), k)
            .into_iter()
            .map(|i| i as u32)
            .collect()
    }

    /// Valid neighbors of configuration `i` under `kind`.
    pub fn neighbors(&self, i: u32, kind: NeighborKind) -> Vec<u32> {
        let base = self.config(i).to_vec();
        let mut out = Vec::new();
        let mut probe = base.clone();
        match kind {
            NeighborKind::Hamming => {
                for d in 0..self.dims {
                    let orig = base[d];
                    for vi in 0..self.params.params[d].cardinality() as u16 {
                        if vi == orig {
                            continue;
                        }
                        probe[d] = vi;
                        if let Some(j) = self.index_of(&probe) {
                            out.push(j);
                        }
                    }
                    probe[d] = orig;
                }
            }
            NeighborKind::Adjacent => {
                for d in 0..self.dims {
                    let orig = base[d];
                    let card = self.params.params[d].cardinality() as u16;
                    if orig > 0 {
                        probe[d] = orig - 1;
                        if let Some(j) = self.index_of(&probe) {
                            out.push(j);
                        }
                    }
                    if orig + 1 < card {
                        probe[d] = orig + 1;
                        if let Some(j) = self.index_of(&probe) {
                            out.push(j);
                        }
                    }
                    probe[d] = orig;
                }
            }
            NeighborKind::StrictlyAdjacent => {
                // All single-dim ±1 moves plus two-dim diagonal ±1 moves.
                out = self.neighbors(i, NeighborKind::Adjacent);
                for d1 in 0..self.dims {
                    for d2 in (d1 + 1)..self.dims {
                        for s1 in [-1i32, 1] {
                            for s2 in [-1i32, 1] {
                                let v1 = base[d1] as i32 + s1;
                                let v2 = base[d2] as i32 + s2;
                                if v1 < 0
                                    || v2 < 0
                                    || v1 >= self.params.params[d1].cardinality() as i32
                                    || v2 >= self.params.params[d2].cardinality() as i32
                                {
                                    continue;
                                }
                                probe[d1] = v1 as u16;
                                probe[d2] = v2 as u16;
                                if let Some(j) = self.index_of(&probe) {
                                    out.push(j);
                                }
                                probe[d1] = base[d1];
                                probe[d2] = base[d2];
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// A uniformly random valid Hamming neighbor, if any (fast path used in
    /// optimizer inner loops — avoids materializing the full neighbor list).
    pub fn random_neighbor(&self, i: u32, rng: &mut Rng, kind: NeighborKind) -> Option<u32> {
        // Try a few random single-dim perturbations before falling back to
        // the exhaustive list.
        let base = self.config(i).to_vec();
        let mut probe = base.clone();
        for _ in 0..8 {
            let d = rng.below(self.dims);
            let card = self.params.params[d].cardinality() as u16;
            if card <= 1 {
                continue;
            }
            let nv = match kind {
                NeighborKind::Hamming => {
                    let mut v = rng.below(card as usize) as u16;
                    if v == base[d] {
                        v = (v + 1) % card;
                    }
                    v
                }
                _ => {
                    let delta: i32 = if rng.chance(0.5) { 1 } else { -1 };
                    let v = base[d] as i32 + delta;
                    if v < 0 || v >= card as i32 {
                        continue;
                    }
                    v as u16
                }
            };
            probe[d] = nv;
            if let Some(j) = self.index_of(&probe) {
                return Some(j);
            }
            probe[d] = base[d];
        }
        let all = self.neighbors(i, kind);
        if all.is_empty() {
            None
        } else {
            Some(*rng.choose(&all))
        }
    }

    /// Repair an arbitrary value-index assignment to a valid configuration:
    /// exact if already valid, otherwise the valid configuration found by
    /// randomized coordinate snapping, falling back to a random valid config.
    pub fn repair(&self, cfg: &[u16], rng: &mut Rng) -> u32 {
        debug_assert_eq!(cfg.len(), self.dims);
        let mut probe: Vec<u16> = cfg
            .iter()
            .enumerate()
            .map(|(d, &vi)| vi.min(self.params.params[d].cardinality() as u16 - 1))
            .collect();
        if let Some(i) = self.index_of(&probe) {
            return i;
        }
        // Randomized coordinate repair: re-sample one dimension at a time.
        let mut order: Vec<usize> = (0..self.dims).collect();
        rng.shuffle(&mut order);
        for &d in &order {
            let orig = probe[d];
            let card = self.params.params[d].cardinality() as u16;
            // Nearest-first sweep over the dimension's values.
            for radius in 1..card {
                for cand in [orig.wrapping_sub(radius), orig + radius] {
                    if cand >= card {
                        continue;
                    }
                    probe[d] = cand;
                    if let Some(i) = self.index_of(&probe) {
                        return i;
                    }
                }
            }
            probe[d] = orig;
        }
        // Two-dimension randomized repair.
        for _ in 0..64 {
            let d1 = rng.below(self.dims);
            let d2 = rng.below(self.dims);
            let (o1, o2) = (probe[d1], probe[d2]);
            probe[d1] = rng.below(self.params.params[d1].cardinality()) as u16;
            probe[d2] = rng.below(self.params.params[d2].cardinality()) as u16;
            if let Some(i) = self.index_of(&probe) {
                return i;
            }
            probe[d1] = o1;
            probe[d2] = o2;
        }
        self.random_valid(rng)
    }

    /// Hamming distance between two valid configurations.
    pub fn hamming(&self, a: u32, b: u32) -> usize {
        self.config(a)
            .iter()
            .zip(self.config(b))
            .filter(|(x, y)| x != y)
            .count()
    }

    /// Iterate all valid configuration indices.
    pub fn iter_indices(&self) -> impl Iterator<Item = u32> + '_ {
        0..self.len() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::searchspace::param::{Param, ParamSet};

    fn toy() -> SearchSpace {
        let ps = ParamSet::new(vec![
            Param::ints("bx", &[1, 2, 4, 8, 16, 32]),
            Param::ints("by", &[8, 16, 32]),
            Param::ints("pad", &[0, 1]),
        ]);
        SearchSpace::build(
            "toy",
            ps,
            &["bx * by >= 32", "bx * by <= 256", "pad == 0 || bx > 1"],
        )
        .unwrap()
    }

    #[test]
    fn enumeration_matches_bruteforce() {
        let s = toy();
        // Brute force count.
        let mut n = 0;
        for bx in [1, 2, 4, 8, 16, 32] {
            for by in [8, 16, 32] {
                for pad in [0, 1] {
                    if bx * by >= 32 && bx * by <= 256 && (pad == 0 || bx > 1) {
                        n += 1;
                    }
                }
            }
        }
        assert_eq!(s.len(), n);
        assert_eq!(s.cartesian_size(), 36);
    }

    #[test]
    fn all_enumerated_satisfy_constraints() {
        let s = toy();
        for i in s.iter_indices() {
            assert!(s.satisfies_constraints(s.config(i)));
        }
    }

    #[test]
    fn index_roundtrip() {
        let s = toy();
        for i in s.iter_indices() {
            assert_eq!(s.index_of(s.config(i)), Some(i));
        }
        assert_eq!(s.index_of(&[0, 0, 1]), None); // bx=1,by=8 violates >=32
    }

    #[test]
    fn hamming_neighbors_differ_in_one_dim() {
        let s = toy();
        for i in s.iter_indices().take(10) {
            for j in s.neighbors(i, NeighborKind::Hamming) {
                assert_eq!(s.hamming(i, j), 1);
            }
        }
    }

    #[test]
    fn adjacent_subset_of_hamming() {
        let s = toy();
        for i in s.iter_indices() {
            let h: std::collections::HashSet<u32> =
                s.neighbors(i, NeighborKind::Hamming).into_iter().collect();
            for j in s.neighbors(i, NeighborKind::Adjacent) {
                assert!(h.contains(&j));
            }
        }
    }

    #[test]
    fn repair_returns_valid() {
        let s = toy();
        let mut rng = Rng::new(1);
        // (bx=1, by=8, pad=1) is invalid two ways.
        let i = s.repair(&[0, 0, 1], &mut rng);
        assert!(s.satisfies_constraints(s.config(i)));
        // Valid configs repair to themselves.
        let j = s.index_of(&[2, 1, 0]).unwrap();
        assert_eq!(s.repair(&[2, 1, 0], &mut rng), j);
    }

    #[test]
    fn random_neighbor_is_neighbor() {
        let s = toy();
        let mut rng = Rng::new(2);
        for i in s.iter_indices() {
            if let Some(j) = s.random_neighbor(i, &mut rng, NeighborKind::Hamming) {
                assert_eq!(s.hamming(i, j), 1);
            }
        }
    }

    #[test]
    fn strictly_adjacent_includes_diagonals() {
        let s = toy();
        let any_diag = s.iter_indices().any(|i| {
            s.neighbors(i, NeighborKind::StrictlyAdjacent)
                .iter()
                .any(|&j| s.hamming(i, j) == 2)
        });
        assert!(any_diag);
    }
}
