//! Search space construction and navigation.
//!
//! The space is constructed once per (kernel, GPU) pair by depth-first
//! enumeration of the Cartesian grid with *early constraint evaluation*: a
//! constraint is checked as soon as its deepest referenced dimension is
//! assigned, pruning entire subtrees (the approach of Willemsen et al. 2025a
//! which the paper builds on). Construction is parallel: the first
//! dimension's values are partitioned across workers and the per-value
//! arenas concatenated in value order, so enumeration order — and therefore
//! every config ordinal, seed derivation and golden result — is
//! byte-identical for any thread count. The DFS inner loop evaluates
//! *compiled* constraint programs ([`super::constraint::Program`]) over a
//! reusable scratch stack: no AST `Box` chasing, no per-node allocation.
//!
//! Valid configurations are stored in a flat arena (`u16` value indices)
//! plus a hash index for O(1) membership tests — the primitive behind the
//! neighbor operations that Kernel Tuner's `SearchSpace` object exposes to
//! generated optimizers:
//!   * `get_neighbors` (Hamming / adjacent / strictly-adjacent)
//!   * `get_random_sample`
//!   * `repair` of infeasible configurations
//!
//! Neighbor lookups come in two forms with one contract:
//!   * [`SearchSpace::neighbors`] enumerates a row on the fly (hash probes,
//!     owned `Vec`) — the reference implementation.
//!   * [`SearchSpace::neighbors_of`] returns a borrowed `&[u32]` row of a
//!     lazily-built CSR adjacency table (offsets + flat neighbor arena),
//!     one table per [`NeighborKind`] behind a `OnceLock`. The table is
//!     built once — in parallel, deterministically — and shared by every
//!     clone of the `Arc<SearchSpace>`, so all optimizers, seeds and jobs
//!     amortize it. Rows equal `neighbors()` element-for-element (same
//!     order); `rust/tests/integration_hotpath.rs` pins this.
//!
//! [`SearchSpace::random_neighbor`] indexes uniformly into the CSR row —
//! O(1) and bias-free for every kind (see its doc for how the old
//! rejection scheme skewed each kind's proposal distribution).

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};
use std::sync::OnceLock;

use super::constraint::Constraint;
use super::param::ParamSet;
use crate::persist::arena::Arena;
use crate::util::parallel;
use crate::util::rng::Rng;

/// FxHash-style hasher (no SipHash overhead on the hot membership path).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        const K: u64 = 0x517cc1b727220a95;
        for &b in bytes {
            self.hash = (self.hash.rotate_left(5) ^ b as u64).wrapping_mul(K);
        }
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        const K: u64 = 0x517cc1b727220a95;
        self.hash = (self.hash.rotate_left(5) ^ i as u64).wrapping_mul(K);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Neighborhood definitions, mirroring Kernel Tuner's options.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NeighborKind {
    /// Differ in exactly one dimension, any other value of that dimension.
    Hamming,
    /// Differ in exactly one dimension by ±1 value-index step.
    Adjacent,
    /// Differ in any number of dimensions, each by at most ±1 value-index;
    /// restricted here to single-dim ±1 plus diagonal two-dim moves kept
    /// tractable (Kernel Tuner's "strictly-adjacent" cube, sampled).
    StrictlyAdjacent,
}

impl NeighborKind {
    pub const ALL: [NeighborKind; 3] = [
        NeighborKind::Hamming,
        NeighborKind::Adjacent,
        NeighborKind::StrictlyAdjacent,
    ];

    /// Slot of this kind in the per-space CSR table array.
    #[inline]
    pub fn index(self) -> usize {
        match self {
            NeighborKind::Hamming => 0,
            NeighborKind::Adjacent => 1,
            NeighborKind::StrictlyAdjacent => 2,
        }
    }
}

/// CSR adjacency table for one [`NeighborKind`]: row `i` occupies
/// `data[offsets[i]..offsets[i+1]]`, in the exact order the on-the-fly
/// enumeration ([`SearchSpace::neighbors`]) produces. Offsets are `u64` so
/// the table serializes as fixed-width arenas (`crate::persist`), and both
/// arrays are [`Arena`]s so a loaded space can borrow them zero-copy from
/// an mmap'd store file.
struct NeighborGraph {
    offsets: Arena<u64>,
    data: Arena<u32>,
}

/// A fully constructed, constraint-filtered search space.
pub struct SearchSpace {
    pub name: String,
    pub params: ParamSet,
    pub constraints: Vec<Constraint>,
    /// Flat arena: config i occupies `[i*dims, (i+1)*dims)`.
    data: Arena<u16>,
    dims: usize,
    index: HashMap<Box<[u16]>, u32, FxBuildHasher>,
    /// Lazily-built CSR neighbor tables, one per [`NeighborKind`] (indexed
    /// by [`NeighborKind::index`]). Shared through the `Arc<SearchSpace>`,
    /// so the build cost is paid once per (space, kind) process-wide.
    graphs: [OnceLock<NeighborGraph>; 3],
}

impl SearchSpace {
    /// Enumerate all valid configurations (DFS with early pruning).
    pub fn build(name: &str, params: ParamSet, constraint_srcs: &[&str]) -> Result<SearchSpace, String> {
        let constraints: Vec<Constraint> = constraint_srcs
            .iter()
            .map(|s| Constraint::parse(s, &params).map_err(|e| format!("{}: {}", s, e)))
            .collect::<Result<_, _>>()?;
        Ok(Self::build_parsed(name, params, constraints))
    }

    /// [`Self::build_parsed_width`] at the process default width
    /// ([`crate::util::parallel::default_width`], i.e. the CLI's
    /// `--threads` or the machine size).
    pub fn build_parsed(name: &str, params: ParamSet, constraints: Vec<Constraint>) -> SearchSpace {
        Self::build_parsed_width(name, params, constraints, parallel::default_width())
    }

    /// Enumerate with an explicit worker count. The first dimension's
    /// values are partitioned across workers and the per-value arenas
    /// concatenated in value order, so the resulting space (arena bytes,
    /// config ordinals, index) is identical for every `width`.
    pub fn build_parsed_width(
        name: &str,
        params: ParamSet,
        constraints: Vec<Constraint>,
        width: usize,
    ) -> SearchSpace {
        let dims = params.dims();
        // Bucket constraints by the dimension at which they become checkable.
        let mut by_depth: Vec<Vec<&Constraint>> = vec![Vec::new(); dims];
        for c in &constraints {
            by_depth[c.max_dim].push(c);
        }

        // Recursive DFS over dimensions `d..dims`, evaluating each depth's
        // compiled constraint programs over the shared scratch stack.
        #[allow(clippy::too_many_arguments)]
        fn dfs(
            d: usize,
            dims: usize,
            params: &ParamSet,
            by_depth: &[Vec<&Constraint>],
            cfg: &mut [u16],
            vals: &mut [f64],
            stack: &mut Vec<f64>,
            data: &mut Vec<u16>,
        ) {
            if d == dims {
                data.extend_from_slice(cfg);
                return;
            }
            for vi in 0..params.params[d].cardinality() {
                cfg[d] = vi as u16;
                vals[d] = params.value_f64(d, vi as u16);
                if by_depth[d].iter().all(|c| c.program.holds(vals, stack)) {
                    dfs(d + 1, dims, params, by_depth, cfg, vals, stack, data);
                }
            }
        }

        let data: Vec<u16> = if dims == 0 {
            Vec::new()
        } else {
            // One chunk per first-dimension value: workers enumerate
            // disjoint subtrees; concatenation in value order reproduces
            // the serial DFS arena byte-for-byte.
            let card0 = params.params[0].cardinality();
            let chunks = parallel::map_chunks_width(card0, 1, width, |range| {
                let mut data = Vec::new();
                let mut cfg = vec![0u16; dims];
                let mut vals = vec![0.0f64; dims];
                let mut stack: Vec<f64> = Vec::new();
                for vi in range {
                    cfg[0] = vi as u16;
                    vals[0] = params.value_f64(0, vi as u16);
                    if by_depth[0].iter().all(|c| c.program.holds(&vals, &mut stack)) {
                        dfs(1, dims, &params, &by_depth, &mut cfg, &mut vals, &mut stack, &mut data);
                    }
                }
                data
            });
            let mut data = Vec::with_capacity(chunks.iter().map(Vec::len).sum());
            for chunk in &chunks {
                data.extend_from_slice(chunk);
            }
            data
        };

        let n = data.len() / dims.max(1);
        let mut index: HashMap<Box<[u16]>, u32, FxBuildHasher> =
            HashMap::with_capacity_and_hasher(n, FxBuildHasher::default());
        for i in 0..n {
            index.insert(data[i * dims..(i + 1) * dims].into(), i as u32);
        }

        SearchSpace {
            name: name.to_string(),
            params,
            constraints,
            data: data.into(),
            dims,
            index,
            graphs: Default::default(),
        }
    }

    /// Reassemble a space from deserialized arenas (`crate::persist`): the
    /// spec (name, params, constraints) comes from the current build — the
    /// store file only carries arena bytes, guarded by its fingerprint —
    /// and the hash index is rebuilt here (O(n), cheap next to
    /// enumeration). Pre-built CSR tables are optional per kind; missing
    /// kinds rebuild lazily as usual. Every structural property a config
    /// or neighbor lookup relies on is validated, so a file that passed
    /// the checksum but violates shape invariants is still rejected
    /// instead of panicking later.
    pub(crate) fn from_parts(
        name: &str,
        params: ParamSet,
        constraints: Vec<Constraint>,
        data: Arena<u16>,
        graphs: [Option<(Arena<u64>, Arena<u32>)>; 3],
    ) -> Result<SearchSpace, String> {
        let dims = params.dims();
        if dims == 0 {
            return Err("space has no dimensions".into());
        }
        if data.len() % dims != 0 {
            return Err(format!(
                "config arena length {} is not a multiple of dims {}",
                data.len(),
                dims
            ));
        }
        let n = data.len() / dims;
        for d in 0..dims {
            let card = params.params[d].cardinality() as u16;
            if (0..n).any(|i| data[i * dims + d] >= card) {
                return Err(format!("value index out of range in dimension {d}"));
            }
        }
        let mut index: HashMap<Box<[u16]>, u32, FxBuildHasher> =
            HashMap::with_capacity_and_hasher(n, FxBuildHasher::default());
        for i in 0..n {
            if index.insert(data[i * dims..(i + 1) * dims].into(), i as u32).is_some() {
                return Err(format!("duplicate configuration at index {i}"));
            }
        }
        let cells: [OnceLock<NeighborGraph>; 3] = Default::default();
        for (slot, g) in graphs.into_iter().enumerate() {
            let Some((offsets, rows)) = g else { continue };
            if offsets.len() != n + 1 || offsets.first() != Some(&0) {
                return Err(format!("CSR table {slot}: bad offsets shape"));
            }
            if offsets.windows(2).any(|w| w[0] > w[1]) {
                return Err(format!("CSR table {slot}: offsets not monotone"));
            }
            if *offsets.last().unwrap() != rows.len() as u64 {
                return Err(format!("CSR table {slot}: offsets do not cover the data"));
            }
            if rows.iter().any(|&j| j as usize >= n) {
                return Err(format!("CSR table {slot}: neighbor index out of range"));
            }
            let _ = cells[slot].set(NeighborGraph { offsets, data: rows });
        }
        Ok(SearchSpace {
            name: name.to_string(),
            params,
            constraints,
            data,
            dims,
            index,
            graphs: cells,
        })
    }

    /// The raw flat config arena (serialization seam for `crate::persist`).
    pub fn config_arena(&self) -> &[u16] {
        &self.data
    }

    /// Borrow the CSR table for `kind` as raw arenas (offsets, neighbor
    /// data), building it first if needed — the serialization seam for
    /// `crate::persist`, which dumps all three tables into the store file.
    pub fn graph_parts(&self, kind: NeighborKind) -> (&[u64], &[u32]) {
        let g = self.graphs[kind.index()].get_or_init(|| self.build_graph(kind));
        (&g.offsets, &g.data)
    }

    /// Whether the CSR table for `kind` has been built (or loaded) yet.
    pub fn has_graph(&self, kind: NeighborKind) -> bool {
        self.graphs[kind.index()].get().is_some()
    }

    /// Number of valid configurations ("constrained size", Table 1).
    #[inline]
    pub fn len(&self) -> usize {
        if self.dims == 0 {
            0
        } else {
            self.data.len() / self.dims
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn dims(&self) -> usize {
        self.dims
    }

    pub fn cartesian_size(&self) -> u64 {
        self.params.cartesian_size()
    }

    /// The configuration at a valid index.
    #[inline]
    pub fn config(&self, i: u32) -> &[u16] {
        let i = i as usize;
        &self.data[i * self.dims..(i + 1) * self.dims]
    }

    /// Index of a configuration if it is valid.
    #[inline]
    pub fn index_of(&self, cfg: &[u16]) -> Option<u32> {
        self.index.get(cfg).copied()
    }

    /// Whether value-index assignment `cfg` satisfies all constraints
    /// (independent of enumeration — used by property tests and repair).
    pub fn satisfies_constraints(&self, cfg: &[u16]) -> bool {
        let mut vals = Vec::with_capacity(self.dims);
        let mut stack = Vec::new();
        self.satisfies_constraints_scratch(cfg, &mut vals, &mut stack)
    }

    /// Allocation-free twin of [`Self::satisfies_constraints`]: `vals` and
    /// `stack` are caller-owned scratch buffers, resized/reused in place.
    pub fn satisfies_constraints_scratch(
        &self,
        cfg: &[u16],
        vals: &mut Vec<f64>,
        stack: &mut Vec<f64>,
    ) -> bool {
        vals.clear();
        vals.extend(
            cfg.iter()
                .enumerate()
                .map(|(d, &vi)| self.params.value_f64(d, vi)),
        );
        self.constraints.iter().all(|c| c.program.holds(vals, stack))
    }

    /// Numeric parameter values of a valid config, by dimension.
    pub fn values_f64(&self, i: u32) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.dims);
        self.values_f64_into(i, &mut out);
        out
    }

    /// Allocation-free twin of [`Self::values_f64`]: fills a caller-owned
    /// buffer (cleared first) with the config's numeric values.
    pub fn values_f64_into(&self, i: u32, out: &mut Vec<f64>) {
        out.clear();
        out.extend(
            self.config(i)
                .iter()
                .enumerate()
                .map(|(d, &vi)| self.params.value_f64(d, vi)),
        );
    }

    /// A uniformly random valid configuration index.
    #[inline]
    pub fn random_valid(&self, rng: &mut Rng) -> u32 {
        rng.below(self.len()) as u32
    }

    /// Distinct random valid configuration indices (initial populations).
    pub fn random_sample(&self, rng: &mut Rng, k: usize) -> Vec<u32> {
        rng.sample_indices(self.len(), k)
            .into_iter()
            .map(|i| i as u32)
            .collect()
    }

    /// Append the valid neighbors of `i` under `kind` to `out`, in the
    /// canonical enumeration order (the CSR row order). `probe` is a
    /// dims-sized scratch buffer.
    fn push_neighbors(&self, i: u32, kind: NeighborKind, probe: &mut [u16], out: &mut Vec<u32>) {
        debug_assert_eq!(probe.len(), self.dims);
        probe.copy_from_slice(self.config(i));
        let base = self.config(i);
        match kind {
            NeighborKind::Hamming => {
                for d in 0..self.dims {
                    let orig = base[d];
                    for vi in 0..self.params.params[d].cardinality() as u16 {
                        if vi == orig {
                            continue;
                        }
                        probe[d] = vi;
                        if let Some(j) = self.index_of(probe) {
                            out.push(j);
                        }
                    }
                    probe[d] = orig;
                }
            }
            NeighborKind::Adjacent => {
                for d in 0..self.dims {
                    let orig = base[d];
                    let card = self.params.params[d].cardinality() as u16;
                    if orig > 0 {
                        probe[d] = orig - 1;
                        if let Some(j) = self.index_of(probe) {
                            out.push(j);
                        }
                    }
                    if orig + 1 < card {
                        probe[d] = orig + 1;
                        if let Some(j) = self.index_of(probe) {
                            out.push(j);
                        }
                    }
                    probe[d] = orig;
                }
            }
            NeighborKind::StrictlyAdjacent => {
                // All single-dim ±1 moves plus two-dim diagonal ±1 moves.
                self.push_neighbors(i, NeighborKind::Adjacent, probe, out);
                for d1 in 0..self.dims {
                    for d2 in (d1 + 1)..self.dims {
                        for s1 in [-1i32, 1] {
                            for s2 in [-1i32, 1] {
                                let v1 = base[d1] as i32 + s1;
                                let v2 = base[d2] as i32 + s2;
                                if v1 < 0
                                    || v2 < 0
                                    || v1 >= self.params.params[d1].cardinality() as i32
                                    || v2 >= self.params.params[d2].cardinality() as i32
                                {
                                    continue;
                                }
                                probe[d1] = v1 as u16;
                                probe[d2] = v2 as u16;
                                if let Some(j) = self.index_of(probe) {
                                    out.push(j);
                                }
                                probe[d1] = base[d1];
                                probe[d2] = base[d2];
                            }
                        }
                    }
                }
            }
        }
    }

    /// Valid neighbors of configuration `i` under `kind`, enumerated on
    /// the fly into an owned `Vec` — the reference implementation. Hot
    /// paths use [`Self::neighbors_of`], whose rows match this output
    /// element-for-element.
    pub fn neighbors(&self, i: u32, kind: NeighborKind) -> Vec<u32> {
        let mut out = Vec::new();
        let mut probe = vec![0u16; self.dims];
        self.push_neighbors(i, kind, &mut probe, &mut out);
        out
    }

    /// Build the CSR table for one kind: chunked parallel row construction
    /// (rows are independent), concatenated in index order — the table is
    /// identical for any worker count or build interleaving.
    fn build_graph(&self, kind: NeighborKind) -> NeighborGraph {
        let n = self.len();
        let chunks = parallel::map_chunks(n, 2048, |range| {
            let mut lens: Vec<u32> = Vec::with_capacity(range.len());
            let mut rows: Vec<u32> = Vec::new();
            let mut probe = vec![0u16; self.dims];
            for i in range {
                let before = rows.len();
                self.push_neighbors(i as u32, kind, &mut probe, &mut rows);
                lens.push((rows.len() - before) as u32);
            }
            (lens, rows)
        });
        let total: usize = chunks.iter().map(|(_, rows)| rows.len()).sum();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut data = Vec::with_capacity(total);
        offsets.push(0u64);
        for (lens, rows) in &chunks {
            for &l in lens {
                offsets.push(offsets.last().unwrap() + l as u64);
            }
            data.extend_from_slice(rows);
        }
        NeighborGraph {
            offsets: offsets.into(),
            data: data.into(),
        }
    }

    /// Valid neighbors of `i` under `kind` as a borrowed CSR row — the
    /// allocation-free fast path. The first call per (space, kind) builds
    /// the table (in parallel, deterministically) behind a `OnceLock`;
    /// every later call is two offset loads and a slice. Row contents and
    /// order equal [`Self::neighbors`].
    pub fn neighbors_of(&self, i: u32, kind: NeighborKind) -> &[u32] {
        let g = self.graphs[kind.index()].get_or_init(|| self.build_graph(kind));
        let i = i as usize;
        &g.data[g.offsets[i] as usize..g.offsets[i + 1] as usize]
    }

    /// A uniformly random valid neighbor of `i` under `kind`, if any: one
    /// RNG draw indexing the CSR row, every neighbor exactly equally
    /// likely. This deliberately changed the proposal distribution of the
    /// pre-CSR rejection scheme for **all** kinds: Hamming remapped draws
    /// colliding with the base value to `(v+1) % card` (that neighbor was
    /// twice as likely); Adjacent/StrictlyAdjacent drew a uniform
    /// dimension then ±1 (dimension-weighted, and diagonal
    /// strictly-adjacent moves were reachable almost only through the
    /// rare exhaustive fallback — they now carry full weight).
    pub fn random_neighbor(&self, i: u32, rng: &mut Rng, kind: NeighborKind) -> Option<u32> {
        let row = self.neighbors_of(i, kind);
        if row.is_empty() {
            None
        } else {
            Some(row[rng.below(row.len())])
        }
    }

    /// Repair an arbitrary value-index assignment to a valid configuration:
    /// exact if already valid, otherwise the valid configuration found by
    /// randomized coordinate snapping, falling back to a random valid config.
    pub fn repair(&self, cfg: &[u16], rng: &mut Rng) -> u32 {
        debug_assert_eq!(cfg.len(), self.dims);
        let mut probe: Vec<u16> = cfg
            .iter()
            .enumerate()
            .map(|(d, &vi)| vi.min(self.params.params[d].cardinality() as u16 - 1))
            .collect();
        if let Some(i) = self.index_of(&probe) {
            return i;
        }
        // Randomized coordinate repair: re-sample one dimension at a time.
        let mut order: Vec<usize> = (0..self.dims).collect();
        rng.shuffle(&mut order);
        for &d in &order {
            let orig = probe[d];
            let card = self.params.params[d].cardinality() as u16;
            // Nearest-first sweep over the dimension's values.
            for radius in 1..card {
                for cand in [orig.wrapping_sub(radius), orig + radius] {
                    if cand >= card {
                        continue;
                    }
                    probe[d] = cand;
                    if let Some(i) = self.index_of(&probe) {
                        return i;
                    }
                }
            }
            probe[d] = orig;
        }
        // Two-dimension randomized repair.
        for _ in 0..64 {
            let d1 = rng.below(self.dims);
            let d2 = rng.below(self.dims);
            let (o1, o2) = (probe[d1], probe[d2]);
            probe[d1] = rng.below(self.params.params[d1].cardinality()) as u16;
            probe[d2] = rng.below(self.params.params[d2].cardinality()) as u16;
            if let Some(i) = self.index_of(&probe) {
                return i;
            }
            probe[d1] = o1;
            probe[d2] = o2;
        }
        self.random_valid(rng)
    }

    /// Hamming distance between two valid configurations.
    pub fn hamming(&self, a: u32, b: u32) -> usize {
        self.config(a)
            .iter()
            .zip(self.config(b))
            .filter(|(x, y)| x != y)
            .count()
    }

    /// Iterate all valid configuration indices.
    pub fn iter_indices(&self) -> impl Iterator<Item = u32> + '_ {
        0..self.len() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::searchspace::param::{Param, ParamSet};

    fn toy() -> SearchSpace {
        let ps = ParamSet::new(vec![
            Param::ints("bx", &[1, 2, 4, 8, 16, 32]),
            Param::ints("by", &[8, 16, 32]),
            Param::ints("pad", &[0, 1]),
        ]);
        SearchSpace::build(
            "toy",
            ps,
            &["bx * by >= 32", "bx * by <= 256", "pad == 0 || bx > 1"],
        )
        .unwrap()
    }

    #[test]
    fn enumeration_matches_bruteforce() {
        let s = toy();
        // Brute force count.
        let mut n = 0;
        for bx in [1, 2, 4, 8, 16, 32] {
            for by in [8, 16, 32] {
                for pad in [0, 1] {
                    if bx * by >= 32 && bx * by <= 256 && (pad == 0 || bx > 1) {
                        n += 1;
                    }
                }
            }
        }
        assert_eq!(s.len(), n);
        assert_eq!(s.cartesian_size(), 36);
    }

    #[test]
    fn parallel_build_identical_to_serial() {
        let serial = {
            let s = toy();
            (s.params.clone(), s.constraints.clone(), s)
        };
        for width in [2, 4, 8] {
            let p =
                SearchSpace::build_parsed_width("toy", serial.0.clone(), serial.1.clone(), width);
            assert_eq!(p.len(), serial.2.len());
            for i in p.iter_indices() {
                assert_eq!(p.config(i), serial.2.config(i), "width {}", width);
            }
        }
    }

    #[test]
    fn all_enumerated_satisfy_constraints() {
        let s = toy();
        for i in s.iter_indices() {
            assert!(s.satisfies_constraints(s.config(i)));
        }
    }

    #[test]
    fn scratch_constraint_check_matches_allocating() {
        let s = toy();
        let mut vals = Vec::new();
        let mut stack = Vec::new();
        for bx in 0..6u16 {
            for by in 0..3u16 {
                for pad in 0..2u16 {
                    let cfg = [bx, by, pad];
                    assert_eq!(
                        s.satisfies_constraints(&cfg),
                        s.satisfies_constraints_scratch(&cfg, &mut vals, &mut stack)
                    );
                }
            }
        }
    }

    #[test]
    fn values_into_matches_allocating() {
        let s = toy();
        let mut buf = Vec::new();
        for i in s.iter_indices() {
            s.values_f64_into(i, &mut buf);
            assert_eq!(buf, s.values_f64(i));
        }
    }

    #[test]
    fn index_roundtrip() {
        let s = toy();
        for i in s.iter_indices() {
            assert_eq!(s.index_of(s.config(i)), Some(i));
        }
        assert_eq!(s.index_of(&[0, 0, 1]), None); // bx=1,by=8 violates >=32
    }

    #[test]
    fn hamming_neighbors_differ_in_one_dim() {
        let s = toy();
        for i in s.iter_indices().take(10) {
            for j in s.neighbors(i, NeighborKind::Hamming) {
                assert_eq!(s.hamming(i, j), 1);
            }
        }
    }

    #[test]
    fn csr_rows_equal_reference_enumeration() {
        let s = toy();
        for kind in NeighborKind::ALL {
            for i in s.iter_indices() {
                assert_eq!(
                    s.neighbors_of(i, kind),
                    s.neighbors(i, kind).as_slice(),
                    "kind {:?} config {}",
                    kind,
                    i
                );
            }
        }
    }

    #[test]
    fn adjacent_subset_of_hamming() {
        let s = toy();
        for i in s.iter_indices() {
            let h: std::collections::HashSet<u32> =
                s.neighbors(i, NeighborKind::Hamming).into_iter().collect();
            for j in s.neighbors(i, NeighborKind::Adjacent) {
                assert!(h.contains(&j));
            }
        }
    }

    #[test]
    fn repair_returns_valid() {
        let s = toy();
        let mut rng = Rng::new(1);
        // (bx=1, by=8, pad=1) is invalid two ways.
        let i = s.repair(&[0, 0, 1], &mut rng);
        assert!(s.satisfies_constraints(s.config(i)));
        // Valid configs repair to themselves.
        let j = s.index_of(&[2, 1, 0]).unwrap();
        assert_eq!(s.repair(&[2, 1, 0], &mut rng), j);
    }

    #[test]
    fn random_neighbor_is_neighbor() {
        let s = toy();
        let mut rng = Rng::new(2);
        for i in s.iter_indices() {
            if let Some(j) = s.random_neighbor(i, &mut rng, NeighborKind::Hamming) {
                assert_eq!(s.hamming(i, j), 1);
            }
        }
    }

    #[test]
    fn random_neighbor_is_uniform_over_row() {
        // The pre-CSR sampler remapped draws that collided with the base
        // value to `(v+1) % card`, making that neighbor twice as likely.
        // With the CSR row the distribution must be flat.
        let s = toy();
        let i = s
            .iter_indices()
            .max_by_key(|&i| s.neighbors(i, NeighborKind::Hamming).len())
            .unwrap();
        let row = s.neighbors(i, NeighborKind::Hamming);
        assert!(row.len() >= 3, "toy space should have a multi-neighbor row");
        let mut counts: std::collections::HashMap<u32, u64> = HashMap::new();
        let mut rng = Rng::new(7);
        let draws = 30_000u64;
        for _ in 0..draws {
            let j = s.random_neighbor(i, &mut rng, NeighborKind::Hamming).unwrap();
            *counts.entry(j).or_insert(0) += 1;
        }
        let expected = draws as f64 / row.len() as f64;
        for &j in &row {
            let c = *counts.get(&j).unwrap_or(&0) as f64;
            assert!(
                (c - expected).abs() < 0.2 * expected,
                "neighbor {} drawn {} times, expected ~{}",
                j,
                c,
                expected
            );
        }
        assert_eq!(counts.len(), row.len(), "all neighbors reachable");
    }

    #[test]
    fn strictly_adjacent_includes_diagonals() {
        let s = toy();
        let any_diag = s.iter_indices().any(|i| {
            s.neighbors(i, NeighborKind::StrictlyAdjacent)
                .iter()
                .any(|&j| s.hamming(i, j) == 2)
        });
        assert!(any_diag);
    }
}
