//! Constraint (restriction) expression engine.
//!
//! Kernel Tuner expresses search-space restrictions as Python expressions
//! over parameter names ("block_size_x*block_size_y >= 32"). We implement
//! the same surface as a parsed infix expression language evaluated over a
//! configuration's numeric values — shared by space construction (where
//! early evaluation prunes the DFS) and by repair.
//!
//! Parsing produces two evaluators with identical semantics: the [`Expr`]
//! AST (kept for introspection and as the reference implementation) and a
//! flat postfix [`Program`] compiled from it by [`compile`]. The program
//! is a `Vec` of opcodes evaluated over a caller-provided scratch stack —
//! no `Box` chasing, no per-evaluation allocation — and is what the DFS
//! enumeration inner loop and the repair hot paths execute.
//! `program_matches_ast` pins the equivalence.
//!
//! Grammar (precedence climbing):
//!   or:      and ('||' and)*            also accepts `or`
//!   and:     cmp ('&&' cmp)*            also accepts `and`
//!   cmp:     sum (('=='|'!='|'<='|'>='|'<'|'>') sum)?
//!   sum:     prod (('+'|'-') prod)*
//!   prod:    unary (('*'|'/'|'%') unary)*
//!   unary:   '-' unary | '!' unary | atom
//!   atom:    number | ident | '(' or ')' | 'min(' or ',' or ')' | 'max(...)'
//!
//! Booleans are 0.0 / 1.0; `/` is float division and `//` integer division.

use std::fmt;

use super::param::ParamSet;

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    Add,
    Sub,
    Mul,
    Div,
    IntDiv,
    Mod,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

/// Expression AST; parameters are resolved to dimension indices at parse
/// time so evaluation is allocation-free.
#[derive(Debug, Clone)]
pub enum Expr {
    Num(f64),
    Param(usize),
    Bin(Op, Box<Expr>, Box<Expr>),
    Neg(Box<Expr>),
    Not(Box<Expr>),
    Min(Box<Expr>, Box<Expr>),
    Max(Box<Expr>, Box<Expr>),
}

impl Op {
    /// Apply the operator to two scalars. `And`/`Or` are evaluated eagerly
    /// (both operands computed); since expression evaluation is pure this
    /// is observationally identical to the AST's short-circuiting.
    #[inline]
    pub fn apply(self, x: f64, y: f64) -> f64 {
        match self {
            Op::Add => x + y,
            Op::Sub => x - y,
            Op::Mul => x * y,
            Op::Div => x / y,
            Op::IntDiv => (x / y).floor(),
            Op::Mod => {
                // Python-style modulo on the integer grid.
                let r = x % y;
                if r != 0.0 && (r < 0.0) != (y < 0.0) {
                    r + y
                } else {
                    r
                }
            }
            Op::Eq => (x == y) as u8 as f64,
            Op::Ne => (x != y) as u8 as f64,
            Op::Lt => (x < y) as u8 as f64,
            Op::Le => (x <= y) as u8 as f64,
            Op::Gt => (x > y) as u8 as f64,
            Op::Ge => (x >= y) as u8 as f64,
            Op::And => (x != 0.0 && y != 0.0) as u8 as f64,
            Op::Or => (x != 0.0 || y != 0.0) as u8 as f64,
        }
    }
}

/// One opcode of a compiled constraint [`Program`] (flat postfix form).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpCode {
    /// Push a literal.
    Push(f64),
    /// Push the value of dimension `d`.
    Load(u16),
    /// Pop two operands, push `Op::apply`.
    Bin(Op),
    /// Negate the top of stack.
    Neg,
    /// Logical-not the top of stack.
    Not,
    /// Pop two operands, push the minimum.
    Min,
    /// Pop two operands, push the maximum.
    Max,
}

/// A constraint compiled to flat postfix form: a linear opcode scan over a
/// reusable operand stack, with no heap pointers to chase. Produced by
/// [`compile`]; semantically identical to evaluating the source [`Expr`].
#[derive(Debug, Clone)]
pub struct Program {
    code: Vec<OpCode>,
    /// Peak operand-stack depth — callers preallocate scratch to this.
    pub max_depth: usize,
}

impl Program {
    /// Evaluate over per-dimension values using `stack` as scratch. The
    /// stack is cleared on entry; no allocation occurs once its capacity
    /// has reached [`Self::max_depth`].
    pub fn eval(&self, values: &[f64], stack: &mut Vec<f64>) -> f64 {
        stack.clear();
        stack.reserve(self.max_depth);
        for op in &self.code {
            match *op {
                OpCode::Push(x) => stack.push(x),
                OpCode::Load(d) => stack.push(values[d as usize]),
                OpCode::Neg => {
                    let a = stack.last_mut().expect("neg on empty stack");
                    *a = -*a;
                }
                OpCode::Not => {
                    let a = stack.last_mut().expect("not on empty stack");
                    *a = (*a == 0.0) as u8 as f64;
                }
                OpCode::Min => {
                    let b = stack.pop().expect("min on empty stack");
                    let a = stack.last_mut().expect("min on unary stack");
                    *a = a.min(b);
                }
                OpCode::Max => {
                    let b = stack.pop().expect("max on empty stack");
                    let a = stack.last_mut().expect("max on unary stack");
                    *a = a.max(b);
                }
                OpCode::Bin(op) => {
                    let b = stack.pop().expect("bin on empty stack");
                    let a = stack.last_mut().expect("bin on unary stack");
                    *a = op.apply(*a, b);
                }
            }
        }
        stack.pop().expect("program left an empty stack")
    }

    /// True when the configuration satisfies the compiled constraint.
    #[inline]
    pub fn holds(&self, values: &[f64], stack: &mut Vec<f64>) -> bool {
        self.eval(values, stack) != 0.0
    }

    /// Number of opcodes (diagnostics).
    pub fn len(&self) -> usize {
        self.code.len()
    }

    pub fn is_empty(&self) -> bool {
        self.code.is_empty()
    }
}

/// Compile an [`Expr`] to its postfix [`Program`] (postorder emission).
pub fn compile(expr: &Expr) -> Program {
    fn emit(e: &Expr, code: &mut Vec<OpCode>) {
        match e {
            Expr::Num(x) => code.push(OpCode::Push(*x)),
            Expr::Param(d) => {
                debug_assert!(*d <= u16::MAX as usize, "dimension index fits u16");
                code.push(OpCode::Load(*d as u16));
            }
            Expr::Neg(a) => {
                emit(a, code);
                code.push(OpCode::Neg);
            }
            Expr::Not(a) => {
                emit(a, code);
                code.push(OpCode::Not);
            }
            Expr::Min(a, b) => {
                emit(a, code);
                emit(b, code);
                code.push(OpCode::Min);
            }
            Expr::Max(a, b) => {
                emit(a, code);
                emit(b, code);
                code.push(OpCode::Max);
            }
            Expr::Bin(op, a, b) => {
                emit(a, code);
                emit(b, code);
                code.push(OpCode::Bin(*op));
            }
        }
    }
    let mut code = Vec::new();
    emit(expr, &mut code);
    // Simulate to find the peak operand-stack depth.
    let (mut depth, mut max_depth) = (0usize, 0usize);
    for op in &code {
        match op {
            OpCode::Push(_) | OpCode::Load(_) => {
                depth += 1;
                max_depth = max_depth.max(depth);
            }
            OpCode::Neg | OpCode::Not => {}
            OpCode::Bin(_) | OpCode::Min | OpCode::Max => depth -= 1,
        }
    }
    debug_assert_eq!(depth, 1, "program must leave exactly one result");
    Program { code, max_depth }
}

/// A named constraint with its source text, the highest dimension it
/// references (for early evaluation during DFS enumeration), and its
/// compiled postfix program (the hot-path evaluator).
#[derive(Debug, Clone)]
pub struct Constraint {
    pub source: String,
    pub expr: Expr,
    pub max_dim: usize,
    pub program: Program,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub message: String,
    pub position: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at {}: {}", self.position, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Expr {
    /// Evaluate over per-dimension numeric values.
    pub fn eval(&self, values: &[f64]) -> f64 {
        match self {
            Expr::Num(x) => *x,
            Expr::Param(d) => values[*d],
            Expr::Neg(e) => -e.eval(values),
            Expr::Not(e) => {
                if e.eval(values) != 0.0 {
                    0.0
                } else {
                    1.0
                }
            }
            Expr::Min(a, b) => a.eval(values).min(b.eval(values)),
            Expr::Max(a, b) => a.eval(values).max(b.eval(values)),
            Expr::Bin(op, a, b) => {
                let x = a.eval(values);
                // Short-circuit the logical ops (pure expressions, so this
                // is observationally identical to `Op::apply`'s eager form).
                match op {
                    Op::And => {
                        return if x != 0.0 && b.eval(values) != 0.0 { 1.0 } else { 0.0 }
                    }
                    Op::Or => {
                        return if x != 0.0 || b.eval(values) != 0.0 { 1.0 } else { 0.0 }
                    }
                    _ => {}
                }
                op.apply(x, b.eval(values))
            }
        }
    }

    fn max_dim(&self) -> usize {
        match self {
            Expr::Num(_) => 0,
            Expr::Param(d) => *d,
            Expr::Neg(e) | Expr::Not(e) => e.max_dim(),
            Expr::Bin(_, a, b) | Expr::Min(a, b) | Expr::Max(a, b) => {
                a.max_dim().max(b.max_dim())
            }
        }
    }
}

impl Constraint {
    /// Parse `source` against the parameter set (names become dims).
    pub fn parse(source: &str, params: &ParamSet) -> Result<Constraint, ParseError> {
        let mut p = Parser {
            src: source.as_bytes(),
            pos: 0,
            params,
        };
        let expr = p.parse_or()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(ParseError {
                message: format!("trailing input: '{}'", &source[p.pos..]),
                position: p.pos,
            });
        }
        let max_dim = expr.max_dim();
        let program = compile(&expr);
        Ok(Constraint {
            source: source.to_string(),
            expr,
            max_dim,
            program,
        })
    }

    /// True when the configuration satisfies the constraint (AST walk; the
    /// hot paths use [`Self::holds_scratch`] over the compiled program).
    #[inline]
    pub fn holds(&self, values: &[f64]) -> bool {
        self.expr.eval(values) != 0.0
    }

    /// Allocation-free twin of [`Self::holds`]: evaluates the compiled
    /// program over a caller-owned scratch stack.
    #[inline]
    pub fn holds_scratch(&self, values: &[f64], stack: &mut Vec<f64>) -> bool {
        self.program.holds(values, stack)
    }
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
    params: &'a ParamSet,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.src.len() && self.src[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> u8 {
        self.skip_ws();
        if self.pos < self.src.len() {
            self.src[self.pos]
        } else {
            0
        }
    }

    fn eat(&mut self, tok: &str) -> bool {
        self.skip_ws();
        if self.src[self.pos..].starts_with(tok.as_bytes()) {
            // Word tokens must not be glued to identifier chars.
            if tok.chars().all(|c| c.is_ascii_alphabetic()) {
                let after = self.pos + tok.len();
                if after < self.src.len()
                    && (self.src[after].is_ascii_alphanumeric() || self.src[after] == b'_')
                {
                    return false;
                }
            }
            self.pos += tok.len();
            true
        } else {
            false
        }
    }

    fn err(&self, message: impl Into<String>) -> ParseError {
        ParseError {
            message: message.into(),
            position: self.pos,
        }
    }

    fn parse_or(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_and()?;
        loop {
            if self.eat("||") || self.eat("or") {
                let rhs = self.parse_and()?;
                lhs = Expr::Bin(Op::Or, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn parse_and(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_cmp()?;
        loop {
            if self.eat("&&") || self.eat("and") {
                let rhs = self.parse_cmp()?;
                lhs = Expr::Bin(Op::And, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn parse_cmp(&mut self) -> Result<Expr, ParseError> {
        let lhs = self.parse_sum()?;
        let op = if self.eat("==") {
            Op::Eq
        } else if self.eat("!=") {
            Op::Ne
        } else if self.eat("<=") {
            Op::Le
        } else if self.eat(">=") {
            Op::Ge
        } else if self.eat("<") {
            Op::Lt
        } else if self.eat(">") {
            Op::Gt
        } else {
            return Ok(lhs);
        };
        let rhs = self.parse_sum()?;
        Ok(Expr::Bin(op, Box::new(lhs), Box::new(rhs)))
    }

    fn parse_sum(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_prod()?;
        loop {
            if self.eat("+") {
                let rhs = self.parse_prod()?;
                lhs = Expr::Bin(Op::Add, Box::new(lhs), Box::new(rhs));
            } else if self.peek() == b'-' && !self.src[self.pos..].starts_with(b"->") {
                self.pos += 1;
                let rhs = self.parse_prod()?;
                lhs = Expr::Bin(Op::Sub, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn parse_prod(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_unary()?;
        loop {
            if self.eat("//") {
                let rhs = self.parse_unary()?;
                lhs = Expr::Bin(Op::IntDiv, Box::new(lhs), Box::new(rhs));
            } else if self.eat("*") {
                let rhs = self.parse_unary()?;
                lhs = Expr::Bin(Op::Mul, Box::new(lhs), Box::new(rhs));
            } else if self.eat("/") {
                let rhs = self.parse_unary()?;
                lhs = Expr::Bin(Op::Div, Box::new(lhs), Box::new(rhs));
            } else if self.eat("%") {
                let rhs = self.parse_unary()?;
                lhs = Expr::Bin(Op::Mod, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn parse_unary(&mut self) -> Result<Expr, ParseError> {
        if self.eat("-") {
            return Ok(Expr::Neg(Box::new(self.parse_unary()?)));
        }
        if self.eat("!") {
            return Ok(Expr::Not(Box::new(self.parse_unary()?)));
        }
        self.parse_atom()
    }

    fn parse_atom(&mut self) -> Result<Expr, ParseError> {
        self.skip_ws();
        if self.pos >= self.src.len() {
            return Err(self.err("unexpected end of input"));
        }
        let c = self.src[self.pos];
        if c == b'(' {
            self.pos += 1;
            let e = self.parse_or()?;
            if !self.eat(")") {
                return Err(self.err("expected ')'"));
            }
            return Ok(e);
        }
        if c.is_ascii_digit() || c == b'.' {
            let start = self.pos;
            while self.pos < self.src.len()
                && (self.src[self.pos].is_ascii_digit() || self.src[self.pos] == b'.')
            {
                self.pos += 1;
            }
            let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
            return text
                .parse::<f64>()
                .map(Expr::Num)
                .map_err(|e| self.err(format!("bad number '{}': {}", text, e)));
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let start = self.pos;
            while self.pos < self.src.len()
                && (self.src[self.pos].is_ascii_alphanumeric() || self.src[self.pos] == b'_')
            {
                self.pos += 1;
            }
            let name = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
            if name == "min" || name == "max" {
                if !self.eat("(") {
                    return Err(self.err(format!("expected '(' after {}", name)));
                }
                let a = self.parse_or()?;
                if !self.eat(",") {
                    return Err(self.err("expected ','"));
                }
                let b = self.parse_or()?;
                if !self.eat(")") {
                    return Err(self.err("expected ')'"));
                }
                return Ok(if name == "min" {
                    Expr::Min(Box::new(a), Box::new(b))
                } else {
                    Expr::Max(Box::new(a), Box::new(b))
                });
            }
            return match self.params.index_of(name) {
                Some(d) => Ok(Expr::Param(d)),
                None => Err(self.err(format!("unknown parameter '{}'", name))),
            };
        }
        Err(self.err(format!("unexpected character '{}'", c as char)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::searchspace::param::Param;

    fn ps() -> ParamSet {
        ParamSet::new(vec![
            Param::ints("bx", &[1, 2, 4, 8]),
            Param::ints("by", &[8, 16]),
            Param::ints("u", &[0, 1, 2, 4]),
        ])
    }

    fn eval(src: &str, vals: &[f64]) -> f64 {
        Constraint::parse(src, &ps()).unwrap().expr.eval(vals)
    }

    #[test]
    fn arithmetic_and_comparison() {
        assert_eq!(eval("bx * by >= 32", &[4.0, 16.0, 0.0]), 1.0);
        assert_eq!(eval("bx * by >= 32", &[2.0, 8.0, 0.0]), 0.0);
        assert_eq!(eval("bx + by - 2", &[4.0, 16.0, 0.0]), 18.0);
        assert_eq!(eval("by // bx", &[4.0, 16.0, 0.0]), 4.0);
        assert_eq!(eval("by % 3", &[0.0, 16.0, 0.0]), 1.0);
    }

    #[test]
    fn logical_ops_and_precedence() {
        assert_eq!(eval("bx == 2 || bx == 4", &[4.0, 0.0, 0.0]), 1.0);
        assert_eq!(eval("bx == 2 && by == 8", &[2.0, 8.0, 0.0]), 1.0);
        assert_eq!(eval("bx == 2 and by == 8 or u == 4", &[1.0, 1.0, 4.0]), 1.0);
        // * binds tighter than ==, == tighter than &&.
        assert_eq!(eval("bx * by == 32 && u != 1", &[4.0, 8.0, 0.0]), 1.0);
    }

    #[test]
    fn unary_and_funcs() {
        assert_eq!(eval("!(bx == 2)", &[2.0, 0.0, 0.0]), 0.0);
        assert_eq!(eval("-bx + 5", &[2.0, 0.0, 0.0]), 3.0);
        assert_eq!(eval("min(bx, by)", &[4.0, 16.0, 0.0]), 4.0);
        assert_eq!(eval("max(bx, by)", &[4.0, 16.0, 0.0]), 16.0);
    }

    #[test]
    fn modulo_divisibility_pattern() {
        // The CLBlast-style pattern: "MWG % (MDIMC * VWM) == 0".
        assert_eq!(eval("by % (bx * 2) == 0", &[4.0, 16.0, 0.0]), 1.0);
        assert_eq!(eval("by % (bx * 2) == 0", &[4.0, 8.0, 0.0]), 1.0);
        assert_eq!(eval("by % (bx * 3) == 0", &[4.0, 16.0, 0.0]), 0.0);
    }

    #[test]
    fn max_dim_tracks_last_param() {
        let c = Constraint::parse("bx * by >= 32", &ps()).unwrap();
        assert_eq!(c.max_dim, 1);
        let c = Constraint::parse("u == 0 || bx > 1", &ps()).unwrap();
        assert_eq!(c.max_dim, 2);
    }

    #[test]
    fn parse_errors() {
        assert!(Constraint::parse("unknown_param == 1", &ps()).is_err());
        assert!(Constraint::parse("bx ==", &ps()).is_err());
        assert!(Constraint::parse("bx == 1 extra", &ps()).is_err());
        assert!(Constraint::parse("(bx == 1", &ps()).is_err());
    }

    #[test]
    fn program_matches_ast() {
        // Every surface construct, compared compiled-vs-AST over a value
        // grid (including zeros that exercise And/Or truth tables).
        let srcs = [
            "bx * by >= 32",
            "bx + by - 2",
            "by // bx",
            "by % 3",
            "bx == 2 || bx == 4",
            "bx == 2 && by == 8",
            "bx == 2 and by == 8 or u == 4",
            "bx * by == 32 && u != 1",
            "!(bx == 2)",
            "-bx + 5",
            "min(bx, by) + max(u, 2)",
            "by % (bx * 2) == 0",
            "u == 0 || bx > 1",
        ];
        let mut stack = Vec::new();
        for src in srcs {
            let c = Constraint::parse(src, &ps()).unwrap();
            for bx in [0.0, 1.0, 2.0, 4.0, 8.0] {
                for by in [0.0, 8.0, 16.0] {
                    for u in [0.0, 1.0, 2.0, 4.0] {
                        let vals = [bx, by, u];
                        let ast = c.expr.eval(&vals);
                        let compiled = c.program.eval(&vals, &mut stack);
                        // NaN-aware equality: "by // bx" at bx=0, by=0
                        // yields NaN from both evaluators.
                        assert!(
                            ast == compiled || (ast.is_nan() && compiled.is_nan()),
                            "{} on {:?}: ast {} vs compiled {}",
                            src,
                            vals,
                            ast,
                            compiled
                        );
                        assert_eq!(c.holds(&vals), c.holds_scratch(&vals, &mut stack));
                    }
                }
            }
        }
    }

    #[test]
    fn program_depth_and_reuse() {
        let c = Constraint::parse("min(bx, by) + max(u, 2) >= bx * by", &ps()).unwrap();
        assert!(c.program.max_depth >= 2);
        assert!(!c.program.is_empty());
        // The scratch stack drains fully each eval and its capacity
        // stabilizes at max_depth — reuse is allocation-free.
        let mut stack = Vec::new();
        c.program.eval(&[1.0, 8.0, 0.0], &mut stack);
        assert!(stack.is_empty());
        let cap = stack.capacity();
        for _ in 0..10 {
            c.program.eval(&[4.0, 16.0, 2.0], &mut stack);
        }
        assert_eq!(stack.capacity(), cap);
    }

    #[test]
    fn word_ops_not_glued() {
        // "or" must not match the prefix of an identifier.
        let p = ParamSet::new(vec![Param::ints("order", &[0, 1])]);
        let c = Constraint::parse("order == 1", &p).unwrap();
        assert_eq!(c.expr.eval(&[1.0]), 1.0);
    }
}
