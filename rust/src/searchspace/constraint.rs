//! Constraint (restriction) expression engine.
//!
//! Kernel Tuner expresses search-space restrictions as Python expressions
//! over parameter names ("block_size_x*block_size_y >= 32"). We implement
//! the same surface as a parsed infix expression language evaluated over a
//! configuration's numeric values — shared by space construction (where
//! early evaluation prunes the DFS) and by repair.
//!
//! Grammar (precedence climbing):
//!   or:      and ('||' and)*            also accepts `or`
//!   and:     cmp ('&&' cmp)*            also accepts `and`
//!   cmp:     sum (('=='|'!='|'<='|'>='|'<'|'>') sum)?
//!   sum:     prod (('+'|'-') prod)*
//!   prod:    unary (('*'|'/'|'%') unary)*
//!   unary:   '-' unary | '!' unary | atom
//!   atom:    number | ident | '(' or ')' | 'min(' or ',' or ')' | 'max(...)'
//!
//! Booleans are 0.0 / 1.0; `/` is float division and `//` integer division.

use std::fmt;

use super::param::ParamSet;

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    Add,
    Sub,
    Mul,
    Div,
    IntDiv,
    Mod,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

/// Expression AST; parameters are resolved to dimension indices at parse
/// time so evaluation is allocation-free.
#[derive(Debug, Clone)]
pub enum Expr {
    Num(f64),
    Param(usize),
    Bin(Op, Box<Expr>, Box<Expr>),
    Neg(Box<Expr>),
    Not(Box<Expr>),
    Min(Box<Expr>, Box<Expr>),
    Max(Box<Expr>, Box<Expr>),
}

/// A named constraint with its source text and the highest dimension it
/// references (for early evaluation during DFS enumeration).
#[derive(Debug, Clone)]
pub struct Constraint {
    pub source: String,
    pub expr: Expr,
    pub max_dim: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub message: String,
    pub position: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at {}: {}", self.position, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Expr {
    /// Evaluate over per-dimension numeric values.
    pub fn eval(&self, values: &[f64]) -> f64 {
        match self {
            Expr::Num(x) => *x,
            Expr::Param(d) => values[*d],
            Expr::Neg(e) => -e.eval(values),
            Expr::Not(e) => {
                if e.eval(values) != 0.0 {
                    0.0
                } else {
                    1.0
                }
            }
            Expr::Min(a, b) => a.eval(values).min(b.eval(values)),
            Expr::Max(a, b) => a.eval(values).max(b.eval(values)),
            Expr::Bin(op, a, b) => {
                let x = a.eval(values);
                // Short-circuit the logical ops.
                match op {
                    Op::And => {
                        return if x != 0.0 && b.eval(values) != 0.0 { 1.0 } else { 0.0 }
                    }
                    Op::Or => {
                        return if x != 0.0 || b.eval(values) != 0.0 { 1.0 } else { 0.0 }
                    }
                    _ => {}
                }
                let y = b.eval(values);
                match op {
                    Op::Add => x + y,
                    Op::Sub => x - y,
                    Op::Mul => x * y,
                    Op::Div => x / y,
                    Op::IntDiv => (x / y).floor(),
                    Op::Mod => {
                        // Python-style modulo on the integer grid.
                        let r = x % y;
                        if r != 0.0 && (r < 0.0) != (y < 0.0) {
                            r + y
                        } else {
                            r
                        }
                    }
                    Op::Eq => (x == y) as u8 as f64,
                    Op::Ne => (x != y) as u8 as f64,
                    Op::Lt => (x < y) as u8 as f64,
                    Op::Le => (x <= y) as u8 as f64,
                    Op::Gt => (x > y) as u8 as f64,
                    Op::Ge => (x >= y) as u8 as f64,
                    Op::And | Op::Or => unreachable!(),
                }
            }
        }
    }

    fn max_dim(&self) -> usize {
        match self {
            Expr::Num(_) => 0,
            Expr::Param(d) => *d,
            Expr::Neg(e) | Expr::Not(e) => e.max_dim(),
            Expr::Bin(_, a, b) | Expr::Min(a, b) | Expr::Max(a, b) => {
                a.max_dim().max(b.max_dim())
            }
        }
    }
}

impl Constraint {
    /// Parse `source` against the parameter set (names become dims).
    pub fn parse(source: &str, params: &ParamSet) -> Result<Constraint, ParseError> {
        let mut p = Parser {
            src: source.as_bytes(),
            pos: 0,
            params,
        };
        let expr = p.parse_or()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(ParseError {
                message: format!("trailing input: '{}'", &source[p.pos..]),
                position: p.pos,
            });
        }
        let max_dim = expr.max_dim();
        Ok(Constraint {
            source: source.to_string(),
            expr,
            max_dim,
        })
    }

    /// True when the configuration satisfies the constraint.
    #[inline]
    pub fn holds(&self, values: &[f64]) -> bool {
        self.expr.eval(values) != 0.0
    }
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
    params: &'a ParamSet,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.src.len() && self.src[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> u8 {
        self.skip_ws();
        if self.pos < self.src.len() {
            self.src[self.pos]
        } else {
            0
        }
    }

    fn eat(&mut self, tok: &str) -> bool {
        self.skip_ws();
        if self.src[self.pos..].starts_with(tok.as_bytes()) {
            // Word tokens must not be glued to identifier chars.
            if tok.chars().all(|c| c.is_ascii_alphabetic()) {
                let after = self.pos + tok.len();
                if after < self.src.len()
                    && (self.src[after].is_ascii_alphanumeric() || self.src[after] == b'_')
                {
                    return false;
                }
            }
            self.pos += tok.len();
            true
        } else {
            false
        }
    }

    fn err(&self, message: impl Into<String>) -> ParseError {
        ParseError {
            message: message.into(),
            position: self.pos,
        }
    }

    fn parse_or(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_and()?;
        loop {
            if self.eat("||") || self.eat("or") {
                let rhs = self.parse_and()?;
                lhs = Expr::Bin(Op::Or, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn parse_and(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_cmp()?;
        loop {
            if self.eat("&&") || self.eat("and") {
                let rhs = self.parse_cmp()?;
                lhs = Expr::Bin(Op::And, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn parse_cmp(&mut self) -> Result<Expr, ParseError> {
        let lhs = self.parse_sum()?;
        let op = if self.eat("==") {
            Op::Eq
        } else if self.eat("!=") {
            Op::Ne
        } else if self.eat("<=") {
            Op::Le
        } else if self.eat(">=") {
            Op::Ge
        } else if self.eat("<") {
            Op::Lt
        } else if self.eat(">") {
            Op::Gt
        } else {
            return Ok(lhs);
        };
        let rhs = self.parse_sum()?;
        Ok(Expr::Bin(op, Box::new(lhs), Box::new(rhs)))
    }

    fn parse_sum(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_prod()?;
        loop {
            if self.eat("+") {
                let rhs = self.parse_prod()?;
                lhs = Expr::Bin(Op::Add, Box::new(lhs), Box::new(rhs));
            } else if self.peek() == b'-' && !self.src[self.pos..].starts_with(b"->") {
                self.pos += 1;
                let rhs = self.parse_prod()?;
                lhs = Expr::Bin(Op::Sub, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn parse_prod(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_unary()?;
        loop {
            if self.eat("//") {
                let rhs = self.parse_unary()?;
                lhs = Expr::Bin(Op::IntDiv, Box::new(lhs), Box::new(rhs));
            } else if self.eat("*") {
                let rhs = self.parse_unary()?;
                lhs = Expr::Bin(Op::Mul, Box::new(lhs), Box::new(rhs));
            } else if self.eat("/") {
                let rhs = self.parse_unary()?;
                lhs = Expr::Bin(Op::Div, Box::new(lhs), Box::new(rhs));
            } else if self.eat("%") {
                let rhs = self.parse_unary()?;
                lhs = Expr::Bin(Op::Mod, Box::new(lhs), Box::new(rhs));
            } else {
                return Ok(lhs);
            }
        }
    }

    fn parse_unary(&mut self) -> Result<Expr, ParseError> {
        if self.eat("-") {
            return Ok(Expr::Neg(Box::new(self.parse_unary()?)));
        }
        if self.eat("!") {
            return Ok(Expr::Not(Box::new(self.parse_unary()?)));
        }
        self.parse_atom()
    }

    fn parse_atom(&mut self) -> Result<Expr, ParseError> {
        self.skip_ws();
        if self.pos >= self.src.len() {
            return Err(self.err("unexpected end of input"));
        }
        let c = self.src[self.pos];
        if c == b'(' {
            self.pos += 1;
            let e = self.parse_or()?;
            if !self.eat(")") {
                return Err(self.err("expected ')'"));
            }
            return Ok(e);
        }
        if c.is_ascii_digit() || c == b'.' {
            let start = self.pos;
            while self.pos < self.src.len()
                && (self.src[self.pos].is_ascii_digit() || self.src[self.pos] == b'.')
            {
                self.pos += 1;
            }
            let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
            return text
                .parse::<f64>()
                .map(Expr::Num)
                .map_err(|e| self.err(format!("bad number '{}': {}", text, e)));
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let start = self.pos;
            while self.pos < self.src.len()
                && (self.src[self.pos].is_ascii_alphanumeric() || self.src[self.pos] == b'_')
            {
                self.pos += 1;
            }
            let name = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
            if name == "min" || name == "max" {
                if !self.eat("(") {
                    return Err(self.err(format!("expected '(' after {}", name)));
                }
                let a = self.parse_or()?;
                if !self.eat(",") {
                    return Err(self.err("expected ','"));
                }
                let b = self.parse_or()?;
                if !self.eat(")") {
                    return Err(self.err("expected ')'"));
                }
                return Ok(if name == "min" {
                    Expr::Min(Box::new(a), Box::new(b))
                } else {
                    Expr::Max(Box::new(a), Box::new(b))
                });
            }
            return match self.params.index_of(name) {
                Some(d) => Ok(Expr::Param(d)),
                None => Err(self.err(format!("unknown parameter '{}'", name))),
            };
        }
        Err(self.err(format!("unexpected character '{}'", c as char)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::searchspace::param::Param;

    fn ps() -> ParamSet {
        ParamSet::new(vec![
            Param::ints("bx", &[1, 2, 4, 8]),
            Param::ints("by", &[8, 16]),
            Param::ints("u", &[0, 1, 2, 4]),
        ])
    }

    fn eval(src: &str, vals: &[f64]) -> f64 {
        Constraint::parse(src, &ps()).unwrap().expr.eval(vals)
    }

    #[test]
    fn arithmetic_and_comparison() {
        assert_eq!(eval("bx * by >= 32", &[4.0, 16.0, 0.0]), 1.0);
        assert_eq!(eval("bx * by >= 32", &[2.0, 8.0, 0.0]), 0.0);
        assert_eq!(eval("bx + by - 2", &[4.0, 16.0, 0.0]), 18.0);
        assert_eq!(eval("by // bx", &[4.0, 16.0, 0.0]), 4.0);
        assert_eq!(eval("by % 3", &[0.0, 16.0, 0.0]), 1.0);
    }

    #[test]
    fn logical_ops_and_precedence() {
        assert_eq!(eval("bx == 2 || bx == 4", &[4.0, 0.0, 0.0]), 1.0);
        assert_eq!(eval("bx == 2 && by == 8", &[2.0, 8.0, 0.0]), 1.0);
        assert_eq!(eval("bx == 2 and by == 8 or u == 4", &[1.0, 1.0, 4.0]), 1.0);
        // * binds tighter than ==, == tighter than &&.
        assert_eq!(eval("bx * by == 32 && u != 1", &[4.0, 8.0, 0.0]), 1.0);
    }

    #[test]
    fn unary_and_funcs() {
        assert_eq!(eval("!(bx == 2)", &[2.0, 0.0, 0.0]), 0.0);
        assert_eq!(eval("-bx + 5", &[2.0, 0.0, 0.0]), 3.0);
        assert_eq!(eval("min(bx, by)", &[4.0, 16.0, 0.0]), 4.0);
        assert_eq!(eval("max(bx, by)", &[4.0, 16.0, 0.0]), 16.0);
    }

    #[test]
    fn modulo_divisibility_pattern() {
        // The CLBlast-style pattern: "MWG % (MDIMC * VWM) == 0".
        assert_eq!(eval("by % (bx * 2) == 0", &[4.0, 16.0, 0.0]), 1.0);
        assert_eq!(eval("by % (bx * 2) == 0", &[4.0, 8.0, 0.0]), 1.0);
        assert_eq!(eval("by % (bx * 3) == 0", &[4.0, 16.0, 0.0]), 0.0);
    }

    #[test]
    fn max_dim_tracks_last_param() {
        let c = Constraint::parse("bx * by >= 32", &ps()).unwrap();
        assert_eq!(c.max_dim, 1);
        let c = Constraint::parse("u == 0 || bx > 1", &ps()).unwrap();
        assert_eq!(c.max_dim, 2);
    }

    #[test]
    fn parse_errors() {
        assert!(Constraint::parse("unknown_param == 1", &ps()).is_err());
        assert!(Constraint::parse("bx ==", &ps()).is_err());
        assert!(Constraint::parse("bx == 1 extra", &ps()).is_err());
        assert!(Constraint::parse("(bx == 1", &ps()).is_err());
    }

    #[test]
    fn word_ops_not_glued() {
        // "or" must not match the prefix of an identifier.
        let p = ParamSet::new(vec![Param::ints("order", &[0, 1])]);
        let c = Constraint::parse("order == 1", &p).unwrap();
        assert_eq!(c.expr.eval(&[1.0]), 1.0);
    }
}
