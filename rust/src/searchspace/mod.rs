//! Search-space substrate: tunable parameters, constraints, enumeration,
//! neighbor operations, and the four benchmark space builders (Table 1).
//!
//! The hot-path architecture (PR 4):
//!
//! - **Compiled constraints** — [`Constraint::parse`] produces both an
//!   [`Expr`] AST (reference/introspection) and a flat postfix
//!   [`constraint::Program`] evaluated over a caller-owned scratch stack.
//!   The DFS enumerator and [`SearchSpace::satisfies_constraints_scratch`]
//!   run the program: no `Box` chasing, no per-evaluation allocation.
//! - **Parallel, deterministic construction** — [`SearchSpace::build_parsed`]
//!   partitions the first dimension's values across workers
//!   (`util::parallel`) and concatenates the arenas in value order, so the
//!   enumeration order (and every config ordinal derived from it) is
//!   byte-identical for any `--threads` width.
//! - **CSR neighbor graphs** — per (space, [`NeighborKind`]) adjacency
//!   tables (offsets + flat `u32` neighbor arena) built lazily behind
//!   `OnceLock`s and shared through the `Arc<SearchSpace>`.
//!   [`SearchSpace::neighbors_of`] returns a borrowed `&[u32]` row in the
//!   exact order the on-the-fly [`SearchSpace::neighbors`] enumeration
//!   produces; [`SearchSpace::random_neighbor`] is one uniform index into
//!   the row.

pub mod builder;
pub mod constraint;
pub mod param;
pub mod space;

pub use builder::{Application, SpaceSpec};
pub use constraint::{compile, Constraint, Expr, Program};
pub use param::{Param, ParamSet, Value};
pub use space::{NeighborKind, SearchSpace};
