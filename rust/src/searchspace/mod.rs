//! Search-space substrate: tunable parameters, constraints, enumeration,
//! neighbor operations, and the four benchmark space builders (Table 1).

pub mod builder;
pub mod constraint;
pub mod param;
pub mod space;

pub use builder::Application;
pub use constraint::{Constraint, Expr};
pub use param::{Param, ParamSet, Value};
pub use space::{NeighborKind, SearchSpace};
