//! Coordinator side of the fleet: a [`RemoteRunner`] implements
//! [`BatchRunner`] by partitioning the batch across worker daemons,
//! streaming their rows into a slot table, and re-dispatching the
//! unfinished remainder of any lost worker to the survivors.
//!
//! Fault model: a worker is *lost* when its connection fails, a read
//! times out (workers heartbeat well inside [`RemoteRunner::read_timeout`],
//! so silence means gone, not busy), the stream ends before `done`, or it
//! sends garbage. Lost workers are dropped for the rest of the batch;
//! their unfinished indices re-partition round-robin over the survivors.
//! Re-dispatch is idempotent — seeds travel with the jobs, so a re-run
//! is bit-equal and the slot table's first-write-wins dedup (see
//! [`super::dispatch`]) makes duplicate rows harmless. With no survivors
//! the remaining slots fail with a structured error; a fleet-wide cancel
//! (Ctrl-C) marks them cancelled instead — both are honest
//! completed-prefix results, never a hang.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::dispatch::{split_round_robin, Record, SlotTable};
use super::protocol::{cancel_request, parse_event, run_request, wire_job, WorkerEvent};
use crate::coordinator::{
    BatchResult, BatchRunner, JobHandle, JobOutcome, JobsSummary, OwnedJob, Progress, ProgressSink,
};
use crate::obs;
use crate::util::cancel::CancelToken;
use crate::util::json::Json;

/// Per-worker accounting: what was dispatched (over all rounds and
/// batches), what came back, and the worker's own `done` summaries
/// (absorbed across its connections). `lost` marks a worker dropped
/// mid-batch; its `jobs` then under-counts, which is why the report's
/// `"jobs"` block is computed from the deduped slot table, not from
/// these tallies — they are for the operator, not the result.
#[derive(Debug, Clone)]
pub struct WorkerTally {
    pub addr: String,
    /// Jobs sent to this worker, summed over dispatch rounds.
    pub dispatched: usize,
    /// Fresh rows this worker delivered (first arrival for the slot).
    pub rows: usize,
    /// Rows dropped as duplicates (slot already filled — benign).
    pub duplicates: usize,
    /// The worker's own completion counters, from its `done` events.
    pub jobs: JobsSummary,
    /// Dropped mid-batch (connect failure, timeout, protocol garbage).
    pub lost: bool,
}

/// A [`BatchRunner`] that fans one batch across `llamea-kt worker`
/// daemons. Construct with the worker addresses, optionally adopt the
/// CLI's SIGINT token via [`RemoteRunner::cancel_via`], and hand it to
/// anything that drives a `BatchRunner` (the coordinate path, the sweep
/// meta-tuner, hypertune's backend).
pub struct RemoteRunner {
    workers: Vec<String>,
    cancel: CancelToken,
    read_timeout: Duration,
    tallies: Mutex<Vec<WorkerTally>>,
}

impl RemoteRunner {
    pub fn new(workers: Vec<String>) -> RemoteRunner {
        RemoteRunner {
            tallies: Mutex::new(Vec::with_capacity(workers.len())),
            workers,
            cancel: CancelToken::new(),
            read_timeout: Duration::from_secs(10),
        }
    }

    /// Adopt an externally owned cancellation token (the CLI's SIGINT
    /// bridge) instead of the fresh per-runner one.
    pub fn cancel_via(mut self, token: CancelToken) -> RemoteRunner {
        self.cancel = token;
        self
    }

    /// Per-read bound on worker silence. Workers heartbeat every ~500ms
    /// while a batch runs, so the default 10s is ~20 missed pulses —
    /// decisively lost, yet instant against real tuning runs.
    pub fn read_timeout(mut self, timeout: Duration) -> RemoteRunner {
        self.read_timeout = timeout;
        self
    }

    /// Per-worker accounting, accumulated over every batch run through
    /// this runner (a sweep drains many inner batches through one).
    pub fn tallies(&self) -> Vec<WorkerTally> {
        self.tallies.lock().unwrap().clone()
    }

    fn fail_all(&self, jobs: &[OwnedJob], sink: &ProgressSink, msg: &str) -> BatchResult {
        for i in 0..jobs.len() {
            sink(&Progress::Failed { slot: i, error: msg.to_string() });
        }
        let handles = jobs
            .iter()
            .enumerate()
            .map(|(i, j)| handle(i, j, JobOutcome::Failed(msg.to_string())))
            .collect();
        BatchResult::from_handles(handles, true)
    }

    /// Drive one worker connection through one dispatch round. Returns
    /// `false` when the worker must be dropped (connect/read/protocol
    /// failure before its `done`).
    #[allow(clippy::too_many_arguments)]
    fn run_worker(
        &self,
        w: usize,
        bucket: &[usize],
        wire: &[Json],
        trace: bool,
        table: &Mutex<SlotTable>,
        completed: &AtomicUsize,
        t0: Instant,
        sink: &ProgressSink,
    ) -> bool {
        let addr = self.workers[w].clone();
        let Ok(stream) = TcpStream::connect(&addr) else { return false };
        if stream.set_read_timeout(Some(self.read_timeout)).is_err() {
            return false;
        }
        // The worker's trace epoch (`base_ns` in its `done`) is pinned
        // just before it starts executing, i.e. "now" from this side —
        // dispatch time is the renormalization anchor.
        let dispatch_ns = obs::now_ns();
        let batch: Vec<Json> = bucket.iter().map(|&i| wire[i].clone()).collect();
        {
            let mut wtr = &stream;
            let line = format!("{}\n", run_request(batch, trace).to_string());
            if wtr.write_all(line.as_bytes()).is_err() {
                return false;
            }
        }
        self.tallies.lock().unwrap()[w].dispatched += bucket.len();

        let Ok(read_half) = stream.try_clone() else { return false };
        let mut reader = BufReader::new(read_half);
        let mut cancel_sent = false;
        loop {
            // Heartbeats bound every read to ~500ms, so a fleet cancel
            // propagates within one pulse even on an idle stream.
            if self.cancel.is_cancelled() && !cancel_sent {
                cancel_sent = true;
                let mut wtr = &stream;
                let _ = wtr.write_all(format!("{}\n", cancel_request().to_string()).as_bytes());
            }
            let mut line = String::new();
            match reader.read_line(&mut line) {
                Err(_) | Ok(0) => return false,
                Ok(_) => {}
            }
            let event = match parse_event(line.trim_end()) {
                Ok(ev) => ev,
                Err(_) => return false,
            };
            match event {
                WorkerEvent::Hello { .. } | WorkerEvent::Heartbeat => {}
                WorkerEvent::Row { index, group: _, curve } => {
                    match table.lock().unwrap().record(index, JobOutcome::Completed(curve)) {
                        Record::Fresh => {
                            self.tallies.lock().unwrap()[w].rows += 1;
                            let done = completed.fetch_add(1, Ordering::SeqCst) + 1;
                            sink(&Progress::Finished {
                                slot: index,
                                completed: done,
                                elapsed_us: t0.elapsed().as_micros() as u64,
                            });
                        }
                        Record::Duplicate => self.tallies.lock().unwrap()[w].duplicates += 1,
                        Record::OutOfRange => return false,
                    }
                }
                WorkerEvent::JobFailed { index, error } => {
                    match table.lock().unwrap().record(index, JobOutcome::Failed(error.clone())) {
                        Record::Fresh => sink(&Progress::Failed { slot: index, error }),
                        Record::Duplicate => self.tallies.lock().unwrap()[w].duplicates += 1,
                        Record::OutOfRange => return false,
                    }
                }
                WorkerEvent::Done { summary, base_ns: worker_base, spans } => {
                    self.tallies.lock().unwrap()[w].jobs.absorb(summary);
                    if trace && !spans.is_empty() {
                        // pid 1 is this process; workers get 2, 3, ...
                        let offset = dispatch_ns as i64 - worker_base as i64;
                        obs::export::import_worker_events(&spans, w as u64 + 2, offset);
                    }
                    return true;
                }
                WorkerEvent::Error { message: _ } => return false,
            }
        }
    }
}

fn handle(slot: usize, job: &OwnedJob, outcome: JobOutcome) -> JobHandle {
    JobHandle {
        slot,
        group: job.group,
        priority: job.priority,
        seed: job.seed,
        cost_us: job.cost_us(),
        outcome,
    }
}

impl BatchRunner for RemoteRunner {
    fn run_batch(&self, jobs: &[OwnedJob], sink: &ProgressSink) -> BatchResult {
        let n = jobs.len();
        {
            // First batch initializes the tallies; later batches keep
            // accumulating, and a worker lost in one batch is retried in
            // the next (it may have restarted) — `lost` then reads
            // "lost at least once".
            let mut tallies = self.tallies.lock().unwrap();
            if tallies.len() != self.workers.len() {
                *tallies = self
                    .workers
                    .iter()
                    .map(|a| WorkerTally {
                        addr: a.clone(),
                        dispatched: 0,
                        rows: 0,
                        duplicates: 0,
                        jobs: JobsSummary::default(),
                        lost: false,
                    })
                    .collect();
            }
        }
        if self.workers.is_empty() {
            return self.fail_all(jobs, sink, "no remote workers configured");
        }
        let wire: Result<Vec<Json>, String> =
            jobs.iter().enumerate().map(|(i, j)| wire_job(i, j)).collect();
        let wire = match wire {
            Ok(w) => w,
            Err(msg) => return self.fail_all(jobs, sink, &msg),
        };
        for i in 0..n {
            sink(&Progress::Started { slot: i });
        }

        let trace = obs::trace_on();
        let table = Mutex::new(SlotTable::new(n));
        let completed = AtomicUsize::new(0);
        let t0 = Instant::now();
        let alive: Vec<AtomicBool> = self.workers.iter().map(|_| AtomicBool::new(true)).collect();

        loop {
            if self.cancel.is_cancelled() {
                break;
            }
            let remaining = table.lock().unwrap().unfinished();
            if remaining.is_empty() {
                break;
            }
            let survivors: Vec<usize> = (0..self.workers.len())
                .filter(|&w| alive[w].load(Ordering::SeqCst))
                .collect();
            if survivors.is_empty() {
                break;
            }
            let buckets = split_round_robin(&remaining, survivors.len());
            std::thread::scope(|s| {
                for (k, bucket) in buckets.iter().enumerate() {
                    if bucket.is_empty() {
                        continue;
                    }
                    let w = survivors[k];
                    let (wire, table, completed, alive) = (&wire, &table, &completed, &alive);
                    s.spawn(move || {
                        let ok =
                            self.run_worker(w, bucket, wire, trace, table, completed, t0, sink);
                        if !ok {
                            alive[w].store(false, Ordering::SeqCst);
                            self.tallies.lock().unwrap()[w].lost = true;
                        }
                    });
                }
            });
        }

        let cancelled = self.cancel.is_cancelled();
        let table = table.into_inner().unwrap();
        for &i in &table.unfinished() {
            if cancelled {
                sink(&Progress::Cancelled { slot: i });
            } else {
                sink(&Progress::Failed {
                    slot: i,
                    error: "no surviving remote workers".to_string(),
                });
            }
        }
        let outcomes = table.into_outcomes(|_| {
            if cancelled {
                JobOutcome::Cancelled
            } else {
                JobOutcome::Failed("no surviving remote workers".to_string())
            }
        });
        let handles = outcomes
            .into_iter()
            .enumerate()
            .map(|(i, outcome)| handle(i, &jobs[i], outcome))
            .collect();
        BatchResult::from_handles(handles, true)
    }

    fn batch_cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }
}
