//! Pure fleet-dispatch bookkeeping: partitioning slot indices across
//! workers and tracking which slots still need an outcome. No sockets —
//! everything here is deterministic, synchronous, and unit-tested in
//! isolation; [`super::runner`] wires it to real connections.

use crate::coordinator::JobOutcome;

/// Round-robin partition: bucket `w` receives `indices[k]` for every
/// `k % ways == w`. On the first dispatch round `indices` is `0..n`, so
/// this is exactly the `ShardSpec` rule (`index % ways == w`) that PR 6
/// proved valid for any partition — seeds are grid-derived, never
/// order-derived. Re-dispatch rounds pass the surviving unfinished
/// indices (sorted ascending), which stay balanced the same way.
pub fn split_round_robin(indices: &[usize], ways: usize) -> Vec<Vec<usize>> {
    let ways = ways.max(1);
    let mut out = vec![Vec::new(); ways];
    for (k, &i) in indices.iter().enumerate() {
        out[k % ways].push(i);
    }
    out
}

/// What [`SlotTable::record`] did with a delivered outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Record {
    /// First outcome for this slot — recorded.
    Fresh,
    /// The slot already holds an outcome — dropped. Benign: re-dispatch
    /// can legitimately produce the same row twice, and identical seeds
    /// make either copy bit-equal, so first-write-wins loses nothing.
    Duplicate,
    /// The index is outside the batch — a protocol violation by the
    /// sender (the runner drops that worker).
    OutOfRange,
}

/// Slot-indexed outcome table for one fleet batch. Deduplication by
/// index lives here — *upstream* of report assembly, because
/// `merge_coordinate` treats a duplicate index as an error — and
/// first-write-wins is sound because a re-run of the same seed is
/// bit-equal to the original.
pub struct SlotTable {
    slots: Vec<Option<JobOutcome>>,
    filled: usize,
}

impl SlotTable {
    pub fn new(n: usize) -> SlotTable {
        SlotTable { slots: vec![None; n], filled: 0 }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Number of slots holding an outcome.
    pub fn filled(&self) -> usize {
        self.filled
    }

    /// Record `outcome` for `index` (first write wins).
    pub fn record(&mut self, index: usize, outcome: JobOutcome) -> Record {
        match self.slots.get_mut(index) {
            None => Record::OutOfRange,
            Some(Some(_)) => Record::Duplicate,
            Some(slot @ None) => {
                *slot = Some(outcome);
                self.filled += 1;
                Record::Fresh
            }
        }
    }

    /// Slots with no outcome yet, ascending.
    pub fn unfinished(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.is_none().then_some(i))
            .collect()
    }

    /// Consume the table into per-slot outcomes, filling any still-empty
    /// slot by calling `fill` with its index (cancelled fleet →
    /// `Cancelled`, no surviving workers → `Failed`).
    pub fn into_outcomes(self, mut fill: impl FnMut(usize) -> JobOutcome) -> Vec<JobOutcome> {
        self.slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.unwrap_or_else(|| fill(i)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_round_split_matches_the_shard_rule() {
        let indices: Vec<usize> = (0..11).collect();
        let buckets = split_round_robin(&indices, 3);
        assert_eq!(buckets.len(), 3);
        for (w, bucket) in buckets.iter().enumerate() {
            for &i in bucket {
                assert_eq!(i % 3, w, "first-round bucket {} must obey i % ways == w", w);
            }
        }
        let total: usize = buckets.iter().map(Vec::len).sum();
        assert_eq!(total, 11, "every index lands in exactly one bucket");
    }

    #[test]
    fn redispatch_split_balances_survivor_load() {
        let remaining = [2, 5, 8, 11, 14];
        let buckets = split_round_robin(&remaining, 2);
        assert_eq!(buckets[0], vec![2, 8, 14]);
        assert_eq!(buckets[1], vec![5, 11]);
        // Degenerate ways are clamped, never a panic.
        assert_eq!(split_round_robin(&remaining, 0).len(), 1);
        assert_eq!(split_round_robin(&[], 4).iter().map(Vec::len).sum::<usize>(), 0);
    }

    #[test]
    fn slot_table_dedups_by_index_and_tracks_unfinished() {
        let mut table = SlotTable::new(4);
        assert_eq!(table.unfinished(), vec![0, 1, 2, 3]);
        assert_eq!(table.record(1, JobOutcome::Completed(vec![1.0])), Record::Fresh);
        assert_eq!(
            table.record(1, JobOutcome::Completed(vec![2.0])),
            Record::Duplicate,
            "second delivery for a slot is dropped"
        );
        assert_eq!(table.record(9, JobOutcome::Completed(vec![0.0])), Record::OutOfRange);
        assert_eq!(table.record(3, JobOutcome::Failed("x".into())), Record::Fresh);
        assert_eq!(table.filled(), 2);
        assert_eq!(table.unfinished(), vec![0, 2]);
        let outcomes = table.into_outcomes(|_| JobOutcome::Cancelled);
        assert_eq!(outcomes.len(), 4);
        // First write won: the duplicate's curve never displaced the original.
        assert_eq!(outcomes[1], JobOutcome::Completed(vec![1.0]));
        assert_eq!(outcomes[0], JobOutcome::Cancelled);
        assert_eq!(outcomes[2], JobOutcome::Cancelled);
        assert_eq!(outcomes[3], JobOutcome::Failed("x".into()));
    }
}
