//! Fleet execution: fan one batch across remote worker daemons and
//! collate the rows into a result byte-identical to the single-process
//! run (modulo the non-deterministic `"caches"` block).
//!
//! Two halves over one newline-delimited-JSON TCP protocol (the serve
//! daemon's conventions — [`crate::serve::protocol`] supplies the shared
//! line reader and limits):
//!
//! - [`worker::Worker`] — the daemon behind `llamea-kt worker`: one
//!   batch per connection, executed on a local deterministic pool,
//!   rows streamed home as they finish.
//! - [`runner::RemoteRunner`] — a [`crate::coordinator::BatchRunner`]
//!   that partitions the batch over workers, re-dispatches after
//!   failures, and deduplicates by slot index.
//!
//! ## Wire grammar
//!
//! One JSON object per `\n`-terminated line, at most
//! [`protocol::MAX_LINE_BYTES`] per line. Coordinator → worker:
//!
//! ```text
//! {"cmd":"run","trace":false,"jobs":[
//!   {"index":4,"space":"convolution@A4000","opt":"sa",
//!    "seed":"17349...202","group":1,"priority":0}, ...]}
//! {"cmd":"cancel"}
//! ```
//!
//! Worker → coordinator (in order: `hello`, then interleaved
//! `row`/`job_failed`/`heartbeat`, then exactly one `done`):
//!
//! ```text
//! {"event":"hello","threads":8,"jobs":12}
//! {"event":"row","index":4,"group":1,"curve":[201.5,...]}
//! {"event":"job_failed","index":7,"error":"..."}
//! {"event":"heartbeat"}
//! {"event":"done","jobs":{"completed":11,"cancelled":0,"failed":1,
//!  "cost_us":33000000},"base_ns":"41527","spans":[...]}
//! {"event":"error","message":"..."}
//! ```
//!
//! Seeds and `base_ns` are decimal *strings* (JSON numbers are `f64`,
//! exact only to 2^53; see [`protocol`]); curves are plain JSON arrays,
//! bit-exact through [`crate::util::json`].
//!
//! ## Why re-dispatch is idempotent
//!
//! The determinism contract makes every job a pure function of
//! `(source, setup, factory, seed)`, and each job's seed travels in its
//! wire record — derived from grid coordinates, never from which host
//! runs it or when. So executing a job twice (the coordinator re-sends a
//! lost worker's unfinished indices; the "lost" worker may in fact still
//! be computing) yields bit-equal curves, and first-write-wins dedup by
//! slot index ([`dispatch::SlotTable`]) loses nothing whichever copy
//! lands first. Collation fills slots by index, so the merged batch is
//! byte-identical to the single-process run at any fleet width, any
//! partition, and under any kill/retry timing — the same argument that
//! justified `ShardSpec` grid sharding, promoted from static shards to
//! dynamic fan-out.
//!
//! ## One fleet trace, one clock
//!
//! Workers record [`crate::obs`] spans against their own process epoch
//! and ship them in `done` (`spans`, with `base_ns` = the worker's epoch
//! reading at batch start). The coordinator renormalizes each worker's
//! timestamps by `offset = dispatch_ns - base_ns` — the connection's
//! dispatch instant on the coordinator clock — clamped at zero, and tags
//! them `pid = worker index + 2` (the coordinator itself is `pid` 1).
//! `--trace` then emits one fleet-wide Chrome trace in the canonical
//! `(epoch-ns, pid, tid, seq)` order, which degenerates to the
//! historical `(epoch-ns, thread, seq)` order when everything ran in one
//! process.

pub mod dispatch;
pub mod protocol;
pub mod runner;
pub mod worker;

pub use runner::{RemoteRunner, WorkerTally};
pub use worker::{Worker, WorkerConfig, WorkerHandle};
