//! The fleet worker daemon: accepts one batch per connection, executes
//! it on a deterministic local pool, and streams rows home as they
//! finish. Runs behind `llamea-kt worker --listen ADDR`; the global
//! `--cache-dir` flag gives it persist warm-starts like any other
//! subcommand (the registry is process-wide).
//!
//! The worker holds no fleet state: every connection is one
//! self-contained batch (`run` request → `hello`, `row`/`job_failed`
//! stream, `done`), so a coordinator that loses a worker simply
//! reconnects elsewhere and re-sends the unfinished indices — job seeds
//! travel with the jobs, which is what makes a re-run bit-equal to the
//! lost original (see [`super`]).
//!
//! Liveness: a heartbeat event every [`WorkerConfig::heartbeat`] while a
//! batch runs, so the coordinator's read timeout cleanly separates "busy"
//! from "gone". Cancellation is cooperative and arrives on the same
//! connection (a `cancel` line, or EOF when the coordinator vanishes —
//! both fire the batch's token, and completed rows stay valid).
//!
//! Trace buffers are process-global: a traced batch resets and drains
//! the `obs` ring, so run traced fleets against dedicated workers, not a
//! worker shared by concurrent coordinators.

use std::io::{BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::protocol::{
    done_event, error_event, heartbeat_event, hello_event, job_failed_event, parse_request,
    row_event, WireJob, WorkerRequest, MAX_LINE_BYTES,
};
use crate::coordinator::executor::execute_isolated;
use crate::coordinator::{CacheKey, CacheRegistry, JobOutcome, JobsSummary, OwnedJob};
use crate::obs;
use crate::optimizers::OptimizerSpec;
use crate::serve::protocol::{read_line, Line};
use crate::util::cancel::CancelToken;
use crate::util::json::Json;

/// Worker daemon knobs.
pub struct WorkerConfig {
    /// Local pool width; `None` means the machine default
    /// ([`crate::util::parallel::default_width`]).
    pub threads: Option<usize>,
    /// Liveness pulse period while a batch runs. Must sit well under the
    /// coordinator's read timeout (default 500ms against 10s).
    pub heartbeat: Duration,
}

impl Default for WorkerConfig {
    fn default() -> WorkerConfig {
        WorkerConfig { threads: None, heartbeat: Duration::from_millis(500) }
    }
}

/// A bound, not-yet-running worker. `bind` → inspect
/// [`Worker::local_addr`] (supports `--listen 127.0.0.1:0`) →
/// [`Worker::run`].
pub struct Worker {
    listener: TcpListener,
    addr: SocketAddr,
    threads: usize,
    heartbeat: Duration,
    shutdown: CancelToken,
}

/// Clonable remote control for a running [`Worker`]: fires the shutdown
/// token and pokes the accept loop awake.
#[derive(Clone)]
pub struct WorkerHandle {
    token: CancelToken,
    addr: SocketAddr,
}

impl WorkerHandle {
    pub fn shutdown(&self) {
        self.token.cancel();
        // The accept loop blocks in `accept`; a throwaway connection
        // makes it re-check the token.
        let _ = TcpStream::connect(self.addr);
    }
}

impl Worker {
    pub fn bind(addr: &str, config: WorkerConfig) -> std::io::Result<Worker> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let threads = config.threads.unwrap_or_else(crate::util::parallel::default_width).max(1);
        Ok(Worker { listener, addr, threads, heartbeat: config.heartbeat, shutdown: CancelToken::new() })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn handle(&self) -> WorkerHandle {
        WorkerHandle { token: self.shutdown.clone(), addr: self.addr }
    }

    /// Accept connections until the shutdown token fires. Each
    /// connection is handled on its own thread; shutdown also cancels
    /// any batch still running (its coordinator sees the wound-down
    /// `done` and re-dispatches elsewhere).
    pub fn run(self) -> std::io::Result<()> {
        for stream in self.listener.incoming() {
            if self.shutdown.is_cancelled() {
                break;
            }
            let Ok(stream) = stream else { continue };
            let threads = self.threads;
            let heartbeat = self.heartbeat;
            let shutdown = self.shutdown.clone();
            std::thread::spawn(move || handle_conn(stream, threads, heartbeat, shutdown));
        }
        Ok(())
    }
}

/// Write one event line (best effort — a hung-up coordinator just ends
/// this batch via the watcher's EOF).
fn send(stream: &TcpStream, event: &Json) {
    let mut w = stream;
    let _ = w.write_all(format!("{}\n", event.to_string()).as_bytes());
}

/// Same, under the shared write lock — rows, heartbeats, and failures
/// are emitted by different threads, and the lock keeps every event
/// line-atomic on the wire.
fn send_locked(stream: &Mutex<TcpStream>, event: &Json) {
    let guard = stream.lock().unwrap();
    send(&guard, event);
}

fn handle_conn(stream: TcpStream, threads: usize, heartbeat: Duration, shutdown: CancelToken) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half.take((MAX_LINE_BYTES + 1) as u64));
    loop {
        let (line, terminated) = match read_line(&mut reader) {
            Line::Eof => return,
            Line::TooLong => {
                // Cannot resync inside an unbounded line; answer and drop.
                send(&stream, &error_event("request line exceeds 1 MiB"));
                return;
            }
            Line::NotUtf8(t) => {
                send(&stream, &error_event("request line is not UTF-8"));
                if t {
                    continue;
                }
                return;
            }
            Line::Data(s, t) => (s, t),
        };
        if !line.trim().is_empty() {
            match parse_request(&line) {
                Err(msg) => send(&stream, &error_event(&msg)),
                // A cancel with no batch on this connection is a no-op.
                Ok(WorkerRequest::Cancel) => {}
                Ok(WorkerRequest::Run { jobs, trace }) => {
                    // One run per connection: the reader moves to the
                    // watcher thread, and the batch's end ends the
                    // connection's useful life.
                    run_batch_conn(stream, reader, jobs, trace, threads, heartbeat, shutdown);
                    return;
                }
            }
        }
        if !terminated {
            return;
        }
    }
}

/// Reconstruct the batch against the local registry. Any failure aborts
/// the whole batch with a structured error — a coordinator that sent one
/// unknown space would otherwise get a silently partial run.
fn resolve_jobs(wire: &[WireJob]) -> Result<Vec<OwnedJob>, String> {
    let registry = CacheRegistry::global();
    wire.iter()
        .map(|wj| {
            let key = CacheKey::parse(&wj.space)
                .ok_or_else(|| format!("unknown space '{}' (use app@gpu)", wj.space))?;
            let spec = OptimizerSpec::parse(&wj.opt)
                .ok_or_else(|| format!("unknown optimizer spec '{}'", wj.opt))?;
            Ok(OwnedJob {
                entry: registry.entry(key),
                spec: Arc::new(spec),
                seed: wj.seed,
                group: wj.group,
                priority: wj.priority,
            })
        })
        .collect()
}

fn run_batch_conn(
    stream: TcpStream,
    mut reader: BufReader<std::io::Take<TcpStream>>,
    wire: Vec<WireJob>,
    trace: bool,
    threads: usize,
    heartbeat: Duration,
    shutdown: CancelToken,
) {
    let owned = match resolve_jobs(&wire) {
        Ok(owned) => owned,
        Err(msg) => {
            send(&stream, &error_event(&msg));
            return;
        }
    };

    let token = CancelToken::new();
    // Watcher: consume the connection for the batch's lifetime. A
    // `cancel` line or the coordinator vanishing (EOF, garbage) fires
    // the batch token. Detached on purpose: it blocks in a read until
    // the coordinator closes, which may be after `done` is sent.
    {
        let token = token.clone();
        std::thread::spawn(move || loop {
            match read_line(&mut reader) {
                Line::Eof | Line::TooLong => {
                    token.cancel();
                    return;
                }
                Line::NotUtf8(t) => {
                    if !t {
                        token.cancel();
                        return;
                    }
                }
                Line::Data(line, t) => {
                    if matches!(parse_request(&line), Ok(WorkerRequest::Cancel)) {
                        token.cancel();
                    }
                    if !t {
                        return;
                    }
                }
            }
        });
    }

    if trace {
        obs::enable(true, false);
        obs::reset();
    }
    let base_ns = obs::now_ns();

    let pool = threads.min(wire.len()).max(1);
    let stream = Mutex::new(stream);
    send_locked(&stream, &hello_event(pool, wire.len()));

    let summary = Mutex::new(JobsSummary::default());
    let next = AtomicUsize::new(0);
    let finished = AtomicBool::new(false);
    std::thread::scope(|s| {
        // Heartbeat + shutdown relay: pulses while the batch runs, and
        // forwards a daemon-wide shutdown into this batch's token.
        let hb = s.spawn(|| {
            let mut since_pulse = Duration::ZERO;
            let tick = Duration::from_millis(25);
            while !finished.load(Ordering::SeqCst) {
                if shutdown.is_cancelled() {
                    token.cancel();
                }
                std::thread::sleep(tick);
                since_pulse += tick;
                if since_pulse >= heartbeat {
                    since_pulse = Duration::ZERO;
                    send_locked(&stream, &heartbeat_event());
                }
            }
        });
        let runners: Vec<_> = (0..pool)
            .map(|_| {
                s.spawn(|| loop {
                    let k = next.fetch_add(1, Ordering::SeqCst);
                    let Some(job) = owned.get(k) else { return };
                    let index = wire[k].index;
                    let mut sp = obs::span("remote.job").kv("index", index);
                    match execute_isolated(&job.as_job(), &token) {
                        JobOutcome::Completed(curve) => {
                            sp.note("outcome", "completed");
                            {
                                let mut sum = summary.lock().unwrap();
                                sum.completed += 1;
                                sum.cost_us += job.cost_us();
                            }
                            send_locked(&stream, &row_event(index, job.group, &curve));
                        }
                        JobOutcome::Cancelled => {
                            sp.note("outcome", "cancelled");
                            summary.lock().unwrap().cancelled += 1;
                        }
                        JobOutcome::Failed(e) => {
                            sp.note("outcome", "failed");
                            summary.lock().unwrap().failed += 1;
                            send_locked(&stream, &job_failed_event(index, &e));
                        }
                    }
                })
            })
            .collect();
        for r in runners {
            let _ = r.join();
        }
        finished.store(true, Ordering::SeqCst);
        let _ = hb.join();
    });

    let spans = if trace {
        let spans = crate::obs::export::events_json();
        obs::enable(false, false);
        obs::reset();
        spans
    } else {
        Json::Arr(Vec::new())
    };
    let summary = *summary.lock().unwrap();
    send_locked(&stream, &done_event(&summary, base_ns, spans));
}
