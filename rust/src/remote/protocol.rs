//! The fleet wire protocol: newline-delimited JSON between the
//! coordinator-side [`RemoteRunner`](super::runner::RemoteRunner) and
//! `llamea-kt worker` daemons, following the serve protocol's
//! conventions (one JSON object per line, [`MAX_LINE_BYTES`] cap,
//! structured `error` events for every malformed input — never a panic
//! or a hang). See [`super`] for the full grammar.
//!
//! Two wire rules keep the determinism contract intact across hosts:
//!
//! - **Seeds are decimal strings.** Per-job seeds are avalanched over
//!   the full 64-bit range, and JSON numbers are `f64` (exact only to
//!   2^53), so `seed` (and the worker's `base_ns`) cross the wire as
//!   strings and re-parse with `str::parse::<u64>` — bit-exact.
//! - **Only registry specs travel.** A `genome:<name>`
//!   [`OptimizerSpec`] does not round-trip through `Display`/`parse`
//!   (pinned by `genome_display_is_explicitly_partial`), so
//!   [`wire_job`] rejects genome jobs up front with a structured error
//!   instead of silently running the wrong optimizer remotely.
//!
//! Curves are `Vec<f64>` riding as plain JSON arrays —
//! [`crate::util::json`] round-trips every `f64` bit-exactly, which is
//! what makes fleet collation byte-identical to the single-process run.

use crate::coordinator::{JobsSummary, OwnedJob};
use crate::optimizers::OptimizerSpec;
use crate::util::json::Json;

pub use crate::serve::protocol::{error_event, MAX_LINE_BYTES};

/// One job as it crosses the wire: the batch-slot index plus everything
/// a worker needs to reconstruct the [`OwnedJob`] against its own
/// registry (space key, optimizer spec rendering, exact seed, group,
/// priority).
#[derive(Debug, Clone, PartialEq)]
pub struct WireJob {
    pub index: usize,
    pub space: String,
    pub opt: String,
    pub seed: u64,
    pub group: usize,
    pub priority: i64,
}

/// A parsed coordinator→worker request line.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkerRequest {
    /// Run a batch; the worker streams `row`/`job_failed` events per job
    /// and closes with a `done` event.
    Run { jobs: Vec<WireJob>, trace: bool },
    /// Cancel the batch running on this connection (cooperative:
    /// completed rows already sent stay valid).
    Cancel,
}

/// A parsed worker→coordinator event line.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkerEvent {
    /// First event on a run connection: the worker accepted the batch.
    Hello { threads: usize, jobs: usize },
    /// One completed job (streamed as soon as it finishes).
    Row { index: usize, group: usize, curve: Vec<f64> },
    /// One failed job (panic isolated worker-side).
    JobFailed { index: usize, error: String },
    /// Liveness pulse while a batch runs; any read-timeout on the
    /// coordinator side therefore means the worker is lost or stalled.
    Heartbeat,
    /// Batch finished (or wound down after a cancel): per-worker
    /// accounting, the worker's trace epoch, and its span buffer (empty
    /// unless the run requested tracing).
    Done { summary: JobsSummary, base_ns: u64, spans: Vec<Json> },
    /// Structured failure (bad request, unknown space, ...).
    Error { message: String },
}

/// Serialize one job for the wire. Fails (with the structured message
/// the coordinator reports) for genome specs, which cannot round-trip.
pub fn wire_job(index: usize, job: &OwnedJob) -> Result<Json, String> {
    if matches!(&*job.spec, OptimizerSpec::Genome(_)) {
        return Err(format!(
            "job {}: optimizer spec '{}' is a genome, which does not round-trip over the wire; \
             remote fleets accept registry specs only",
            index,
            job.spec.label()
        ));
    }
    let mut j = Json::obj();
    j.set("index", index);
    j.set("space", job.entry.key.id());
    j.set("opt", job.spec.to_string());
    j.set("seed", job.seed.to_string());
    j.set("group", job.group);
    j.set("priority", job.priority);
    Ok(j)
}

/// Build a `run` request line from pre-serialized [`wire_job`] objects.
pub fn run_request(jobs: Vec<Json>, trace: bool) -> Json {
    let mut j = Json::obj();
    j.set("cmd", "run");
    j.set("trace", trace);
    j.set("jobs", Json::Arr(jobs));
    j
}

/// Build the `cancel` request line.
pub fn cancel_request() -> Json {
    let mut j = Json::obj();
    j.set("cmd", "cancel");
    j
}

fn u64_string_field(j: &Json, key: &str) -> Result<u64, String> {
    let s = j.get(key).and_then(|v| v.as_str()).ok_or_else(|| {
        format!("'{}' must be a decimal string (64-bit values overflow JSON numbers)", key)
    })?;
    s.parse::<u64>().map_err(|e| format!("'{}' is not a u64: {}", key, e))
}

fn usize_field(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| format!("'{}' must be a non-negative integer", key))
}

fn parse_wire_job(j: &Json) -> Result<WireJob, String> {
    let index = usize_field(j, "index")?;
    let space = j
        .get("space")
        .and_then(|v| v.as_str())
        .ok_or_else(|| "job 'space' must be a string".to_string())?
        .to_string();
    let opt = j
        .get("opt")
        .and_then(|v| v.as_str())
        .ok_or_else(|| "job 'opt' must be a string".to_string())?
        .to_string();
    let seed = u64_string_field(j, "seed")?;
    let group = usize_field(j, "group")?;
    let priority = j.get("priority").and_then(|v| v.as_f64()).unwrap_or(0.0) as i64;
    Ok(WireJob { index, space, opt, seed, group, priority })
}

/// Parse one coordinator→worker request line. Every failure is a
/// client-visible message the worker wraps in an `error` event.
pub fn parse_request(line: &str) -> Result<WorkerRequest, String> {
    let j = Json::parse(line).map_err(|e| format!("bad request line: {}", e))?;
    let cmd = j
        .get("cmd")
        .and_then(|v| v.as_str())
        .ok_or_else(|| "request must carry a string 'cmd'".to_string())?;
    match cmd {
        "run" => {
            let trace = j.get("trace").map(|v| matches!(v, Json::Bool(true))).unwrap_or(false);
            let arr = j
                .get("jobs")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| "'jobs' must be an array".to_string())?;
            if arr.is_empty() {
                return Err("'jobs' must be non-empty".into());
            }
            let jobs = arr.iter().map(parse_wire_job).collect::<Result<Vec<_>, _>>()?;
            Ok(WorkerRequest::Run { jobs, trace })
        }
        "cancel" => Ok(WorkerRequest::Cancel),
        other => Err(format!("unknown cmd '{}'", other)),
    }
}

pub fn hello_event(threads: usize, jobs: usize) -> Json {
    let mut j = Json::obj();
    j.set("event", "hello");
    j.set("threads", threads);
    j.set("jobs", jobs);
    j
}

pub fn row_event(index: usize, group: usize, curve: &[f64]) -> Json {
    let mut j = Json::obj();
    j.set("event", "row");
    j.set("index", index);
    j.set("group", group);
    j.set("curve", curve.to_vec());
    j
}

pub fn job_failed_event(index: usize, error: &str) -> Json {
    let mut j = Json::obj();
    j.set("event", "job_failed");
    j.set("index", index);
    j.set("error", error);
    j
}

pub fn heartbeat_event() -> Json {
    let mut j = Json::obj();
    j.set("event", "heartbeat");
    j
}

pub fn done_event(summary: &JobsSummary, base_ns: u64, spans: Json) -> Json {
    let mut j = Json::obj();
    j.set("event", "done");
    j.set("jobs", summary.to_json());
    j.set("base_ns", base_ns.to_string());
    j.set("spans", spans);
    j
}

fn summary_from_json(j: &Json) -> Result<JobsSummary, String> {
    Ok(JobsSummary {
        completed: usize_field(j, "completed")?,
        cancelled: usize_field(j, "cancelled")?,
        failed: usize_field(j, "failed")?,
        cost_us: usize_field(j, "cost_us")? as u64,
    })
}

/// Parse one worker→coordinator event line. A parse failure means the
/// worker is speaking garbage — the runner treats it as worker loss.
pub fn parse_event(line: &str) -> Result<WorkerEvent, String> {
    let mut j = Json::parse(line).map_err(|e| format!("bad event line: {}", e))?;
    let event = j
        .get("event")
        .and_then(|v| v.as_str())
        .ok_or_else(|| "event line must carry a string 'event'".to_string())?
        .to_string();
    match event.as_str() {
        "hello" => Ok(WorkerEvent::Hello {
            threads: usize_field(&j, "threads")?,
            jobs: usize_field(&j, "jobs")?,
        }),
        "row" => {
            let index = usize_field(&j, "index")?;
            let group = usize_field(&j, "group")?;
            let arr = j
                .get("curve")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| "row 'curve' must be an array".to_string())?;
            let curve = arr
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| "row 'curve' must hold numbers".to_string()))
                .collect::<Result<Vec<f64>, _>>()?;
            Ok(WorkerEvent::Row { index, group, curve })
        }
        "job_failed" => Ok(WorkerEvent::JobFailed {
            index: usize_field(&j, "index")?,
            error: j
                .get("error")
                .and_then(|v| v.as_str())
                .unwrap_or("unspecified worker-side failure")
                .to_string(),
        }),
        "heartbeat" => Ok(WorkerEvent::Heartbeat),
        "done" => {
            let summary = summary_from_json(
                j.get("jobs").ok_or_else(|| "done event needs a 'jobs' summary".to_string())?,
            )?;
            let base_ns = u64_string_field(&j, "base_ns")?;
            let spans = match j.remove("spans") {
                Some(Json::Arr(spans)) => spans,
                _ => Vec::new(),
            };
            Ok(WorkerEvent::Done { summary, base_ns, spans })
        }
        "error" => Ok(WorkerEvent::Error {
            message: j
                .get("message")
                .and_then(|v| v.as_str())
                .unwrap_or("unspecified worker error")
                .to_string(),
        }),
        other => Err(format!("unknown event '{}'", other)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CacheKey, CacheRegistry};
    use std::sync::Arc;

    fn owned_job(seed: u64) -> OwnedJob {
        let entry = CacheRegistry::global().entry(CacheKey::parse("convolution@A4000").unwrap());
        OwnedJob {
            entry,
            spec: Arc::new(OptimizerSpec::parse("sa").unwrap()),
            seed,
            group: 3,
            priority: 0,
        }
    }

    #[test]
    fn run_request_round_trips_with_full_u64_seeds() {
        // A seed far beyond 2^53: exact only because it rides as a string.
        let seed = u64::MAX - 12345;
        let wire = wire_job(7, &owned_job(seed)).expect("registry specs serialize");
        let line = run_request(vec![wire], true).to_string();
        let parsed = parse_request(&line).expect("round trip");
        match parsed {
            WorkerRequest::Run { jobs, trace } => {
                assert!(trace);
                assert_eq!(jobs.len(), 1);
                assert_eq!(jobs[0].index, 7);
                assert_eq!(jobs[0].space, "convolution@A4000");
                assert_eq!(jobs[0].opt, "sa");
                assert_eq!(jobs[0].seed, seed, "seed must survive the wire bit-exactly");
                assert_eq!(jobs[0].group, 3);
                assert_eq!(jobs[0].priority, 0);
            }
            other => panic!("expected run, got {:?}", other),
        }
        assert_eq!(parse_request(&cancel_request().to_string()), Ok(WorkerRequest::Cancel));
    }

    #[test]
    fn genome_jobs_are_rejected_at_serialization() {
        let mut job = owned_job(1);
        let genome = crate::llamea::Genome::hybrid_vndx_like();
        job.spec = Arc::new(OptimizerSpec::Genome(genome));
        let err = wire_job(0, &job).expect_err("genomes cannot round-trip");
        assert!(err.contains("genome"), "{}", err);
        assert!(err.contains("job 0"), "{}", err);
    }

    #[test]
    fn events_round_trip() {
        let curve = vec![1.5, f64::MIN_POSITIVE, 2.25e-300];
        let row = row_event(4, 2, &curve).to_string();
        assert_eq!(parse_event(&row), Ok(WorkerEvent::Row { index: 4, group: 2, curve }));

        let hello = hello_event(8, 20).to_string();
        assert_eq!(parse_event(&hello), Ok(WorkerEvent::Hello { threads: 8, jobs: 20 }));

        assert_eq!(parse_event(&heartbeat_event().to_string()), Ok(WorkerEvent::Heartbeat));

        let failed = job_failed_event(9, "boom").to_string();
        assert_eq!(
            parse_event(&failed),
            Ok(WorkerEvent::JobFailed { index: 9, error: "boom".into() })
        );

        let summary =
            JobsSummary { completed: 5, cancelled: 1, failed: 0, cost_us: 123_456 };
        let base_ns = u64::MAX / 3;
        let done = done_event(&summary, base_ns, Json::Arr(Vec::new())).to_string();
        match parse_event(&done).expect("done parses") {
            WorkerEvent::Done { summary: s, base_ns: b, spans } => {
                assert_eq!(s.completed, 5);
                assert_eq!(s.cancelled, 1);
                assert_eq!(s.failed, 0);
                assert_eq!(s.cost_us, 123_456);
                assert_eq!(b, base_ns, "base_ns must survive the wire bit-exactly");
                assert!(spans.is_empty());
            }
            other => panic!("expected done, got {:?}", other),
        }

        let err = error_event("no such space").to_string();
        assert_eq!(parse_event(&err), Ok(WorkerEvent::Error { message: "no such space".into() }));
    }

    #[test]
    fn malformed_lines_yield_messages_not_panics() {
        for bad in [
            "{not json",
            "[]",
            r#"{"cmd":"warp"}"#,
            r#"{"cmd":"run","jobs":[]}"#,
            r#"{"cmd":"run","jobs":[{"index":0}]}"#,
            // Numeric seeds are rejected: they would silently lose bits.
            r#"{"cmd":"run","jobs":[{"index":0,"space":"a@b","opt":"sa","seed":7,"group":0}]}"#,
            r#"{"cmd":"run","jobs":[{"index":0,"space":"a@b","opt":"sa","seed":"x","group":0}]}"#,
        ] {
            assert!(parse_request(bad).is_err(), "{} must be rejected", bad);
        }
        for bad in [
            "{not json",
            r#"{"event":"comet"}"#,
            r#"{"event":"row","index":0,"group":0,"curve":["a"]}"#,
            r#"{"event":"done","jobs":{"completed":1},"base_ns":"0"}"#,
            r#"{"event":"done","jobs":{"completed":1,"cancelled":0,"failed":0,"cost_us":0},"base_ns":9}"#,
        ] {
            assert!(parse_event(bad).is_err(), "{} must be rejected", bad);
        }
    }
}
