//! Shared utilities: PRNG, statistics, JSON/table rendering, property tests,
//! error-context plumbing, cooperative cancellation (including the SIGINT
//! bridge), and the process-wide parallelism primitives.
//!
//! The offline build environment provides no `rand`, `serde`, `criterion`,
//! `proptest` or `anyhow`; these modules are small, tested substitutes (see
//! DESIGN.md §3).

pub mod cancel;
pub mod error;
pub mod json;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod signal;
pub mod stats;
pub mod table;
