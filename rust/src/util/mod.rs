//! Shared utilities: PRNG, statistics, JSON/table rendering, property tests.
//!
//! The offline build environment provides no `rand`, `serde`, `criterion` or
//! `proptest`; these modules are small, tested substitutes (see DESIGN.md §3).

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
