//! Plain-text / markdown / CSV table rendering for experiment reports.
//!
//! Every `llamea-kt experiment ...` subcommand renders its paper table or
//! figure data through this module so stdout, EXPERIMENTS.md and the CSV
//! result files stay consistent.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Render as column-aligned plain text (for terminal output).
    pub fn to_text(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
            out.push_str(&"=".repeat(self.title.len()));
            out.push('\n');
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180 quoting where needed).
    pub fn to_csv(&self) -> String {
        let quote = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| quote(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals, stripping `-0.000`.
pub fn f(x: f64, decimals: usize) -> String {
    let s = format!("{:.*}", decimals, x);
    if s.starts_with('-') && s[1..].chars().all(|c| c == '0' || c == '.') {
        s[1..].to_string()
    } else {
        s
    }
}

/// Format a signed delta, e.g. `+0.132` / `-0.019`.
pub fn delta(x: f64, decimals: usize) -> String {
    if x >= 0.0 {
        format!("+{}", f(x, decimals))
    } else {
        f(x, decimals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x".into(), "1".into()]);
        t
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| x | 1 |"));
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["x,y".into()]);
        assert_eq!(t.to_csv(), "a\n\"x,y\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(0.12345, 3), "0.123");
        assert_eq!(f(-0.0001, 3), "0.000");
        assert_eq!(delta(0.132, 3), "+0.132");
        assert_eq!(delta(-0.019, 3), "-0.019");
    }

    #[test]
    fn text_alignment() {
        let txt = sample().to_text();
        assert!(txt.contains("a  b"));
    }
}
