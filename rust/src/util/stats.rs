//! Descriptive statistics used by the evaluation methodology and reports.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile of *unsorted* data, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Linear-interpolated percentile of pre-sorted ascending data.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 100.0);
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median of unsorted data.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Half-width of the 95% normal-approximation confidence interval of the
/// mean (1.96 * sigma / sqrt(n)); the shaded bands of Figs 6 and 8.
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.959964 * std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Index of the minimum (first on ties); None for empty input.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

/// Running minimum (prefix-min) of a sequence.
pub fn running_min(xs: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut best = f64::INFINITY;
    for &x in xs {
        best = best.min(x);
        out.push(best);
    }
    out
}

/// Mean of per-position values across equal-length rows (curve aggregation,
/// Eq. (3) inner sum). Panics if rows have differing lengths.
pub fn mean_curve(rows: &[Vec<f64>]) -> Vec<f64> {
    if rows.is_empty() {
        return Vec::new();
    }
    let n = rows[0].len();
    let mut out = vec![0.0; n];
    for row in rows {
        assert_eq!(row.len(), n, "curve length mismatch");
        for (o, v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    for o in &mut out {
        *o /= rows.len() as f64;
    }
    out
}

/// Per-position 95% CI half-widths across rows.
pub fn ci95_curve(rows: &[Vec<f64>]) -> Vec<f64> {
    if rows.is_empty() {
        return Vec::new();
    }
    let n = rows[0].len();
    (0..n)
        .map(|i| {
            let col: Vec<f64> = rows.iter().map(|r| r[i]).collect();
            ci95_half_width(&col)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn running_min_monotone() {
        let r = running_min(&[5.0, 3.0, 4.0, 1.0, 2.0]);
        assert_eq!(r, vec![5.0, 3.0, 3.0, 1.0, 1.0]);
    }

    #[test]
    fn curve_aggregation() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(mean_curve(&rows), vec![2.0, 3.0]);
        assert_eq!(ci95_curve(&rows).len(), 2);
    }

    #[test]
    fn argmin_handles_nan() {
        assert_eq!(argmin(&[f64::NAN, 2.0, 1.0]), Some(2));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..1000).map(|i| (i % 10) as f64).collect();
        assert!(ci95_half_width(&b) < ci95_half_width(&a));
    }
}
