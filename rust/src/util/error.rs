//! Minimal error-context plumbing for the runtime layer.
//!
//! The offline build environment ships no registry, so the `anyhow` crate
//! the measured path originally leaned on is unavailable; this module is a
//! drop-in subset: a string-backed [`Error`], a [`Result`] alias, the
//! [`Context`] extension trait for `Result`/`Option`, and the `bail!` /
//! `format_err!` macros. Like the rest of `util`, it is dependency-free.

use std::fmt;

/// Best-effort text of a caught panic payload (`&str` or `String`
/// payloads — everything `panic!` produces — else a placeholder). Shared
/// by the executor's per-job isolation and the proptest harness.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// A chain-formatted error: the context message plus its source, rendered
/// as `context: source` (one level is enough for the runtime layer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error(String);

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error(msg.to_string())
    }

    fn wrap(self, context: impl fmt::Display) -> Error {
        Error(format!("{}: {}", context, self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style message attachment for fallible values.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(msg))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! format_err {
    ($e:expr) => { $crate::util::error::Error::msg($e) };
    ($fmt:literal, $($arg:tt)*) => { $crate::util::error::Error::msg(format!($fmt, $($arg)*)) };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::format_err!($($arg)*)) };
}

pub use crate::{bail, format_err};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("bad value {}", 7)
    }

    #[test]
    fn bail_and_display() {
        assert_eq!(fails().unwrap_err().to_string(), "bad value 7");
    }

    #[test]
    fn context_chains_messages() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("opening manifest").unwrap_err();
        assert!(e.to_string().starts_with("opening manifest: "), "{}", e);
        let o: Option<u32> = None;
        assert_eq!(
            o.with_context(|| format!("missing {}", "x")).unwrap_err().to_string(),
            "missing x"
        );
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/nonexistent/llamea-kt")?)
        }
        assert!(read().is_err());
    }
}
