//! Cooperative cancellation: a cheap, cloneable token shared between a
//! controller (the executor, a CLI signal handler, a progress consumer)
//! and the workers it may want to stop.
//!
//! Cancellation is *cooperative*: setting the token never interrupts
//! anything by force. Workers poll [`CancelToken::is_cancelled`] at their
//! natural check sites — for tuning runs that is
//! [`TuningContext::budget_exhausted`](crate::tuning::TuningContext::budget_exhausted)
//! between evaluations — and wind down on their own. A run that observes
//! the token mid-flight is reported as cancelled (its partial output is
//! discarded, never mixed into completed results); a run that finishes
//! without ever observing it is a normal completion, bit-identical to the
//! same run without a token. That asymmetry is what makes cancellation
//! deterministic at the result level: *which* jobs complete may depend on
//! timing, but every completed job's output is exactly its drain-all
//! counterpart.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A shared cancellation flag. Clones observe the same flag; `Default`
/// yields a fresh, un-cancelled token.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; never blocks. All clones observe
    /// the flag on their next [`Self::is_cancelled`] poll.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clones_share_the_flag() {
        let t = CancelToken::new();
        let c = t.clone();
        assert!(!t.is_cancelled());
        assert!(!c.is_cancelled());
        c.cancel();
        assert!(t.is_cancelled());
        assert!(c.is_cancelled());
        // Idempotent.
        t.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn fresh_tokens_are_independent() {
        let a = CancelToken::new();
        let b = CancelToken::default();
        a.cancel();
        assert!(!b.is_cancelled());
    }

    #[test]
    fn flag_crosses_threads() {
        let t = CancelToken::new();
        let c = t.clone();
        std::thread::scope(|s| {
            s.spawn(move || c.cancel());
        });
        assert!(t.is_cancelled());
    }
}
