//! Mini property-based testing framework (offline `proptest` substitute).
//!
//! Usage:
//! ```ignore
//! check("neighbors are symmetric", 256, |rng| {
//!     let i = rng.below(space.len());
//!     ... assertions ...
//! });
//! ```
//! Each case gets a deterministic per-case RNG; on failure the panic message
//! includes the reproducing case seed so `check_one(seed, ...)` replays it.

use super::rng::Rng;

/// Run `cases` randomized cases of `property`. Panics (with the failing
/// case seed) on the first assertion failure inside `property`.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u64, property: F) {
    check_seeded(name, 0xC0FFEE, cases, property)
}

/// As [`check`] but with an explicit base seed.
pub fn check_seeded<F: Fn(&mut Rng)>(name: &str, base_seed: u64, cases: u64, property: F) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            property(&mut rng);
        }));
        if let Err(err) = result {
            let msg = super::error::panic_message(err.as_ref());
            panic!(
                "property '{}' failed at case {}/{} (replay: check_one({:#x})): {}",
                name, case, cases, seed, msg
            );
        }
    }
}

/// Replay a single failing case by its seed.
pub fn check_one<F: Fn(&mut Rng)>(seed: u64, property: F) {
    let mut rng = Rng::new(seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0u64;
        check("count", 10, |_| {});
        // `check` takes Fn, so count via a Cell instead.
        let counter = std::cell::Cell::new(0u64);
        check("count2", 10, |_| counter.set(counter.get() + 1));
        n += counter.get();
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check("fails", 10, |rng| {
            assert!(rng.f64() < 0.5, "too big");
        });
    }

    #[test]
    fn deterministic_per_case() {
        let a = std::cell::Cell::new(0u64);
        check("det", 5, |rng| a.set(a.get() ^ rng.next_u64()));
        let b = std::cell::Cell::new(0u64);
        check("det", 5, |rng| b.set(b.get() ^ rng.next_u64()));
        assert_eq!(a.get(), b.get());
    }
}
