//! SIGINT → [`CancelToken`] bridge for the CLI and the serve daemon.
//!
//! [`install_sigint`] registers a process-wide token that a `SIGINT`
//! (Ctrl-C) fires. The handler does exactly one async-signal-safe thing —
//! a relaxed-to-SeqCst atomic store through [`CancelToken::cancel`] — and
//! then resets the disposition to the default, so a **second** Ctrl-C
//! kills the process the ordinary way. That two-stage shape is what makes
//! completed-prefix reports safe to offer: the first interrupt asks every
//! running job to wind down cooperatively (each completed curve stays
//! bit-identical to its drain-all counterpart; partial trajectories are
//! discarded, never truncated-and-kept), and the escape hatch for a hung
//! run is still one keystroke away.
//!
//! The binding is registered at most once per process (`OnceLock`);
//! later calls return a clone of the same token, so `coordinate`, `sweep`
//! and the daemon can all observe one interrupt line. On non-Unix targets
//! this module is a no-op that still hands out the token — cancellation
//! then simply never fires from a signal.

use std::sync::OnceLock;

use super::cancel::CancelToken;

static SIGINT_TOKEN: OnceLock<CancelToken> = OnceLock::new();

#[cfg(unix)]
mod sys {
    use std::os::raw::c_int;
    pub const SIGINT: c_int = 2;
    /// `SIG_DFL` is the null handler pointer in every libc ABI we target.
    pub const SIG_DFL: usize = 0;
    extern "C" {
        /// ISO C `signal(2)`: good enough here — the handler performs a
        /// single atomic store, needs no siginfo, and immediately
        /// reinstalls the default disposition.
        pub fn signal(signum: c_int, handler: usize) -> usize;
    }
}

#[cfg(unix)]
extern "C" fn on_sigint(_signum: std::os::raw::c_int) {
    if let Some(token) = SIGINT_TOKEN.get() {
        token.cancel();
    }
    // Restore the default disposition so a second Ctrl-C terminates the
    // process even if cooperative wind-down has stalled.
    unsafe {
        sys::signal(sys::SIGINT, sys::SIG_DFL);
    }
}

/// Install the process-wide SIGINT handler (idempotent) and return the
/// token it fires. Callers clone the token into their executor so a
/// Ctrl-C cancels the in-flight batch cooperatively.
pub fn install_sigint() -> CancelToken {
    let mut first = false;
    let token = SIGINT_TOKEN.get_or_init(|| {
        first = true;
        CancelToken::new()
    });
    if first {
        #[cfg(unix)]
        unsafe {
            sys::signal(sys::SIGINT, on_sigint as extern "C" fn(std::os::raw::c_int) as usize);
        }
    }
    token.clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_is_idempotent_and_returns_one_shared_token() {
        let a = install_sigint();
        let b = install_sigint();
        assert!(!a.is_cancelled());
        // Both handles observe the same underlying flag.
        a.cancel();
        assert!(b.is_cancelled());
        // NOTE: we never raise a real SIGINT in tests — the libtest
        // harness shares the process — so the handler body itself is
        // exercised only manually; the test pins the registration
        // plumbing around it.
    }
}
