//! Deterministic, seedable PRNG (SplitMix64 seeding + xoshiro256** core).
//!
//! The offline environment has no `rand` crate; this is a faithful
//! implementation of the reference algorithms (Blackman & Vigna). Every
//! stochastic component in the library (optimizers, noise models, the mock
//! LLM) draws from a forked child of a single experiment seed, which makes
//! whole experiments bit-reproducible.

/// xoshiro256** PRNG with convenience distributions.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent child stream (for parallel runs / components).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our sizes (n << 2^64): multiply-shift.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Lognormal with given median (= exp(mu)) and sigma.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Choose a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            return idx;
        }
        // Sparse: rejection sampling with a small set.
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.below(n);
            if seen.insert(i) {
                out.push(i);
            }
        }
        out
    }

    /// Roulette-wheel selection over non-negative weights; returns an index.
    pub fn roulette(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// The SplitMix64 finalizer: a full-avalanche bijection on `u64`, used to
/// decorrelate structured seed inputs (grid coordinates, meta-config
/// ordinals). Note `avalanche(0) == 0`: the zero ordinal is a fixed point,
/// which `hypertune` relies on so that meta-config 0 inherits the caller's
/// base seed unchanged (the grid-of-one ≡ `coordinate` equivalence).
#[inline]
pub fn avalanche(mut h: u64) -> u64 {
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
    h ^ (h >> 31)
}

/// Stable 64-bit hash of arbitrary bytes (FNV-1a), for deterministic
/// config-keyed noise in the performance models.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash a (salt, u16-slice) pair — the config-identity hash used by the
/// simulator's rugged-terrain term.
pub fn hash_config(salt: u64, cfg: &[u16]) -> u64 {
    let mut h = 0xcbf29ce484222325u64 ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
    for &v in cfg {
        h ^= v as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    // Final avalanche.
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51afd7ed558ccd);
    h ^ (h >> 33)
}

/// Map a u64 hash to a deterministic standard normal (inverse-CDF approx).
pub fn hash_normal(h: u64) -> f64 {
    let u = ((h >> 11) as f64 + 0.5) * (1.0 / (1u64 << 53) as f64);
    inverse_normal_cdf(u)
}

/// Acklam's inverse normal CDF approximation (|eps| < 1.15e-9).
pub fn inverse_normal_cdf(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const PLOW: f64 = 0.02425;
    if p < PLOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - PLOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(7);
        let mut c1 = a.fork(1);
        let mut c2 = a.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sum2 += z * z;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn inverse_cdf_symmetry() {
        assert!((inverse_normal_cdf(0.5)).abs() < 1e-8);
        assert!((inverse_normal_cdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((inverse_normal_cdf(0.025) + 1.959964).abs() < 1e-4);
    }

    #[test]
    fn roulette_prefers_heavy_weights() {
        let mut r = Rng::new(1);
        let w = [0.0, 0.0, 10.0, 0.0];
        for _ in 0..50 {
            assert_eq!(r.roulette(&w), 2);
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(100, 20);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 20);
        let all = r.sample_indices(5, 10);
        assert_eq!(all.len(), 5);
    }

    #[test]
    fn hash_config_distinguishes() {
        assert_ne!(hash_config(1, &[1, 2, 3]), hash_config(1, &[1, 2, 4]));
        assert_ne!(hash_config(1, &[1, 2, 3]), hash_config(2, &[1, 2, 3]));
        assert_eq!(hash_config(1, &[1, 2, 3]), hash_config(1, &[1, 2, 3]));
    }
}
