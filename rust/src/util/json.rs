//! Minimal JSON writer + reader (no serde in the offline environment).
//!
//! Only what the results pipeline needs: objects, arrays, strings, numbers,
//! booleans, with stable key order (insertion order) so result files diff
//! cleanly between runs. [`Json::parse`] is the matching reader — the
//! `merge` subcommand uses it to reassemble shard partial reports — and
//! round-trips every value this writer emits exactly: numbers are printed
//! with Rust's shortest-round-trip `f64` formatting, so
//! `parse(x.to_string())` recovers bit-identical values.

use std::fmt::Write as _;

/// A JSON value with insertion-ordered object keys.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key on an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value.into();
                } else {
                    pairs.push((key.to_string(), value.into()));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(xs) => xs.push(value.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    /// Parse a JSON document. Accepts exactly the standard grammar (objects,
    /// arrays, strings with escapes, numbers, literals); numbers become
    /// `f64` via Rust's `str::parse`, which inverts both the integer and the
    /// shortest-round-trip float forms the writer emits, so values
    /// round-trip bit-exactly. `\uXXXX` escapes outside the BMP (surrogate
    /// pairs) are rejected — the writer never emits them.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Look up a key on an object (`None` for missing keys / non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Remove and return a key from an object, preserving the order of the
    /// remaining keys (`None` for missing keys / non-objects).
    pub fn remove(&mut self, key: &str) -> Option<Json> {
        match self {
            Json::Obj(pairs) => {
                let i = pairs.iter().position(|(k, _)| k == key)?;
                Some(pairs.remove(i).1)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value that is a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && *x == x.trunc() && *x < 9e15 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(w) = indent {
                out.push('\n');
                for _ in 0..w * d {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.depth += 1;
        if self.depth > 128 {
            return Err("nesting deeper than 128 levels".into());
        }
        let v = match self.b.get(self.i) {
            None => Err("unexpected end of input".into()),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
        };
        self.depth -= 1;
        v
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while matches!(
            self.b.get(self.i),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number {:?} at byte {}", text, start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.i += 1; // opening quote
        let mut out = Vec::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    // Input is &str, so verbatim bytes are valid UTF-8 and
                    // escape sequences push encoded chars.
                    return String::from_utf8(out).map_err(|_| "invalid UTF-8".into());
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = *self.b.get(self.i).ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'n' => out.push(b'\n'),
                        b't' => out.push(b'\t'),
                        b'r' => out.push(b'\r'),
                        b'b' => out.push(0x08),
                        b'f' => out.push(0x0C),
                        b'u' => {
                            let code = self.hex4()?;
                            let c = char::from_u32(code)
                                .ok_or_else(|| format!("unsupported \\u{:04x} escape", code))?;
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                        }
                        _ => return Err(format!("invalid escape at byte {}", self.i - 1)),
                    }
                }
                Some(&c) => {
                    out.push(c);
                    self.i += 1;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let text = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| "invalid \\u escape".to_string())?;
        self.i += 4;
        u32::from_str_radix(text, 16).map_err(|_| "invalid \\u escape".into())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1; // '['
        let mut xs = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.i += 1; // '{'
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            if self.b.get(self.i) != Some(&b'"') {
                return Err(format!("expected object key at byte {}", self.i));
            }
            let key = self.string()?;
            self.skip_ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(format!("expected ':' at byte {}", self.i));
            }
            self.i += 1;
            self.skip_ws();
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

/// Read and parse a JSON file (the reader used by `merge`).
pub fn read_file(path: &std::path::Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {}", path.display(), e))?;
    Json::parse(&text).map_err(|e| format!("{}: {}", path.display(), e))
}

/// Write a value pretty-printed to `path` (creating parent directories) —
/// the one writer behind `coordinate --out` and `sweep --out`, so every
/// result file shares the same stable, diff-friendly serialization.
pub fn write_file(path: &std::path::Path, value: &Json) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, value.to_pretty())
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(xs: Vec<f64>) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }
}
impl From<Vec<Json>> for Json {
    fn from(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let mut o = Json::obj();
        o.set("name", "gemm").set("score", 0.719).set("n", 24usize);
        o.set("curve", vec![0.0, 0.5, 1.0]);
        let s = o.to_string();
        assert_eq!(
            s,
            r#"{"name":"gemm","score":0.719,"n":24,"curve":[0,0.5,1]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn set_overwrites() {
        let mut o = Json::obj();
        o.set("k", 1.0).set("k", 2.0);
        assert_eq!(o.to_string(), r#"{"k":2}"#);
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_has_newlines() {
        let mut o = Json::obj();
        o.set("a", 1.0);
        assert!(o.to_pretty().contains('\n'));
    }

    #[test]
    fn parse_inverts_writer() {
        let mut o = Json::obj();
        o.set("name", "gemm\"x\\y\n").set("score", 0.7193428711816438);
        o.set("n", 24usize).set("neg", -1.5e-9).set("flag", true);
        o.set("none", Json::Null);
        o.set("curve", vec![0.0, 0.5, 123456789.25]);
        for text in [o.to_string(), o.to_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), o);
        }
    }

    #[test]
    fn parse_roundtrips_f64_bits() {
        for x in [0.1 + 0.2, 1.0 / 3.0, f64::MIN_POSITIVE, 1e300, -2.5] {
            let text = Json::Num(x).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{text}");
        }
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse(r#""a\u0041\u00e9é""#).unwrap(),
            Json::Str("aAéé".into())
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"\\q\"", "nan"] {
            assert!(Json::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn get_and_remove() {
        let mut o = Json::parse(r#"{"a":1,"b":2,"c":3}"#).unwrap();
        assert_eq!(o.get("b").and_then(Json::as_f64), Some(2.0));
        assert_eq!(o.remove("b"), Some(Json::Num(2.0)));
        assert_eq!(o.get("b"), None);
        assert_eq!(o.to_string(), r#"{"a":1,"c":3}"#);
    }
}
