//! Minimal JSON writer (no serde in the offline environment).
//!
//! Only what the results pipeline needs: objects, arrays, strings, numbers,
//! booleans, with stable key order (insertion order) so result files diff
//! cleanly between runs.

use std::fmt::Write as _;

/// A JSON value with insertion-ordered object keys.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key on an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value.into();
                } else {
                    pairs.push((key.to_string(), value.into()));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(xs) => xs.push(value.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(w) = indent {
                out.push('\n');
                for _ in 0..w * d {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Write a value pretty-printed to `path` (creating parent directories) —
/// the one writer behind `coordinate --out` and `sweep --out`, so every
/// result file shares the same stable, diff-friendly serialization.
pub fn write_file(path: &std::path::Path, value: &Json) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, value.to_pretty())
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(xs: Vec<f64>) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }
}
impl From<Vec<Json>> for Json {
    fn from(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let mut o = Json::obj();
        o.set("name", "gemm").set("score", 0.719).set("n", 24usize);
        o.set("curve", vec![0.0, 0.5, 1.0]);
        let s = o.to_string();
        assert_eq!(
            s,
            r#"{"name":"gemm","score":0.719,"n":24,"curve":[0,0.5,1]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn set_overwrites() {
        let mut o = Json::obj();
        o.set("k", 1.0).set("k", 2.0);
        assert_eq!(o.to_string(), r#"{"k":2}"#);
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_has_newlines() {
        let mut o = Json::obj();
        o.set("a", 1.0);
        assert!(o.to_pretty().contains('\n'));
    }
}
