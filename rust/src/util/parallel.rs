//! Process-wide parallelism width and deterministic chunked fan-out.
//!
//! Two things live here, shared by every layer that spawns threads:
//!
//! - The **default width**: one process-global knob (0 = size to the
//!   machine) set by the CLI's `--threads` and consulted by the
//!   coordinator's [`Scheduler`](crate::coordinator::Scheduler) *and* by
//!   the construction paths below it (space enumeration, neighbor-graph
//!   and cache builds). Width never affects results, only concurrency.
//! - [`map_chunks`]: order-preserving chunked fan-out. The index range is
//!   split into contiguous chunks, workers claim chunks off an atomic
//!   cursor, and the per-chunk outputs are returned **in chunk order** —
//!   so a caller that concatenates them gets output byte-identical to a
//!   serial loop, for any width. This is the primitive behind the
//!   determinism contract of parallel space and cache construction
//!   (`rust/tests/integration_hotpath.rs` pins it).

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Process-wide default width (0 = size to the machine). Set once by the
/// CLI's `--threads`, read by [`default_width`].
static DEFAULT_WIDTH: AtomicUsize = AtomicUsize::new(0);

/// Set the process default width (`None` restores machine-sized).
pub fn set_default_width(threads: Option<usize>) {
    DEFAULT_WIDTH.store(threads.unwrap_or(0), Ordering::Relaxed);
}

/// The effective default width: the CLI override if set, otherwise the
/// machine's available parallelism (min 1).
pub fn default_width() -> usize {
    match DEFAULT_WIDTH.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        n => n.max(1),
    }
}

/// Split `0..n` into contiguous chunks of at most `chunk_size` elements.
fn chunk_ranges(n: usize, chunk_size: usize) -> Vec<Range<usize>> {
    let chunk_size = chunk_size.max(1);
    let mut out = Vec::with_capacity(n.div_ceil(chunk_size));
    let mut start = 0;
    while start < n {
        let end = (start + chunk_size).min(n);
        out.push(start..end);
        start = end;
    }
    out
}

/// Apply `f` to contiguous chunks of `0..n` on up to `width` workers and
/// return the per-chunk outputs in chunk order.
///
/// `f` must be a pure function of its range for the determinism contract
/// to hold; under that condition the result is identical for every
/// `width`, including 1 (which runs inline without spawning).
///
/// `T: Send + Sync` because the result slots (`OnceLock<T>`) are shared
/// by reference across the scoped workers.
pub fn map_chunks_width<T, F>(n: usize, chunk_size: usize, width: usize, f: F) -> Vec<T>
where
    T: Send + Sync,
    F: Fn(Range<usize>) -> T + Sync,
{
    let chunks = chunk_ranges(n, chunk_size);
    let width = width.max(1).min(chunks.len());
    if width <= 1 {
        return chunks.into_iter().map(f).collect();
    }
    let slots: Vec<OnceLock<T>> = (0..chunks.len()).map(|_| OnceLock::new()).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..width {
            scope.spawn(|| loop {
                let c = next.fetch_add(1, Ordering::Relaxed);
                if c >= chunks.len() {
                    break;
                }
                let value = f(chunks[c].clone());
                if slots[c].set(value).is_err() {
                    panic!("chunk slot written twice");
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("map_chunks finished with a missing chunk"))
        .collect()
}

/// The "wide" width the test suite's determinism checks run at:
/// `LLAMEA_KT_TEST_THREADS` when set, else `default`. CI runs the
/// integration suite with the variable pinned to 1 and 8 (a matrix
/// independent of libtest's `--test-threads`), so width-determinism
/// regressions fail there, not just on a many-core dev box.
pub fn test_width(default: usize) -> usize {
    std::env::var("LLAMEA_KT_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(default)
}

/// [`map_chunks_width`] at the process default width.
pub fn map_chunks<T, F>(n: usize, chunk_size: usize, f: F) -> Vec<T>
where
    T: Send + Sync,
    F: Fn(Range<usize>) -> T + Sync,
{
    map_chunks_width(n, chunk_size, default_width(), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range_in_order() {
        let r = chunk_ranges(10, 3);
        assert_eq!(r, vec![0..3, 3..6, 6..9, 9..10]);
        assert!(chunk_ranges(0, 3).is_empty());
        // chunk_size 0 is clamped to 1.
        assert_eq!(chunk_ranges(2, 0).len(), 2);
    }

    #[test]
    fn output_in_chunk_order_any_width() {
        let serial = map_chunks_width(1000, 7, 1, |r| r.sum::<usize>());
        for width in [2, 4, 16] {
            let parallel = map_chunks_width(1000, 7, width, |r| r.sum::<usize>());
            assert_eq!(serial, parallel, "width {}", width);
        }
    }

    #[test]
    fn concatenation_equals_serial_loop() {
        let chunks = map_chunks_width(257, 16, 8, |r| r.map(|i| i * i).collect::<Vec<_>>());
        let flat: Vec<usize> = chunks.into_iter().flatten().collect();
        let expected: Vec<usize> = (0..257).map(|i| i * i).collect();
        assert_eq!(flat, expected);
    }

    // NOTE: no set-and-read test of DEFAULT_WIDTH here — the process
    // global is shared with `coordinator::scheduler`'s
    // `width_is_clamped_and_default_is_settable`, which owns that assert;
    // a second mutating test in the same binary would race it under the
    // parallel test runner.
}
