//! Process-wide, dependency-free tracing & metrics recorder.
//!
//! Every layer of the pipeline — cache registry, persistent store,
//! streaming executor, hypertune meta-evals, tuning runs, and the serve
//! daemon — reports spans and counters through this module. It exists so
//! the questions the ROADMAP's budget-reallocation items need answered
//! ("where does tuning time actually go?", "what stalls the pool?") are
//! observable without attaching a debugger or grepping stderr.
//!
//! # Event model
//!
//! Three primitives:
//!
//! - **Spans** ([`span`] / [`span_at`]): an RAII guard measuring one
//!   delimited piece of work (`obs::span("cache.build").kv("id", ...)`),
//!   recorded on drop as a *complete* event — start, duration, thread,
//!   per-thread sequence number, and up to [`MAX_ARGS`] key/value tags.
//!   Closed-by-construction: a guard cannot leak an unclosed span into
//!   the trace. Spans feed both the trace buffer (when tracing) and a
//!   fixed-bucket latency histogram keyed by span name (when metrics
//!   are on).
//! - **Counters** ([`counter`]): monotonically increasing named totals
//!   (admission rejections, dedup hits, pool picks), aggregated in
//!   place — O(distinct names) memory, never per-event.
//! - **Symbols** ([`sym`]): dynamic strings (cache ids, optimizer
//!   labels) interned once to a small integer so recording an event
//!   never allocates; the string table is resolved at export.
//!
//! Timestamps are monotonic [`Instant`]s normalized to a process epoch
//! (pinned when recording is first enabled), exported as integer
//! nanoseconds. The canonical event order is `(epoch-ns, thread, seq)` —
//! [`export::chrome_trace`] sorts by exactly that key, so two traces of
//! the same run diff structurally.
//!
//! # Overhead contract
//!
//! - **Disabled** (the default): every entry point loads one relaxed
//!   atomic and returns. No clock read, no lock, no allocation, no
//!   thread-local registration. `bench_hotpath`'s `obs_overhead`
//!   section pins this.
//! - **Enabled**: events append to a per-thread shard — an
//!   uncontended `Mutex<Vec<Event>>` registered on first use (shards of
//!   exited threads are recycled, so the shard list is bounded by peak
//!   thread count, not thread churn). An event is a fixed-size struct;
//!   pushing one performs no per-event heap allocation beyond the
//!   buffer's amortized growth. Metrics aggregate in place (counters
//!   and fixed-bucket histograms), so a long-lived daemon can keep
//!   metrics on forever with bounded memory; only tracing accumulates
//!   per-event state.
//!
//! # Out-of-band invariant
//!
//! Observability is strictly write-only with respect to results: no
//! code path reads recorder state to make a scheduling, seeding, or
//! reporting decision, so report bytes are identical with tracing on or
//! off at any thread width (pinned in `rust/tests/integration_obs.rs`).
//! Wall-clock readings taken here ride only in traces, metrics, and
//! `Progress` events — never in reports.

pub mod export;

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::Instant;

const TRACE: u8 = 1;
const METRICS: u8 = 2;

/// Global mode word. The disabled hot path is a single relaxed load of
/// this atomic — nothing else.
static FLAGS: AtomicU8 = AtomicU8::new(0);

/// Is the trace buffer recording?
#[inline]
pub fn trace_on() -> bool {
    FLAGS.load(Ordering::Relaxed) & TRACE != 0
}

/// Are metrics (counters + histograms) aggregating?
#[inline]
pub fn metrics_on() -> bool {
    FLAGS.load(Ordering::Relaxed) & METRICS != 0
}

/// Is any recording enabled?
#[inline]
pub fn enabled() -> bool {
    FLAGS.load(Ordering::Relaxed) != 0
}

/// Set the recording mode. Pins the process epoch on first call so all
/// subsequently recorded timestamps share one origin.
pub fn enable(trace: bool, metrics: bool) {
    let _ = epoch();
    let bits = if trace { TRACE } else { 0 } | if metrics { METRICS } else { 0 };
    FLAGS.store(bits, Ordering::Relaxed);
}

/// Turn metrics aggregation on without touching the tracing bit (the
/// serve daemon keeps daemon-wide metrics live regardless of `--metrics`).
pub fn enable_metrics() {
    let _ = epoch();
    FLAGS.fetch_or(METRICS, Ordering::Relaxed);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process recording epoch. Public so the remote
/// fleet layer can renormalize worker-shipped span timestamps onto the
/// coordinator's clock (offset = coordinator dispatch ns − worker
/// `base_ns`); everything else should go through [`span`] / [`span_at`].
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Maximum key/value tags per event (fixed so events stay `Copy` and
/// recording never allocates).
pub const MAX_ARGS: usize = 4;

/// An interned dynamic string (see [`sym`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sym(u32);

/// One tag value. Dynamic strings must come in as [`Sym`]s.
#[derive(Debug, Clone, Copy)]
pub enum ArgValue {
    U64(u64),
    F64(f64),
    Str(&'static str),
    Sym(Sym),
}

impl From<u64> for ArgValue {
    fn from(x: u64) -> ArgValue {
        ArgValue::U64(x)
    }
}
impl From<usize> for ArgValue {
    fn from(x: usize) -> ArgValue {
        ArgValue::U64(x as u64)
    }
}
impl From<u32> for ArgValue {
    fn from(x: u32) -> ArgValue {
        ArgValue::U64(x as u64)
    }
}
impl From<i64> for ArgValue {
    fn from(x: i64) -> ArgValue {
        ArgValue::F64(x as f64)
    }
}
impl From<f64> for ArgValue {
    fn from(x: f64) -> ArgValue {
        ArgValue::F64(x)
    }
}
impl From<&'static str> for ArgValue {
    fn from(x: &'static str) -> ArgValue {
        ArgValue::Str(x)
    }
}
impl From<Sym> for ArgValue {
    fn from(x: Sym) -> ArgValue {
        ArgValue::Sym(x)
    }
}

const NO_ARG: (&str, ArgValue) = ("", ArgValue::U64(0));

/// One recorded event: a closed span (or instant, `dur_ns == 0`).
#[derive(Clone, Copy)]
pub(crate) struct Event {
    pub ns: u64,
    pub dur_ns: u64,
    pub name: &'static str,
    pub thread: u32,
    pub seq: u64,
    pub n_args: u8,
    pub args: [(&'static str, ArgValue); MAX_ARGS],
}

/// Fixed latency buckets (nanoseconds): decades from 1 µs to 10 s, plus
/// the implicit +Inf bucket. Fixed so histogram memory is constant and
/// Prometheus `le` labels are stable across runs.
pub(crate) const BUCKET_BOUNDS_NS: [u64; 8] = [
    1_000,
    10_000,
    100_000,
    1_000_000,
    10_000_000,
    100_000_000,
    1_000_000_000,
    10_000_000_000,
];

#[derive(Clone, Copy)]
pub(crate) struct Hist {
    pub buckets: [u64; BUCKET_BOUNDS_NS.len() + 1],
    pub count: u64,
    pub sum_ns: u64,
}

impl Hist {
    fn zero() -> Hist {
        Hist { buckets: [0; BUCKET_BOUNDS_NS.len() + 1], count: 0, sum_ns: 0 }
    }

    fn observe(&mut self, ns: u64) {
        let i = BUCKET_BOUNDS_NS
            .iter()
            .position(|&b| ns <= b)
            .unwrap_or(BUCKET_BOUNDS_NS.len());
        self.buckets[i] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
    }
}

/// Per-thread recording shard. Only its owner thread writes (export
/// takes the locks briefly), so the mutexes are effectively uncontended.
struct Shard {
    thread: u32,
    seq: AtomicU64,
    events: Mutex<Vec<Event>>,
    counters: Mutex<Vec<(&'static str, u64)>>,
    hists: Mutex<Vec<(&'static str, Hist)>>,
}

struct Shards {
    all: Vec<Arc<Shard>>,
    /// Shards whose owner thread exited, available for reuse so thread
    /// churn (e.g. one serve connection thread per client) does not grow
    /// the shard list without bound.
    free: Vec<Arc<Shard>>,
    next_thread: u32,
}

fn shards() -> &'static Mutex<Shards> {
    static SHARDS: OnceLock<Mutex<Shards>> = OnceLock::new();
    SHARDS.get_or_init(|| Mutex::new(Shards { all: Vec::new(), free: Vec::new(), next_thread: 0 }))
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Owner handle parked in a thread-local; returning the shard to the
/// free list on thread exit is what bounds the shard count.
struct LocalHandle(Arc<Shard>);

impl Drop for LocalHandle {
    fn drop(&mut self) {
        lock(shards()).free.push(Arc::clone(&self.0));
    }
}

thread_local! {
    static LOCAL: RefCell<Option<LocalHandle>> = const { RefCell::new(None) };
}

/// Run `f` against this thread's shard, registering one on first use.
/// Silently drops the record if the thread-local is already destroyed
/// (only possible from other TLS destructors, which never record).
fn with_shard(f: impl FnOnce(&Shard)) {
    let _ = LOCAL.try_with(|cell| {
        let mut cell = cell.borrow_mut();
        if cell.is_none() {
            let mut s = lock(shards());
            let shard = s.free.pop().unwrap_or_else(|| {
                let shard = Arc::new(Shard {
                    thread: s.next_thread,
                    seq: AtomicU64::new(0),
                    events: Mutex::new(Vec::new()),
                    counters: Mutex::new(Vec::new()),
                    hists: Mutex::new(Vec::new()),
                });
                s.next_thread += 1;
                s.all.push(Arc::clone(&shard));
                shard
            });
            *cell = Some(LocalHandle(shard));
        }
        f(&cell.as_ref().expect("shard registered above").0);
    });
}

struct Interner {
    map: HashMap<String, u32>,
    names: Vec<String>,
}

fn interner() -> &'static Mutex<Interner> {
    static INTERNER: OnceLock<Mutex<Interner>> = OnceLock::new();
    INTERNER.get_or_init(|| Mutex::new(Interner { map: HashMap::new(), names: Vec::new() }))
}

/// Intern a dynamic string so events can carry it without allocating.
/// Call only on enabled paths (gate on [`enabled`] first): interning
/// itself takes a lock and may allocate once per distinct string.
pub fn sym(s: &str) -> Sym {
    let mut int = lock(interner());
    if let Some(&id) = int.map.get(s) {
        return Sym(id);
    }
    let id = int.names.len() as u32;
    int.map.insert(s.to_string(), id);
    int.names.push(s.to_string());
    Sym(id)
}

/// Resolve an interned symbol back to its string (export-time only).
pub(crate) fn sym_name(s: Sym) -> String {
    lock(interner()).names.get(s.0 as usize).cloned().unwrap_or_default()
}

/// An in-flight span. Dropping it records the event; [`Span::kv`] /
/// [`Span::note`] attach tags (the builder form for construction-time
/// tags, the `&mut` form for outcomes known only at the end).
pub struct Span {
    active: bool,
    name: &'static str,
    start_ns: u64,
    n_args: u8,
    args: [(&'static str, ArgValue); MAX_ARGS],
}

/// Open a span starting now. When recording is off this is one relaxed
/// atomic load and a trivially droppable return value.
#[inline]
pub fn span(name: &'static str) -> Span {
    if FLAGS.load(Ordering::Relaxed) == 0 {
        return Span { active: false, name, start_ns: 0, n_args: 0, args: [NO_ARG; MAX_ARGS] };
    }
    Span { active: true, name, start_ns: now_ns(), n_args: 0, args: [NO_ARG; MAX_ARGS] }
}

/// Open a span retroactively, starting at `started` (e.g. a queue-wait
/// measured from enqueue time). Instants predating the process epoch
/// clamp to 0.
#[inline]
pub fn span_at(name: &'static str, started: Instant) -> Span {
    if FLAGS.load(Ordering::Relaxed) == 0 {
        return Span { active: false, name, start_ns: 0, n_args: 0, args: [NO_ARG; MAX_ARGS] };
    }
    let start_ns = started
        .checked_duration_since(epoch())
        .map_or(0, |d| d.as_nanos() as u64);
    Span { active: true, name, start_ns, n_args: 0, args: [NO_ARG; MAX_ARGS] }
}

impl Span {
    /// Attach a tag (builder form). Tags beyond [`MAX_ARGS`] are dropped.
    #[inline]
    pub fn kv(mut self, key: &'static str, value: impl Into<ArgValue>) -> Span {
        self.note(key, value);
        self
    }

    /// Attach a tag to a held guard (for outcomes known at completion).
    #[inline]
    pub fn note(&mut self, key: &'static str, value: impl Into<ArgValue>) {
        if !self.active {
            return;
        }
        if (self.n_args as usize) < MAX_ARGS {
            self.args[self.n_args as usize] = (key, value.into());
            self.n_args += 1;
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = now_ns();
        let dur_ns = end.saturating_sub(self.start_ns);
        let (name, start_ns, n_args, args) = (self.name, self.start_ns, self.n_args, self.args);
        let flags = FLAGS.load(Ordering::Relaxed);
        with_shard(|shard| {
            if flags & TRACE != 0 {
                let seq = shard.seq.fetch_add(1, Ordering::Relaxed);
                lock(&shard.events).push(Event {
                    ns: start_ns,
                    dur_ns,
                    name,
                    thread: shard.thread,
                    seq,
                    n_args,
                    args,
                });
            }
            if flags & METRICS != 0 {
                let mut hists = lock(&shard.hists);
                match hists.iter_mut().find(|(n, _)| *n == name) {
                    Some((_, h)) => h.observe(dur_ns),
                    None => {
                        let mut h = Hist::zero();
                        h.observe(dur_ns);
                        hists.push((name, h));
                    }
                }
            }
        });
    }
}

/// Bump a named monotone counter. One relaxed load when recording is
/// off; aggregated in place (no per-event state) when on.
#[inline]
pub fn counter(name: &'static str, delta: u64) {
    if FLAGS.load(Ordering::Relaxed) == 0 {
        return;
    }
    with_shard(|shard| {
        let mut counters = lock(&shard.counters);
        match counters.iter_mut().find(|(n, _)| *n == name) {
            Some((_, v)) => *v += delta,
            None => counters.push((name, delta)),
        }
    });
}

/// Number of trace events recorded so far, across all threads.
pub fn event_count() -> usize {
    let s = lock(shards());
    s.all.iter().map(|shard| lock(&shard.events).len()).sum()
}

/// Clear all recorded events, counters, and histograms (shards stay
/// registered), plus any worker-imported events. A test/bench seam —
/// production code never truncates — and the worker daemon's
/// between-batches truncation point.
pub fn reset() {
    let s = lock(shards());
    for shard in &s.all {
        lock(&shard.events).clear();
        lock(&shard.counters).clear();
        lock(&shard.hists).clear();
    }
    drop(s);
    lock(imported()).clear();
}

/// A span imported from another process (a fleet worker), already
/// renormalized to this process's epoch. Unlike the in-process `Event`
/// it owns its strings — worker names arrive over the wire, not from
/// `&'static str` call sites — and carries an explicit `pid` so the
/// Chrome trace keeps each worker's rows distinct from the
/// coordinator's (local events export as pid 1; workers get 2, 3, ...).
#[derive(Debug, Clone)]
pub struct ImportedEvent {
    pub ns: u64,
    pub dur_ns: u64,
    pub name: String,
    pub pid: u64,
    pub tid: u64,
    pub seq: u64,
    pub args: Vec<(String, crate::util::json::Json)>,
}

fn imported() -> &'static Mutex<Vec<ImportedEvent>> {
    static IMPORTED: OnceLock<Mutex<Vec<ImportedEvent>>> = OnceLock::new();
    IMPORTED.get_or_init(|| Mutex::new(Vec::new()))
}

/// Append worker-shipped events to the imported buffer (the coordinator
/// side of fleet tracing; see [`export::import_worker_events`] for the
/// wire-JSON decoding and epoch renormalization that produce them).
pub fn import_events(events: Vec<ImportedEvent>) {
    if events.is_empty() {
        return;
    }
    lock(imported()).extend(events);
}

/// Snapshot of all imported events, sorted by `(ns, pid, tid, seq)`.
pub(crate) fn snapshot_imported() -> Vec<ImportedEvent> {
    let mut out = lock(imported()).clone();
    out.sort_by(|a, b| (a.ns, a.pid, a.tid, a.seq).cmp(&(b.ns, b.pid, b.tid, b.seq)));
    out
}

/// Canonical snapshot of all events, sorted by `(ns, thread, seq)`.
pub(crate) fn snapshot_events() -> Vec<Event> {
    let s = lock(shards());
    let mut out = Vec::new();
    for shard in &s.all {
        out.extend(lock(&shard.events).iter().copied());
    }
    drop(s);
    out.sort_by_key(|e| (e.ns, e.thread, e.seq));
    out
}

/// Aggregated (counters, histograms), each sorted by name.
pub(crate) fn snapshot_metrics() -> (Vec<(&'static str, u64)>, Vec<(&'static str, Hist)>) {
    let s = lock(shards());
    let mut counters: Vec<(&'static str, u64)> = Vec::new();
    let mut hists: Vec<(&'static str, Hist)> = Vec::new();
    for shard in &s.all {
        for &(name, v) in lock(&shard.counters).iter() {
            match counters.iter_mut().find(|(n, _)| *n == name) {
                Some((_, total)) => *total += v,
                None => counters.push((name, v)),
            }
        }
        for &(name, h) in lock(&shard.hists).iter() {
            match hists.iter_mut().find(|(n, _)| *n == name) {
                Some((_, total)) => {
                    for (b, add) in total.buckets.iter_mut().zip(h.buckets.iter()) {
                        *b += add;
                    }
                    total.count += h.count;
                    total.sum_ns = total.sum_ns.saturating_add(h.sum_ns);
                }
                None => hists.push((name, h)),
            }
        }
    }
    counters.sort_by_key(|(n, _)| *n);
    hists.sort_by_key(|(n, _)| *n);
    (counters, hists)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Recording is process-global; serialize the tests that toggle it.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(PoisonError::into_inner)
    }

    // Other unit tests in this binary run concurrently through
    // instrumented code, so while a test here has recording enabled the
    // global buffers may pick up their events too — assert only on this
    // module's own "test."-prefixed names.

    #[test]
    fn disabled_records_nothing() {
        let _g = guard();
        enable(false, false);
        reset();
        {
            let _s = span("test.noop").kv("k", 1u64);
        }
        counter("test.counter", 3);
        let events = snapshot_events();
        assert!(events.iter().all(|e| !e.name.starts_with("test.")));
        let (counters, hists) = snapshot_metrics();
        assert!(counters.iter().all(|(n, _)| !n.starts_with("test.")));
        assert!(hists.iter().all(|(n, _)| !n.starts_with("test.")));
    }

    #[test]
    fn spans_record_args_and_canonical_order() {
        let _g = guard();
        enable(true, true);
        {
            let mut s = span("test.outer").kv("n", 2u64);
            s.note("outcome", "ok");
        }
        {
            let _s = span("test.inner");
        }
        counter("test.hits", 2);
        counter("test.hits", 1);
        let events = snapshot_events();
        enable(false, false);
        reset();
        let mine: Vec<_> = events.iter().filter(|e| e.name.starts_with("test.")).collect();
        assert_eq!(mine.len(), 2);
        // Canonical order: by start ns (outer opened first).
        assert_eq!(mine[0].name, "test.outer");
        assert_eq!(mine[0].n_args, 2);
        assert!(events.windows(2).all(|w| {
            (w[0].ns, w[0].thread, w[0].seq) <= (w[1].ns, w[1].thread, w[1].seq)
        }));
    }

    #[test]
    fn worker_events_import_renormalized_and_reset_clears_them() {
        let _g = guard();
        use crate::util::json::Json;
        let mut ev = Json::obj();
        ev.set("name", "remote.job");
        ev.set("ns", 5_000u64);
        ev.set("dur_ns", 40u64);
        ev.set("thread", 3u64);
        ev.set("seq", 9u64);
        let mut args = Json::obj();
        args.set("index", 2u64);
        ev.set("args", args);
        let garbage = Json::parse("{\"name\":\"half\"}").unwrap();
        // The offset pushes the events below the epoch: they clamp to 0.
        let n = export::import_worker_events(&[ev.clone(), garbage, ev], 7, -6_000);
        assert_eq!(n, 2, "both well-formed events import; garbage is skipped");
        let mine: Vec<_> = snapshot_imported().into_iter().filter(|e| e.pid == 7).collect();
        assert_eq!(mine.len(), 2);
        assert_eq!(mine[0].ns, 0, "pre-epoch timestamps clamp to 0");
        assert_eq!(mine[0].name, "remote.job");
        assert_eq!(mine[0].tid, 3);
        assert_eq!(mine[0].seq, 9);
        assert_eq!(mine[0].args.len(), 1);
        reset();
        assert!(snapshot_imported().iter().all(|e| e.pid != 7), "reset clears imports");
    }

    #[test]
    fn syms_intern_and_resolve() {
        let a = sym("gemm@A100");
        let b = sym("gemm@A100");
        assert_eq!(a, b);
        assert_eq!(sym_name(a), "gemm@A100");
    }

    #[test]
    fn histogram_buckets_are_cumulative_safe() {
        let mut h = Hist::zero();
        h.observe(500); // ≤ 1µs bucket
        h.observe(5_000_000); // ≤ 10ms bucket
        h.observe(u64::MAX); // +Inf bucket
        assert_eq!(h.count, 3);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[BUCKET_BOUNDS_NS.len()], 1);
    }
}
