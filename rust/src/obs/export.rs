//! Exporters for the recorder: Chrome trace-event JSON (`--trace FILE`,
//! loadable in `chrome://tracing` / Perfetto), a Prometheus text
//! snapshot (`--metrics`, dumped to stderr at exit), and a JSON metrics
//! block for the serve daemon's `status` response.
//!
//! All exporters read the same canonical snapshot: events sorted by
//! `(epoch-ns, thread, seq)` and name-sorted metric aggregates, so the
//! outputs of two runs diff structurally.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};

use super::{
    snapshot_events, snapshot_imported, snapshot_metrics, sym_name, ArgValue, ImportedEvent,
    BUCKET_BOUNDS_NS,
};
use crate::util::json::{self, Json};

fn arg_json(v: &ArgValue) -> Json {
    match v {
        ArgValue::U64(x) => Json::Num(*x as f64),
        ArgValue::F64(x) => Json::Num(*x),
        ArgValue::Str(s) => Json::Str((*s).to_string()),
        ArgValue::Sym(s) => Json::Str(sym_name(*s)),
    }
}

fn trace_row(name: &str, ns: u64, dur_ns: u64, pid: u64, tid: u64, seq: u64) -> Json {
    let mut j = Json::obj();
    j.set("name", name);
    j.set("cat", "obs");
    j.set("ph", "X");
    j.set("ts", ns / 1_000);
    j.set("dur", dur_ns / 1_000);
    j.set("pid", pid);
    j.set("tid", tid);
    j
}

/// The full trace as a Chrome trace-event document. Every event is a
/// complete ("X") span — closed by construction — with microsecond
/// `ts`/`dur` (truncated; the exact nanosecond start and per-thread
/// sequence number ride in `args` so the canonical order stays visible
/// after truncation). Local events carry pid 1; events imported from
/// fleet workers keep their assigned worker pid, and the merged stream
/// is sorted by the canonical `(epoch-ns, pid, tid, seq)` key — for a
/// single-process run (constant pid 1) that is exactly the historical
/// `(epoch-ns, thread, seq)` order.
pub fn chrome_trace() -> Json {
    let mut rows: Vec<((u64, u64, u64, u64), Json)> = Vec::new();
    for e in snapshot_events() {
        let mut j = trace_row(e.name, e.ns, e.dur_ns, 1, e.thread as u64, e.seq);
        let mut args = Json::obj();
        args.set("ns", e.ns);
        args.set("seq", e.seq);
        for (key, value) in e.args.iter().take(e.n_args as usize) {
            args.set(key, arg_json(value));
        }
        j.set("args", args);
        rows.push(((e.ns, 1, e.thread as u64, e.seq), j));
    }
    for e in snapshot_imported() {
        let mut j = trace_row(&e.name, e.ns, e.dur_ns, e.pid, e.tid, e.seq);
        let mut args = Json::obj();
        args.set("ns", e.ns);
        args.set("seq", e.seq);
        for (key, value) in &e.args {
            args.set(key, value.clone());
        }
        j.set("args", args);
        rows.push(((e.ns, e.pid, e.tid, e.seq), j));
    }
    rows.sort_by(|a, b| a.0.cmp(&b.0));
    let mut events = Json::Arr(Vec::new());
    for (_, j) in rows {
        events.push(j);
    }
    let mut doc = Json::obj();
    doc.set("traceEvents", events);
    doc.set("displayTimeUnit", "ms");
    doc
}

/// The local trace buffer as a plain JSON array (`{name, ns, dur_ns,
/// thread, seq, args}` per event, symbols resolved) — the form a fleet
/// worker ships home with its final rows. Timestamps are the worker's
/// own epoch-nanoseconds; the coordinator renormalizes them via
/// [`import_worker_events`].
pub fn events_json() -> Json {
    let mut arr = Json::Arr(Vec::new());
    for e in snapshot_events() {
        let mut j = Json::obj();
        j.set("name", e.name);
        j.set("ns", e.ns);
        j.set("dur_ns", e.dur_ns);
        j.set("thread", e.thread as u64);
        j.set("seq", e.seq);
        let mut args = Json::obj();
        for (key, value) in e.args.iter().take(e.n_args as usize) {
            args.set(key, arg_json(value));
        }
        j.set("args", args);
        arr.push(j);
    }
    arr
}

/// Decode a worker's [`events_json`] array and fold it into the imported
/// buffer under `pid`, shifting every timestamp by `offset_ns` (the
/// coordinator's clock reading at dispatch minus the worker's reported
/// `base_ns`, so fleet spans land on the coordinator's epoch; negative
/// results clamp to 0). Malformed entries are skipped — trace shipping
/// is best-effort and must never fail a batch. Returns the number of
/// events imported.
pub fn import_worker_events(spans: &[Json], pid: u64, offset_ns: i64) -> usize {
    let mut out = Vec::new();
    for s in spans {
        let name = s.get("name").and_then(Json::as_str);
        let ns = s.get("ns").and_then(Json::as_usize);
        let dur_ns = s.get("dur_ns").and_then(Json::as_usize);
        let tid = s.get("thread").and_then(Json::as_usize);
        let seq = s.get("seq").and_then(Json::as_usize);
        let (Some(name), Some(ns), Some(dur_ns), Some(tid), Some(seq)) =
            (name, ns, dur_ns, tid, seq)
        else {
            continue;
        };
        let mut args = Vec::new();
        if let Some(Json::Obj(pairs)) = s.get("args") {
            for (k, v) in pairs {
                args.push((k.clone(), v.clone()));
            }
        }
        out.push(ImportedEvent {
            ns: (ns as i64).saturating_add(offset_ns).max(0) as u64,
            dur_ns: dur_ns as u64,
            name: name.to_string(),
            pid,
            tid: tid as u64,
            seq: seq as u64,
            args,
        });
    }
    let n = out.len();
    super::import_events(out);
    n
}

/// `a.b.c` → `a_b_c`: Prometheus metric names allow `[a-zA-Z0-9_:]`.
fn metric_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// Prometheus text-exposition snapshot: one `_total` counter per
/// [`super::counter`] name and one `_seconds` histogram per span name.
pub fn metrics_text() -> String {
    use std::fmt::Write as _;
    let (counters, hists) = snapshot_metrics();
    let mut out = String::new();
    for (name, value) in counters {
        let m = metric_name(name);
        let _ = writeln!(out, "# TYPE llamea_{m}_total counter");
        let _ = writeln!(out, "llamea_{m}_total {value}");
    }
    for (name, h) in hists {
        let m = metric_name(name);
        let _ = writeln!(out, "# TYPE llamea_{m}_seconds histogram");
        let mut cumulative = 0u64;
        for (i, count) in h.buckets.iter().enumerate() {
            cumulative += count;
            if i < BUCKET_BOUNDS_NS.len() {
                let le = BUCKET_BOUNDS_NS[i] as f64 / 1e9;
                let _ = writeln!(out, "llamea_{m}_seconds_bucket{{le=\"{le}\"}} {cumulative}");
            } else {
                let _ = writeln!(out, "llamea_{m}_seconds_bucket{{le=\"+Inf\"}} {cumulative}");
            }
        }
        let _ = writeln!(out, "llamea_{m}_seconds_sum {}", h.sum_ns as f64 / 1e9);
        let _ = writeln!(out, "llamea_{m}_seconds_count {}", h.count);
    }
    out
}

/// The `"metrics"` block of the serve daemon's `status` response:
/// counters plus per-span-name latency summaries. Present even when
/// aggregation is off (all-zero), so consumers can rely on the shape.
pub fn metrics_json() -> Json {
    let (counters, hists) = snapshot_metrics();
    let mut c = Json::obj();
    for (name, value) in counters {
        c.set(name, value);
    }
    let mut s = Json::obj();
    for (name, h) in hists {
        let mut row = Json::obj();
        row.set("count", h.count);
        row.set("total_s", h.sum_ns as f64 / 1e9);
        if h.count > 0 {
            row.set("mean_s", h.sum_ns as f64 / 1e9 / h.count as f64);
        }
        s.set(name, row);
    }
    let mut block = Json::obj();
    block.set("counters", c);
    block.set("spans", s);
    block
}

struct ExportConfig {
    trace_path: Option<PathBuf>,
    dump_metrics: bool,
}

fn config() -> &'static Mutex<ExportConfig> {
    static CONFIG: OnceLock<Mutex<ExportConfig>> = OnceLock::new();
    CONFIG.get_or_init(|| Mutex::new(ExportConfig { trace_path: None, dump_metrics: false }))
}

/// Register what [`finalize`] should emit. Called once by `main` after
/// flag parsing, before any work runs.
pub fn configure(trace_path: Option<PathBuf>, dump_metrics: bool) {
    let mut cfg = config().lock().unwrap_or_else(PoisonError::into_inner);
    cfg.trace_path = trace_path;
    cfg.dump_metrics = dump_metrics;
}

/// Write the configured exports: the Chrome trace to `--trace FILE` and
/// the Prometheus snapshot to stderr under `--metrics`. Idempotent — the
/// first call wins — so both the normal end of `main` and early
/// `process::exit` paths can call it unconditionally.
pub fn finalize() {
    static DONE: AtomicBool = AtomicBool::new(false);
    if DONE.swap(true, Ordering::SeqCst) {
        return;
    }
    let cfg = config().lock().unwrap_or_else(PoisonError::into_inner);
    if let Some(path) = &cfg.trace_path {
        let trace = chrome_trace();
        if let Err(e) = json::write_file(path, &trace) {
            eprintln!("obs: cannot write trace {} ({e})", path.display());
        }
    }
    if cfg.dump_metrics {
        eprint!("{}", metrics_text());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_names_are_sanitized() {
        assert_eq!(metric_name("executor.job"), "executor_job");
        assert_eq!(metric_name("serve.rejected-cap"), "serve_rejected_cap");
    }

    #[test]
    fn metrics_json_has_stable_shape_when_empty() {
        let block = metrics_json();
        assert!(block.get("counters").is_some());
        assert!(block.get("spans").is_some());
    }

    #[test]
    fn chrome_trace_is_an_object_with_event_array() {
        let doc = chrome_trace();
        assert!(doc.get("traceEvents").is_some());
        assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    }

}
