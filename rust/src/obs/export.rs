//! Exporters for the recorder: Chrome trace-event JSON (`--trace FILE`,
//! loadable in `chrome://tracing` / Perfetto), a Prometheus text
//! snapshot (`--metrics`, dumped to stderr at exit), and a JSON metrics
//! block for the serve daemon's `status` response.
//!
//! All exporters read the same canonical snapshot: events sorted by
//! `(epoch-ns, thread, seq)` and name-sorted metric aggregates, so the
//! outputs of two runs diff structurally.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};

use super::{snapshot_events, snapshot_metrics, sym_name, ArgValue, BUCKET_BOUNDS_NS};
use crate::util::json::{self, Json};

fn arg_json(v: &ArgValue) -> Json {
    match v {
        ArgValue::U64(x) => Json::Num(*x as f64),
        ArgValue::F64(x) => Json::Num(*x),
        ArgValue::Str(s) => Json::Str((*s).to_string()),
        ArgValue::Sym(s) => Json::Str(sym_name(*s)),
    }
}

/// The full trace as a Chrome trace-event document. Every event is a
/// complete ("X") span — closed by construction — with microsecond
/// `ts`/`dur` (truncated; the exact nanosecond start and per-thread
/// sequence number ride in `args` so the canonical order stays visible
/// after truncation).
pub fn chrome_trace() -> Json {
    let mut events = Json::Arr(Vec::new());
    for e in snapshot_events() {
        let mut j = Json::obj();
        j.set("name", e.name);
        j.set("cat", "obs");
        j.set("ph", "X");
        j.set("ts", e.ns / 1_000);
        j.set("dur", e.dur_ns / 1_000);
        j.set("pid", 1u64);
        j.set("tid", e.thread as u64);
        let mut args = Json::obj();
        args.set("ns", e.ns);
        args.set("seq", e.seq);
        for (key, value) in e.args.iter().take(e.n_args as usize) {
            args.set(key, arg_json(value));
        }
        j.set("args", args);
        events.push(j);
    }
    let mut doc = Json::obj();
    doc.set("traceEvents", events);
    doc.set("displayTimeUnit", "ms");
    doc
}

/// `a.b.c` → `a_b_c`: Prometheus metric names allow `[a-zA-Z0-9_:]`.
fn metric_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// Prometheus text-exposition snapshot: one `_total` counter per
/// [`super::counter`] name and one `_seconds` histogram per span name.
pub fn metrics_text() -> String {
    use std::fmt::Write as _;
    let (counters, hists) = snapshot_metrics();
    let mut out = String::new();
    for (name, value) in counters {
        let m = metric_name(name);
        let _ = writeln!(out, "# TYPE llamea_{m}_total counter");
        let _ = writeln!(out, "llamea_{m}_total {value}");
    }
    for (name, h) in hists {
        let m = metric_name(name);
        let _ = writeln!(out, "# TYPE llamea_{m}_seconds histogram");
        let mut cumulative = 0u64;
        for (i, count) in h.buckets.iter().enumerate() {
            cumulative += count;
            if i < BUCKET_BOUNDS_NS.len() {
                let le = BUCKET_BOUNDS_NS[i] as f64 / 1e9;
                let _ = writeln!(out, "llamea_{m}_seconds_bucket{{le=\"{le}\"}} {cumulative}");
            } else {
                let _ = writeln!(out, "llamea_{m}_seconds_bucket{{le=\"+Inf\"}} {cumulative}");
            }
        }
        let _ = writeln!(out, "llamea_{m}_seconds_sum {}", h.sum_ns as f64 / 1e9);
        let _ = writeln!(out, "llamea_{m}_seconds_count {}", h.count);
    }
    out
}

/// The `"metrics"` block of the serve daemon's `status` response:
/// counters plus per-span-name latency summaries. Present even when
/// aggregation is off (all-zero), so consumers can rely on the shape.
pub fn metrics_json() -> Json {
    let (counters, hists) = snapshot_metrics();
    let mut c = Json::obj();
    for (name, value) in counters {
        c.set(name, value);
    }
    let mut s = Json::obj();
    for (name, h) in hists {
        let mut row = Json::obj();
        row.set("count", h.count);
        row.set("total_s", h.sum_ns as f64 / 1e9);
        if h.count > 0 {
            row.set("mean_s", h.sum_ns as f64 / 1e9 / h.count as f64);
        }
        s.set(name, row);
    }
    let mut block = Json::obj();
    block.set("counters", c);
    block.set("spans", s);
    block
}

struct ExportConfig {
    trace_path: Option<PathBuf>,
    dump_metrics: bool,
}

fn config() -> &'static Mutex<ExportConfig> {
    static CONFIG: OnceLock<Mutex<ExportConfig>> = OnceLock::new();
    CONFIG.get_or_init(|| Mutex::new(ExportConfig { trace_path: None, dump_metrics: false }))
}

/// Register what [`finalize`] should emit. Called once by `main` after
/// flag parsing, before any work runs.
pub fn configure(trace_path: Option<PathBuf>, dump_metrics: bool) {
    let mut cfg = config().lock().unwrap_or_else(PoisonError::into_inner);
    cfg.trace_path = trace_path;
    cfg.dump_metrics = dump_metrics;
}

/// Write the configured exports: the Chrome trace to `--trace FILE` and
/// the Prometheus snapshot to stderr under `--metrics`. Idempotent — the
/// first call wins — so both the normal end of `main` and early
/// `process::exit` paths can call it unconditionally.
pub fn finalize() {
    static DONE: AtomicBool = AtomicBool::new(false);
    if DONE.swap(true, Ordering::SeqCst) {
        return;
    }
    let cfg = config().lock().unwrap_or_else(PoisonError::into_inner);
    if let Some(path) = &cfg.trace_path {
        let trace = chrome_trace();
        if let Err(e) = json::write_file(path, &trace) {
            eprintln!("obs: cannot write trace {} ({e})", path.display());
        }
    }
    if cfg.dump_metrics {
        eprint!("{}", metrics_text());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_names_are_sanitized() {
        assert_eq!(metric_name("executor.job"), "executor_job");
        assert_eq!(metric_name("serve.rejected-cap"), "serve_rejected_cap");
    }

    #[test]
    fn metrics_json_has_stable_shape_when_empty() {
        let block = metrics_json();
        assert!(block.get("counters").is_some());
        assert!(block.get("spans").is_some());
    }

    #[test]
    fn chrome_trace_is_an_object_with_event_array() {
        let doc = chrome_trace();
        assert!(doc.get("traceEvents").is_some());
        assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    }
}
