//! The community evaluation methodology (Willemsen et al. 2024): calculated
//! random-search baseline, budget from the 95% cutoff, performance curves
//! at equidistant times, and the aggregate performance score P of Eq. (3).

pub mod baseline;
pub mod curve;
pub mod runner;
pub mod score;

pub use baseline::Baseline;
pub use runner::{run_many, FnFactory, NamedFactory, OptimizerFactory, SpaceSetup, DEFAULT_CUTOFF};
pub use score::{aggregate, Aggregate};

/// Evaluate a set of optimizer factories over a set of caches; returns, per
/// factory, the aggregate over all spaces. `runs` seeds per (space,
/// optimizer); setups are computed once per cache.
pub fn evaluate_all(
    caches: &[crate::tuning::Cache],
    factories: &[&dyn OptimizerFactory],
    runs: usize,
    base_seed: u64,
) -> Vec<(String, Aggregate)> {
    let setups: Vec<SpaceSetup> = caches.iter().map(SpaceSetup::new).collect();
    factories
        .iter()
        .map(|f| {
            let per_space: Vec<Vec<Vec<f64>>> = caches
                .iter()
                .zip(&setups)
                .map(|(c, s)| run_many(c, s, *f, runs, base_seed))
                .collect();
            (f.label(), aggregate(&per_space))
        })
        .collect()
}
