//! Performance-over-time curves at equidistant sampling points (Eq. 2).
//!
//! A tuning run's trajectory (improvement events) is resampled at |T|
//! equidistant fractions of the budget and normalized against the
//! calculated baseline:
//!
//!   P_t = (S_baseline(t) - F_t) / (S_baseline(t) - S_opt)
//!
//! so P_t = 0 means parity with random search and P_t = 1 means the
//! optimum was already found at time t.

use super::baseline::Baseline;

/// Number of equidistant time sampling points |T| (paper uses a smooth
/// curve; 50 points matches its plots' resolution).
pub const DEFAULT_T_POINTS: usize = 50;

/// The equidistant sampling times for a budget.
pub fn sample_times(budget_s: f64, n_points: usize) -> Vec<f64> {
    (1..=n_points)
        .map(|j| budget_s * j as f64 / n_points as f64)
        .collect()
}

/// Best-so-far objective value at each sample time, from an improvement
/// trajectory `[(t_s, best_ms)]` (step function, non-increasing).
/// Before the first evaluation completes the baseline's n=0 level is used.
pub fn resample_trajectory(
    trajectory: &[(f64, f64)],
    times: &[f64],
    no_value_level: f64,
) -> Vec<f64> {
    let mut out = Vec::with_capacity(times.len());
    let mut k = 0usize;
    let mut current = no_value_level;
    for &t in times {
        while k < trajectory.len() && trajectory[k].0 <= t {
            current = trajectory[k].1;
            k += 1;
        }
        out.push(current);
    }
    out
}

/// Normalize a resampled best-value curve into a performance curve (Eq. 2).
///
/// Scores are clamped to [-1, 1]: late in the budget the baseline sits just
/// above the optimum, so the raw ratio for a lagging run diverges to large
/// negative values; one unlucky run would otherwise dominate a 100-run
/// mean. -1 ("a full baseline-to-optimum unit behind") is the floor.
pub fn performance_curve(
    best_values: &[f64],
    times: &[f64],
    baseline: &Baseline,
) -> Vec<f64> {
    debug_assert_eq!(best_values.len(), times.len());
    let opt = baseline.optimum();
    best_values
        .iter()
        .zip(times)
        .map(|(&f_t, &t)| {
            let b_t = baseline.value_at(t);
            let denom = b_t - opt;
            if denom <= 1e-12 {
                // Baseline already at the optimum: score 1 iff we are too.
                if (f_t - opt).abs() < 1e-9 {
                    1.0
                } else {
                    0.0
                }
            } else {
                ((b_t - f_t) / denom).clamp(-1.0, 1.0)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gpu::GpuSpec;
    use crate::searchspace::Application;
    use crate::tuning::Cache;

    #[test]
    fn sample_times_equidistant_and_end_at_budget() {
        let ts = sample_times(100.0, 4);
        assert_eq!(ts, vec![25.0, 50.0, 75.0, 100.0]);
    }

    #[test]
    fn resample_steps_correctly() {
        let traj = vec![(10.0, 5.0), (30.0, 3.0), (90.0, 1.0)];
        let times = vec![5.0, 20.0, 50.0, 100.0];
        let r = resample_trajectory(&traj, &times, 9.0);
        assert_eq!(r, vec![9.0, 5.0, 3.0, 1.0]);
    }

    #[test]
    fn perfect_optimizer_scores_one() {
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let b = Baseline::from_cache(&cache);
        let budget = b.budget_s(0.95);
        let times = sample_times(budget, 10);
        // Found the optimum instantly.
        let best = vec![b.optimum(); times.len()];
        let p = performance_curve(&best, &times, &b);
        assert!(p.iter().all(|&x| (x - 1.0).abs() < 1e-9), "{:?}", p);
    }

    #[test]
    fn baseline_equals_zero_score() {
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let b = Baseline::from_cache(&cache);
        let budget = b.budget_s(0.95);
        let times = sample_times(budget, 10);
        let best: Vec<f64> = times.iter().map(|&t| b.value_at(t)).collect();
        let p = performance_curve(&best, &times, &b);
        assert!(p.iter().all(|&x| x.abs() < 1e-9), "{:?}", p);
    }

    #[test]
    fn worse_than_baseline_is_negative() {
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let b = Baseline::from_cache(&cache);
        let budget = b.budget_s(0.95);
        let times = sample_times(budget, 5);
        let worst = b.median() * 2.0;
        let p = performance_curve(&vec![worst; 5], &times, &b);
        assert!(p.iter().all(|&x| x < 0.0));
    }
}
