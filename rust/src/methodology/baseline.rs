//! Calculated random-search baseline (Willemsen et al. 2024).
//!
//! Instead of running random search many times, the expected
//! best-objective-after-n-draws curve is computed *exactly* from the
//! cached objective-value distribution via order statistics:
//!
//!   P(best of n draws > v_k) = ((N - k) / N)^n
//!
//! over the N total configurations (crashing configurations count as draws
//! that never produce a value — exactly how they waste auto-tuning budget).
//! Time is mapped to draws through the space's mean evaluation cost.

use crate::tuning::Cache;

/// The calculated baseline for one search space.
pub struct Baseline {
    /// Sorted successful objective values, ascending (ms).
    values: Vec<f64>,
    /// Total configurations incl. failures (the draw population).
    n_total: usize,
    /// Mean seconds per random-search evaluation.
    pub mean_eval_cost_s: f64,
}

impl Baseline {
    pub fn from_cache(cache: &Cache) -> Baseline {
        Baseline {
            values: cache.sorted_times(),
            n_total: cache.len(),
            mean_eval_cost_s: cache.mean_eval_cost_s,
        }
    }

    /// Degenerate flat baseline for spaces with no pre-explored value
    /// distribution (lazy measured backends): the expected best is a
    /// constant. Performance scores computed against it are meaningless
    /// (baseline == optimum, so `performance_curve` hits its
    /// zero-denominator branch) — uncalibrated runs report trajectories
    /// and best configs, never score tables. See
    /// `SpaceSetup::uncalibrated`.
    pub fn flat(mean_eval_cost_s: f64) -> Baseline {
        Baseline { values: vec![1.0], n_total: 1, mean_eval_cost_s: mean_eval_cost_s.max(1e-9) }
    }

    /// Expected best objective value after `n` uniform draws (ms).
    ///
    /// For n = 0 (before any evaluation) returns the worst successful value
    /// — the neutral "no information" level.
    pub fn expected_best_after(&self, n: u64) -> f64 {
        let m = self.values.len();
        if m == 0 {
            return f64::INFINITY;
        }
        if n == 0 {
            return self.values[m - 1];
        }
        let nn = self.n_total as f64;
        let n = n as f64;
        // E[best] = sum_k v_k * (P(best > v_{k-1}) - P(best > v_k)),
        // with P(best > v_k) = ((N - (k+1)) / N)^n for 0-indexed k.
        // The residual mass (all draws fail) is assigned the worst value.
        let mut e = 0.0;
        let mut p_prev = 1.0; // P(best "worse" than everything before v_0)
        for (k, &v) in self.values.iter().enumerate() {
            let p_k = (((self.n_total - (k + 1)) as f64) / nn).powf(n);
            e += v * (p_prev - p_k);
            p_prev = p_k;
            if p_prev < 1e-15 {
                break; // the remaining mass is numerically zero
            }
        }
        // All-draws-fail mass keeps the worst successful value.
        e += self.values[m - 1] * p_prev;
        e
    }

    /// Baseline objective value at wall-clock time `t` seconds.
    pub fn value_at(&self, t_s: f64) -> f64 {
        let n = (t_s / self.mean_eval_cost_s).floor().max(0.0) as u64;
        self.expected_best_after(n)
    }

    /// The cutoff objective value: `cutoff` of the way from the median down
    /// to the optimum (paper: ~0.95).
    pub fn cutoff_value(&self, cutoff: f64) -> f64 {
        let opt = self.values[0];
        let med = self.values[self.values.len() / 2];
        opt + (1.0 - cutoff) * (med - opt)
    }

    /// Number of draws for the expected best to reach the cutoff value
    /// (doubling + binary search over the monotone curve).
    pub fn draws_to_reach(&self, target: f64) -> u64 {
        let mut hi = 1u64;
        while self.expected_best_after(hi) > target {
            hi *= 2;
            if hi > 1 << 40 {
                return hi; // unreachable targets: effectively infinite
            }
        }
        let mut lo = hi / 2;
        while lo + 1 < hi {
            let mid = lo + (hi - lo) / 2;
            if self.expected_best_after(mid) > target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        hi
    }

    /// The time budget for this space: time for the baseline to reach the
    /// `cutoff` point between median and optimum (paper §4.1.5, 95%).
    pub fn budget_s(&self, cutoff: f64) -> f64 {
        let draws = self.draws_to_reach(self.cutoff_value(cutoff));
        draws as f64 * self.mean_eval_cost_s
    }

    pub fn optimum(&self) -> f64 {
        self.values[0]
    }

    pub fn median(&self) -> f64 {
        self.values[self.values.len() / 2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gpu::GpuSpec;
    use crate::searchspace::Application;

    fn baseline() -> Baseline {
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        Baseline::from_cache(&cache)
    }

    #[test]
    fn expected_best_is_monotone_decreasing() {
        let b = baseline();
        let mut prev = b.expected_best_after(0);
        for n in [1, 2, 5, 10, 50, 200, 1000, 5000] {
            let e = b.expected_best_after(n);
            assert!(e <= prev + 1e-12, "n={} e={} prev={}", n, e, prev);
            prev = e;
        }
    }

    #[test]
    fn expected_best_converges_to_optimum() {
        let b = baseline();
        let e = b.expected_best_after(100_000_000);
        assert!((e - b.optimum()) / b.optimum() < 1e-3);
    }

    #[test]
    fn one_draw_expectation_is_distribution_mean_ish() {
        // E[best of 1 draw] = mean of successful values weighted by success
        // probability + worst * failure probability; must sit between
        // optimum and worst, above the median of successes.
        let b = baseline();
        let e1 = b.expected_best_after(1);
        assert!(e1 > b.median() * 0.5);
        assert!(e1 < b.values[b.values.len() - 1] * 1.01);
    }

    #[test]
    fn budget_reaches_cutoff() {
        let b = baseline();
        let cutoff_v = b.cutoff_value(0.95);
        assert!(cutoff_v > b.optimum() && cutoff_v < b.median());
        let n = b.draws_to_reach(cutoff_v);
        assert!(b.expected_best_after(n) <= cutoff_v);
        assert!(b.expected_best_after(n - 1) > cutoff_v);
        assert!(b.budget_s(0.95) > 0.0);
    }

    #[test]
    fn monte_carlo_agrees_with_analytic() {
        // Cross-check the order-statistics formula against simulation.
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let b = Baseline::from_cache(&cache);
        let mut rng = crate::util::rng::Rng::new(99);
        let n_draws = 30u64;
        let trials = 3000;
        let mut sum = 0.0;
        for _ in 0..trials {
            let mut best = f64::INFINITY;
            for _ in 0..n_draws {
                let i = rng.below(cache.len()) as u32;
                if let Some(t) = cache.true_mean_ms(i) {
                    best = best.min(t);
                }
            }
            if !best.is_finite() {
                best = *b.values.last().unwrap();
            }
            sum += best;
        }
        let mc = sum / trials as f64;
        let analytic = b.expected_best_after(n_draws);
        assert!(
            (mc - analytic).abs() / analytic < 0.05,
            "mc {} vs analytic {}",
            mc,
            analytic
        );
    }
}
