//! Run optimizers against evaluation backends under the methodology's
//! budget and produce per-run performance curves. Multi-run execution is
//! delegated to the L3 coordinator's executor (`crate::coordinator`),
//! which drains streamed job batches through a bounded worker pool;
//! [`run_many`] is its single-space convenience wrapper.
//!
//! Runs are expressed over [`BackendSource`] (anything that mints per-run
//! [`EvalBackend`](crate::tuning::EvalBackend)s): a shared `Cache` in
//! simulation mode, or a `MeasuredSource` timing real variants — the
//! runner never touches a `Cache` directly.

use super::baseline::Baseline;
use super::curve::{performance_curve, resample_trajectory, sample_times, DEFAULT_T_POINTS};
use crate::optimizers::Optimizer;
use crate::tuning::{BackendSource, Cache, TuningContext};
use crate::util::cancel::CancelToken;

/// The methodology's cutoff percentile (paper: ~95%).
pub const DEFAULT_CUTOFF: f64 = 0.95;

/// Precomputed per-space evaluation setup: baseline, budget, sample times.
pub struct SpaceSetup {
    pub baseline: Baseline,
    pub budget_s: f64,
    pub times: Vec<f64>,
}

impl SpaceSetup {
    pub fn new(cache: &Cache) -> SpaceSetup {
        Self::with(cache, DEFAULT_CUTOFF, DEFAULT_T_POINTS)
    }

    pub fn with(cache: &Cache, cutoff: f64, n_points: usize) -> SpaceSetup {
        let baseline = Baseline::from_cache(cache);
        let budget_s = baseline.budget_s(cutoff);
        let times = sample_times(budget_s, n_points);
        SpaceSetup { baseline, budget_s, times }
    }

    /// Setup for spaces with no pre-explored value distribution (lazy
    /// measured backends): a fixed wall-clock budget and a flat baseline.
    /// The baseline is degenerate, so performance *scores* derived from it
    /// are meaningless placeholders — consume the context's trajectory and
    /// best-config outputs instead (the measured CLI paths do exactly
    /// that and print no score table).
    pub fn uncalibrated(budget_s: f64, mean_eval_cost_s: f64) -> SpaceSetup {
        let baseline = Baseline::flat(mean_eval_cost_s);
        let times = sample_times(budget_s, DEFAULT_T_POINTS);
        SpaceSetup { baseline, budget_s, times }
    }
}

/// A thread-safe optimizer factory (fresh instance per run).
pub trait OptimizerFactory: Sync {
    fn build(&self) -> Box<dyn Optimizer>;
    fn label(&self) -> String;
}

/// Factory from a closure.
pub struct FnFactory<F: Fn() -> Box<dyn Optimizer> + Sync> {
    pub f: F,
    pub name: String,
}

impl<F: Fn() -> Box<dyn Optimizer> + Sync> OptimizerFactory for FnFactory<F> {
    fn build(&self) -> Box<dyn Optimizer> {
        (self.f)()
    }
    fn label(&self) -> String {
        self.name.clone()
    }
}

/// Factory for a registry name (`crate::optimizers::by_name`).
pub struct NamedFactory(pub String);

impl OptimizerFactory for NamedFactory {
    fn build(&self) -> Box<dyn Optimizer> {
        crate::optimizers::by_name(&self.0)
            .unwrap_or_else(|| panic!("unknown optimizer '{}'", self.0))
    }
    fn label(&self) -> String {
        self.0.clone()
    }
}

/// Execute one tuning run over a fresh backend from `source` and return
/// its performance curve.
pub fn single_run(
    source: &dyn BackendSource,
    setup: &SpaceSetup,
    opt: &mut dyn Optimizer,
    seed: u64,
) -> Vec<f64> {
    single_run_cancellable(source, setup, opt, seed, &CancelToken::new())
        .expect("a fresh token cannot cancel the run")
}

/// [`single_run`] under a cooperative cancellation token: the context
/// reports the budget as spent once the token fires, so the optimizer
/// winds down at its next between-evaluations check. Returns `None` when
/// the run *observed* the fired token (the truncated trajectory is
/// discarded — it must never pass as a completed curve) and `Some` for a
/// completed run, bit-identical to the token-less path.
pub fn single_run_cancellable(
    source: &dyn BackendSource,
    setup: &SpaceSetup,
    opt: &mut dyn Optimizer,
    seed: u64,
    cancel: &CancelToken,
) -> Option<Vec<f64>> {
    let mut backend = source.backend();
    let mut ctx = TuningContext::with_backend(backend.as_mut(), setup.budget_s, seed);
    ctx.set_cancel_token(cancel.clone());
    let mut run_span = crate::obs::span("tuning.run");
    opt.run(&mut ctx);
    // Per-run evaluation accounting: observational only — recorded after
    // the optimizer finishes, read from (never written to) the context.
    if crate::obs::enabled() {
        let evals = ctx.eval_calls();
        let dedup_hits = evals - ctx.unique_evals();
        run_span.note("evals", evals);
        run_span.note("dedup_hits", dedup_hits);
        run_span.note("budget_frac", ctx.budget_spent_fraction());
        crate::obs::counter("tuning.evals", evals);
        crate::obs::counter("tuning.dedup_hits", dedup_hits);
    }
    drop(run_span);
    if ctx.cancellation_observed() {
        return None;
    }
    let no_value = setup.baseline.expected_best_after(0);
    let best = resample_trajectory(&ctx.trajectory, &setup.times, no_value);
    Some(performance_curve(&best, &setup.times, &setup.baseline))
}

/// Run `runs` independent seeds of the factory's optimizer on one space,
/// in parallel; returns `runs` performance curves.
///
/// Thin wrapper over the L3 executor: one streamed job per seed, with
/// per-job seeds derived from (space id, optimizer label, run index) so
/// results are identical to the same grid executed inside a larger batch.
pub fn run_many(
    source: &dyn BackendSource,
    setup: &SpaceSetup,
    factory: &dyn OptimizerFactory,
    runs: usize,
    base_seed: u64,
) -> Vec<Vec<f64>> {
    use crate::coordinator::executor::{Executor, FnSource};
    use crate::coordinator::{job_seed, TuningJob};
    let space_id = source.space_id();
    let label = factory.label();
    let mut jobs = FnSource::new(runs, |r| {
        TuningJob {
            source,
            setup,
            factory,
            seed: job_seed(base_seed, &space_id, &label, r as u64),
            group: 0,
        }
        .into()
    });
    Executor::auto().fail_fast().run(&mut jobs).expect_curves()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gpu::GpuSpec;
    use crate::searchspace::Application;

    fn cache() -> Cache {
        Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap())
    }

    #[test]
    fn single_run_curve_shape() {
        let c = cache();
        let setup = SpaceSetup::new(&c);
        let mut opt = crate::optimizers::by_name("random").unwrap();
        let curve = single_run(&c, &setup, opt.as_mut(), 5);
        assert_eq!(curve.len(), setup.times.len());
        // Random search tracks the baseline: scores hover near 0, within
        // a broad band (it is one realization vs the expectation).
        let m = crate::util::stats::mean(&curve);
        assert!(m.abs() < 0.6, "mean {}", m);
    }

    #[test]
    fn run_many_is_deterministic_and_parallel_safe() {
        let c = cache();
        let setup = SpaceSetup::new(&c);
        let f = NamedFactory("sa".into());
        let a = run_many(&c, &setup, &f, 8, 77);
        let b = run_many(&c, &setup, &f, 8, 77);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn good_optimizer_scores_above_random() {
        let c = cache();
        let setup = SpaceSetup::new(&c);
        let hv = run_many(&c, &setup, &NamedFactory("hybrid_vndx".into()), 10, 1);
        let rs = run_many(&c, &setup, &NamedFactory("random".into()), 10, 1);
        let mean_of = |curves: &Vec<Vec<f64>>| {
            crate::util::stats::mean(&crate::util::stats::mean_curve(curves))
        };
        assert!(
            mean_of(&hv) > mean_of(&rs) + 0.05,
            "hybrid {} vs random {}",
            mean_of(&hv),
            mean_of(&rs)
        );
    }

    #[test]
    fn uncalibrated_setup_has_flat_baseline() {
        let setup = SpaceSetup::uncalibrated(30.0, 0.5);
        assert_eq!(setup.budget_s, 30.0);
        assert!(!setup.times.is_empty());
        assert_eq!(setup.baseline.expected_best_after(0), setup.baseline.expected_best_after(100));
    }
}
