//! Aggregation of performance curves into the scalar score P (Eq. 3).
//!
//! Per space: mean over runs at each sample point. Across spaces: mean of
//! the per-space curves at each point (all spaces share the same number of
//! relative sample points, which is what makes them aggregable). The score
//! is the mean of the aggregate curve over the sample points.

use crate::util::stats;

/// Aggregate result of evaluating one optimizer on a set of spaces.
#[derive(Debug, Clone)]
pub struct Aggregate {
    /// Mean performance curve over spaces (length |T|).
    pub curve: Vec<f64>,
    /// 95% CI half-width per sample point, over per-run aggregate curves.
    pub ci95: Vec<f64>,
    /// The scalar performance score P (mean of `curve`).
    pub score: f64,
    /// Standard deviation of the per-space scores (Table 2's +- column).
    pub score_std: f64,
    /// Per-space scalar scores, in input order (Fig. 7 / Fig. 9 rows).
    pub per_space_scores: Vec<f64>,
}

/// `curves_per_space[s][r]` = performance curve of run `r` on space `s`.
pub fn aggregate(curves_per_space: &[Vec<Vec<f64>>]) -> Aggregate {
    assert!(!curves_per_space.is_empty());
    let n_points = curves_per_space[0][0].len();

    // Per-space mean curves and scalar scores.
    let space_curves: Vec<Vec<f64>> = curves_per_space
        .iter()
        .map(|runs| stats::mean_curve(runs))
        .collect();
    let per_space_scores: Vec<f64> = space_curves.iter().map(|c| stats::mean(c)).collect();

    // Aggregate curve: mean over spaces.
    let curve = stats::mean_curve(&space_curves);
    let score = stats::mean(&curve);
    let score_std = stats::std_dev(&per_space_scores);

    // CI over per-run aggregate curves: pair run r across spaces (all
    // spaces were run with the same run count).
    let runs = curves_per_space.iter().map(|s| s.len()).min().unwrap();
    let mut run_aggregates: Vec<Vec<f64>> = Vec::with_capacity(runs);
    for r in 0..runs {
        let rows: Vec<Vec<f64>> = curves_per_space
            .iter()
            .map(|s| s[r].clone())
            .collect();
        run_aggregates.push(stats::mean_curve(&rows));
    }
    let ci95 = stats::ci95_curve(&run_aggregates);

    debug_assert_eq!(curve.len(), n_points);
    Aggregate { curve, ci95, score, score_std, per_space_scores }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_of_constant_curves() {
        // Two spaces, two runs each: constant 0.4 and 0.6.
        let s1 = vec![vec![0.4; 5], vec![0.4; 5]];
        let s2 = vec![vec![0.6; 5], vec![0.6; 5]];
        let a = aggregate(&[s1, s2]);
        assert!((a.score - 0.5).abs() < 1e-12);
        assert!(a.curve.iter().all(|&x| (x - 0.5).abs() < 1e-12));
        assert_eq!(a.per_space_scores, vec![0.4, 0.6]);
        // Identical runs -> zero CI.
        assert!(a.ci95.iter().all(|&w| w.abs() < 1e-12));
    }

    #[test]
    fn ci_reflects_run_variance() {
        let s1 = vec![vec![0.0; 3], vec![1.0; 3]];
        let a = aggregate(&[s1]);
        assert!((a.score - 0.5).abs() < 1e-12);
        assert!(a.ci95.iter().all(|&w| w > 0.1));
    }

    #[test]
    fn score_std_over_spaces() {
        let s1 = vec![vec![0.2; 4]];
        let s2 = vec![vec![0.8; 4]];
        let a = aggregate(&[s1, s2]);
        assert!(a.score_std > 0.3);
    }
}
