//! Sharded grid execution: split a deterministic job grid across
//! processes, then collate the per-shard partial reports into exactly the
//! single-process report, byte for byte.
//!
//! Because every job's seed is derived from its grid *coordinates*
//! ([`super::job::job_seed`]) and never from execution order, the grid can
//! be partitioned arbitrarily: shard `K/N` owns the flat indices `i` with
//! `i % N == K` (round-robin, so uneven space costs spread across shards
//! instead of clustering in one). Each shard executes only its own jobs
//! and writes a *partial* report — the grid header plus its raw
//! per-job curves. [`merge_reports`] then validates that the partials
//! describe the same grid (identical headers), that every shard of the
//! declared count is present exactly once, and that the job indices cover
//! the grid exactly; it reassembles the curves in flat-index order and
//! recomputes the aggregation pipeline ([`super::report::collate_groups`]
//! → [`super::report::grid_aggregates`] → [`super::report::scores_json`]).
//! The JSON number grammar round-trips `f64` bit-exactly
//! ([`crate::util::json::Json::parse`]), so the merged report is
//! byte-identical to `coordinate --out` run in one process — pinned by
//! `rust/tests/integration_persist.rs`.
//!
//! Sweep partials work the same way over meta-ordinals instead of job
//! indices (grid strategy only — the adaptive strategies decide later
//! evaluations from earlier scores, so their job sets are not
//! partitionable up front); their rows are produced by
//! [`crate::hypertune::sweep_partial_json`] and merged here.

use super::executor::JobsSummary;
use super::report::{grid_aggregates, scores_json};
use crate::util::json::Json;

/// One shard of an `N`-way split: owns flat indices `i % count == index`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
}

impl ShardSpec {
    /// Parse the CLI's `--shard K/N` (0-based, `K < N`).
    pub fn parse(s: &str) -> Result<ShardSpec, String> {
        let (k, n) = s.split_once('/').ok_or_else(|| format!("--shard wants K/N, got '{s}'"))?;
        let index: usize = k.parse().map_err(|_| format!("bad shard index '{k}'"))?;
        let count: usize = n.parse().map_err(|_| format!("bad shard count '{n}'"))?;
        if count == 0 {
            return Err("shard count must be at least 1".into());
        }
        if index >= count {
            return Err(format!("shard index {index} out of range for {count} shard(s)"));
        }
        Ok(ShardSpec { index, count })
    }

    /// Does this shard own flat grid index `i`?
    pub fn owns(&self, i: usize) -> bool {
        i % self.count == self.index
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("index", self.index);
        j.set("count", self.count);
        j
    }
}

/// One executed job of a shard: its flat grid index, its reassembly group,
/// and its curve.
pub struct ShardJob {
    pub index: usize,
    pub group: usize,
    pub curve: Vec<f64>,
}

/// The partial report of one `coordinate --shard K/N` run: the full grid
/// header (so the merger can prove all partials describe the same grid)
/// plus this shard's raw curves. Deliberately *not* aggregated — scores
/// only exist on the merged whole.
#[allow(clippy::too_many_arguments)]
pub fn partial_coordinate_json(
    title: &str,
    space_ids: &[String],
    labels: &[String],
    runs: usize,
    seed: u64,
    shard: &ShardSpec,
    total_jobs: usize,
    summary: &JobsSummary,
    jobs: &[ShardJob],
) -> Json {
    let mut j = Json::obj();
    j.set("partial", "coordinate");
    j.set("title", title);
    j.set("spaces", Json::Arr(space_ids.iter().map(|s| Json::from(s.as_str())).collect()));
    j.set("optimizers", Json::Arr(labels.iter().map(|s| Json::from(s.as_str())).collect()));
    j.set("runs", runs);
    j.set("seed", seed);
    j.set("total_jobs", total_jobs);
    j.set("shard", shard.to_json());
    j.set("jobs", summary.to_json());
    let mut rows: Vec<Json> = Vec::with_capacity(jobs.len());
    for job in jobs {
        let mut row = Json::obj();
        row.set("index", job.index);
        row.set("group", job.group);
        row.set("curve", job.curve.clone());
        rows.push(row);
    }
    j.set("curves", Json::Arr(rows));
    j
}

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("partial report is missing '{key}'"))
}

fn usize_field(j: &Json, key: &str) -> Result<usize, String> {
    field(j, key)?.as_usize().ok_or_else(|| format!("'{key}' is not a non-negative integer"))
}

/// Check that `key` renders identically in every partial (the cheap,
/// exact way to compare grid headers — the writer is canonical).
fn require_equal(partials: &[Json], key: &str) -> Result<(), String> {
    let first = field(&partials[0], key)?.to_string();
    for (i, p) in partials.iter().enumerate().skip(1) {
        if field(p, key)?.to_string() != first {
            return Err(format!("partial {i} disagrees on '{key}' (different grids?)"));
        }
    }
    Ok(())
}

/// Validate the shard set: every partial declares the same count, and the
/// indices are exactly `0..count`, each once. Returns the count.
fn require_complete_shards(partials: &[Json]) -> Result<usize, String> {
    let count = usize_field(field(&partials[0], "shard")?, "count")?;
    let mut seen = vec![false; count];
    for p in partials {
        let shard = field(p, "shard")?;
        if usize_field(shard, "count")? != count {
            return Err("partials disagree on the shard count".into());
        }
        let idx = usize_field(shard, "index")?;
        if idx >= count {
            return Err(format!("shard index {idx} out of range for {count} shard(s)"));
        }
        if std::mem::replace(&mut seen[idx], true) {
            return Err(format!("shard {idx}/{count} appears twice"));
        }
    }
    if let Some(missing) = seen.iter().position(|s| !s) {
        return Err(format!("shard {missing}/{count} is missing"));
    }
    Ok(count)
}

/// Sum the per-shard `"jobs"` completion blocks. Costs are integer
/// microseconds, so the sum is associative and the merged block is
/// bit-identical to the single-process one regardless of shard order.
fn summed_jobs(partials: &[Json]) -> Result<JobsSummary, String> {
    let mut out = JobsSummary::default();
    for p in partials {
        let jobs = field(p, "jobs")?;
        out.absorb(JobsSummary {
            completed: usize_field(jobs, "completed")?,
            cancelled: usize_field(jobs, "cancelled")?,
            failed: usize_field(jobs, "failed")?,
            cost_us: usize_field(jobs, "cost_us")? as u64,
        });
    }
    Ok(out)
}

fn str_list(j: &Json, key: &str) -> Result<Vec<String>, String> {
    field(j, key)?
        .as_arr()
        .ok_or_else(|| format!("'{key}' is not an array"))?
        .iter()
        .map(|v| {
            v.as_str().map(str::to_string).ok_or_else(|| format!("'{key}' holds a non-string"))
        })
        .collect()
}

/// Merge per-shard partial reports into the single-process report. The
/// input order is irrelevant; the output is a pure function of the
/// partial *set*. Errors (never panics) on partials from different grids,
/// duplicate or missing shards, and incomplete or overlapping coverage.
pub fn merge_reports(partials: &[Json]) -> Result<Json, String> {
    if partials.is_empty() {
        return Err("no partial reports to merge".into());
    }
    let kind = field(&partials[0], "partial")?
        .as_str()
        .ok_or("'partial' is not a string")?
        .to_string();
    for (i, p) in partials.iter().enumerate() {
        if field(p, "partial")?.as_str() != Some(kind.as_str()) {
            return Err(format!("partial {i} is not a '{kind}' report"));
        }
    }
    match kind.as_str() {
        "coordinate" => merge_coordinate(partials),
        "sweep" => merge_sweep(partials),
        other => Err(format!("unknown partial report kind '{other}'")),
    }
}

fn merge_coordinate(partials: &[Json]) -> Result<Json, String> {
    for key in ["title", "spaces", "optimizers", "runs", "seed", "total_jobs"] {
        require_equal(partials, key)?;
    }
    require_complete_shards(partials)?;
    let head = &partials[0];
    let title = field(head, "title")?.as_str().ok_or("'title' is not a string")?;
    let space_ids = str_list(head, "spaces")?;
    let labels = str_list(head, "optimizers")?;
    let total_jobs = usize_field(head, "total_jobs")?;
    let n_groups = labels.len() * space_ids.len();

    // Reassemble the flat curve array: every grid index exactly once.
    let mut slots: Vec<Option<(usize, Vec<f64>)>> = (0..total_jobs).map(|_| None).collect();
    for p in partials {
        let rows = field(p, "curves")?.as_arr().ok_or("'curves' is not an array")?;
        for row in rows {
            let index = usize_field(row, "index")?;
            if index >= total_jobs {
                return Err(format!("job index {index} out of range for {total_jobs} jobs"));
            }
            let group = usize_field(row, "group")?;
            if group >= n_groups {
                return Err(format!("job {index} has group {group}, grid has {n_groups}"));
            }
            let curve: Vec<f64> = field(row, "curve")?
                .as_arr()
                .ok_or("'curve' is not an array")?
                .iter()
                .map(|v| v.as_f64().ok_or("curve holds a non-number"))
                .collect::<Result<_, _>>()?;
            if slots[index].replace((group, curve)).is_some() {
                return Err(format!("job index {index} appears in more than one partial"));
            }
        }
    }
    if let Some(missing) = slots.iter().position(|s| s.is_none()) {
        return Err(format!("job index {missing} is covered by no partial"));
    }
    let (groups, curves): (Vec<usize>, Vec<Vec<f64>>) =
        slots.into_iter().map(|s| s.unwrap()).unzip();

    let grouped = super::report::collate_groups(n_groups, &groups, curves);
    let results = grid_aggregates(&labels, space_ids.len(), grouped);
    Ok(scores_json(title, &space_ids, &results, &summed_jobs(partials)?))
}

fn merge_sweep(partials: &[Json]) -> Result<Json, String> {
    for key in ["base", "strategy", "spaces", "runs", "seed", "meta_space_size"] {
        require_equal(partials, key)?;
    }
    require_complete_shards(partials)?;
    let head = &partials[0];
    let meta_space_size = usize_field(head, "meta_space_size")?;

    // Every meta-ordinal exactly once; rows re-sorted into leaderboard
    // order (score descending, ties by ascending ordinal — the exact
    // comparator of `MetaTuning::leaderboard`).
    let mut rows: Vec<(usize, f64, Json)> = Vec::with_capacity(meta_space_size);
    let mut seen = vec![false; meta_space_size];
    for p in partials {
        for row in field(p, "leaderboard")?.as_arr().ok_or("'leaderboard' is not an array")? {
            let ordinal = usize_field(row, "ordinal")?;
            if ordinal >= meta_space_size {
                return Err(format!(
                    "meta-ordinal {ordinal} out of range for {meta_space_size} configs"
                ));
            }
            if std::mem::replace(&mut seen[ordinal], true) {
                return Err(format!("meta-ordinal {ordinal} appears in more than one partial"));
            }
            let score =
                field(row, "score")?.as_f64().ok_or("'score' is not a number")?;
            let mut row = row.clone();
            row.remove("ordinal");
            rows.push((ordinal, score, row));
        }
    }
    if let Some(missing) = seen.iter().position(|s| !s) {
        return Err(format!("meta-ordinal {missing} is covered by no partial"));
    }
    rows.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));

    let mut j = Json::obj();
    for key in ["base", "strategy", "spaces", "runs", "seed", "meta_space_size"] {
        j.set(key, field(head, key)?.clone());
    }
    j.set("jobs", summed_jobs(partials)?.to_json());
    j.set("leaderboard", Json::Arr(rows.into_iter().map(|(_, _, r)| r).collect()));
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_parse_and_ownership() {
        let s = ShardSpec::parse("1/3").unwrap();
        assert_eq!(s, ShardSpec { index: 1, count: 3 });
        assert!(!s.owns(0) && s.owns(1) && !s.owns(2) && !s.owns(3) && s.owns(4));
        // Every index is owned by exactly one shard of a split.
        for i in 0..20 {
            let owners =
                (0..3).filter(|&k| ShardSpec { index: k, count: 3 }.owns(i)).count();
            assert_eq!(owners, 1);
        }
        assert!(ShardSpec::parse("3/3").is_err());
        assert!(ShardSpec::parse("0/0").is_err());
        assert!(ShardSpec::parse("1").is_err());
        assert!(ShardSpec::parse("a/b").is_err());
        assert!(ShardSpec::parse("1/2").unwrap().owns(1));
    }

    fn tiny_partial(shard: ShardSpec, total: usize) -> Json {
        let jobs: Vec<ShardJob> = (0..total)
            .filter(|&i| shard.owns(i))
            .map(|i| ShardJob { index: i, group: i % 2, curve: vec![i as f64, 0.5] })
            .collect();
        let summary = JobsSummary {
            completed: jobs.len(),
            cancelled: 0,
            failed: 0,
            cost_us: jobs.len() as u64 * 100,
        };
        partial_coordinate_json(
            "t",
            &["s".to_string()],
            &["a".to_string(), "b".to_string()],
            3,
            7,
            &shard,
            total,
            &summary,
            &jobs,
        )
    }

    #[test]
    fn merge_validates_shard_set_and_coverage() {
        let a = tiny_partial(ShardSpec { index: 0, count: 2 }, 6);
        let b = tiny_partial(ShardSpec { index: 1, count: 2 }, 6);
        // Complete set merges (order-independently).
        let m1 = merge_reports(&[a.clone(), b.clone()]).unwrap();
        let m2 = merge_reports(&[b.clone(), a.clone()]).unwrap();
        assert_eq!(m1.to_string(), m2.to_string());
        assert_eq!(
            m1.get("jobs").unwrap().get("completed").unwrap().as_usize(),
            Some(6)
        );
        // The merged report is a full report, not a partial.
        assert!(m1.get("partial").is_none());
        assert!(m1.get("scores").is_some());
        // Missing shard.
        let err = merge_reports(&[a.clone()]).unwrap_err();
        assert!(err.contains("missing"), "{err}");
        // Duplicate shard.
        let err = merge_reports(&[a.clone(), a.clone()]).unwrap_err();
        assert!(err.contains("twice"), "{err}");
        // Mismatched grids.
        let c = tiny_partial(ShardSpec { index: 1, count: 2 }, 8);
        let err = merge_reports(&[a.clone(), c]).unwrap_err();
        assert!(err.contains("total_jobs"), "{err}");
        // Nothing at all.
        assert!(merge_reports(&[]).is_err());
        // Duplicate job coverage: two shards both claiming index 0.
        let mut d = tiny_partial(ShardSpec { index: 1, count: 2 }, 6);
        let mut extra = Json::obj();
        extra.set("index", 0usize);
        extra.set("group", 0usize);
        extra.set("curve", vec![0.0]);
        let mut rows = d.remove("curves").unwrap();
        rows.push(extra);
        d.set("curves", rows);
        let err = merge_reports(&[a, d]).unwrap_err();
        assert!(err.contains("more than one partial"), "{err}");
    }
}
