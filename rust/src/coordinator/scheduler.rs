//! Compatibility wrapper over the streaming [`Executor`]: the drain-all,
//! curves-only batch API the coordinator grew up with.
//!
//! `Scheduler::run` drains a pre-materialized batch and returns plain
//! curves in batch order — no priorities, no cancellation, no events. It
//! is now a thin veneer over [`Executor::run_jobs`], kept during the
//! execution-API transition for callers (and tests) whose contract is
//! exactly "every job completes, give me the curves". New code should
//! talk to the [`Executor`] seam directly; a failed job here still
//! panics (with the per-job structured message), because this API has no
//! channel to report partial results through.

use super::executor::Executor;
use super::job::TuningJob;
use crate::util::parallel;

/// A fixed-width, drain-all worker pool over tuning jobs (the
/// compatibility surface of [`Executor`]).
pub struct Scheduler {
    threads: usize,
}

impl Scheduler {
    /// Pool with exactly `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Scheduler {
        Scheduler { threads: threads.max(1) }
    }

    /// Pool sized to the process default
    /// ([`crate::util::parallel::default_width`]), falling back to the
    /// machine.
    pub fn auto() -> Scheduler {
        Scheduler::new(parallel::default_width())
    }

    /// Set the process-wide default `auto()` width (`None` restores
    /// machine-sized). This is how `--threads` reaches the `run_many`
    /// paths (LLaMEA fitness evaluation, train/test split) that spawn
    /// pools internally, and the parallel space/cache construction in
    /// `searchspace`/`tuning` (via `util::parallel`); width never affects
    /// results, only concurrency.
    pub fn set_default_width(threads: Option<usize>) {
        parallel::set_default_width(threads);
    }

    /// `Some(n)` for an explicit width (the CLI's `--threads`/`--jobs`),
    /// `None` for machine-sized.
    pub fn with_threads(threads: Option<usize>) -> Scheduler {
        threads.map(Scheduler::new).unwrap_or_else(Scheduler::auto)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute every job and return the performance curves in batch order.
    /// Drain-all semantics: panics (with the executor's structured
    /// message) if any job fails — use the [`Executor`] API to consume
    /// partial batches.
    pub fn run(&self, jobs: &[TuningJob]) -> Vec<Vec<f64>> {
        // Fail fast: expect_curves discards everything on failure, so
        // finishing the rest of the batch first would be pure waste.
        Executor::new(self.threads).fail_fast().run_jobs(jobs).expect_curves()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::job_seed;
    use crate::kernels::gpu::GpuSpec;
    use crate::methodology::{NamedFactory, SpaceSetup};
    use crate::searchspace::Application;
    use crate::tuning::Cache;

    fn curves_with(threads: usize, runs: usize) -> Vec<Vec<f64>> {
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let setup = SpaceSetup::new(&cache);
        let factory = NamedFactory("sa".into());
        let space_id = cache.id();
        let jobs: Vec<TuningJob> = (0..runs)
            .map(|r| TuningJob {
                source: &cache,
                setup: &setup,
                factory: &factory,
                seed: job_seed(42, &space_id, "sa", r as u64),
                group: 0,
            })
            .collect();
        Scheduler::new(threads).run(&jobs)
    }

    #[test]
    fn empty_batch_is_fine() {
        assert!(Scheduler::new(4).run(&[]).is_empty());
    }

    #[test]
    fn output_is_identical_across_thread_counts() {
        let single = curves_with(1, 6);
        let pooled = curves_with(8, 6);
        assert_eq!(single.len(), 6);
        assert_eq!(single, pooled, "scheduler output must not depend on thread count");
    }

    #[test]
    fn width_is_clamped_and_default_is_settable() {
        assert_eq!(Scheduler::new(0).threads(), 1);
        assert_eq!(Scheduler::with_threads(Some(3)).threads(), 3);
        assert!(Scheduler::with_threads(None).threads() >= 1);
        // The process default reaches auto() (and never affects results —
        // see output_is_identical_across_thread_counts).
        Scheduler::set_default_width(Some(2));
        assert_eq!(Scheduler::auto().threads(), 2);
        Scheduler::set_default_width(None);
        assert!(Scheduler::auto().threads() >= 1);
    }
}
