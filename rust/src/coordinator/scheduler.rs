//! The work-stealing worker pool that executes tuning-job batches.
//!
//! One shared atomic cursor hands jobs to whichever worker is free, so the
//! pool parallelizes across spaces *and* optimizers *and* seeds — not just
//! the innermost seed loop. Results land in per-job slots indexed by batch
//! position, and every job's seed is pre-derived ([`super::job::job_seed`]),
//! so output is byte-identical for any thread count or execution order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::job::TuningJob;
use crate::util::parallel;

/// A fixed-width worker pool over tuning jobs.
pub struct Scheduler {
    threads: usize,
}

impl Scheduler {
    /// Pool with exactly `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Scheduler {
        Scheduler { threads: threads.max(1) }
    }

    /// Pool sized to the process default
    /// ([`crate::util::parallel::default_width`]), falling back to the
    /// machine.
    pub fn auto() -> Scheduler {
        Scheduler::new(parallel::default_width())
    }

    /// Set the process-wide default `auto()` width (`None` restores
    /// machine-sized). This is how `--threads` reaches the `run_many`
    /// paths (LLaMEA fitness evaluation, train/test split) that spawn
    /// pools internally, and the parallel space/cache construction in
    /// `searchspace`/`tuning` (via `util::parallel`); width never affects
    /// results, only concurrency.
    pub fn set_default_width(threads: Option<usize>) {
        parallel::set_default_width(threads);
    }

    /// `Some(n)` for an explicit width (the CLI's `--threads`/`--jobs`),
    /// `None` for machine-sized.
    pub fn with_threads(threads: Option<usize>) -> Scheduler {
        threads.map(Scheduler::new).unwrap_or_else(Scheduler::auto)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute every job and return the performance curves in batch order.
    pub fn run(&self, jobs: &[TuningJob]) -> Vec<Vec<f64>> {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let threads = self.threads.min(n);
        if threads <= 1 {
            return jobs.iter().map(TuningJob::execute).collect();
        }
        let slots: Vec<OnceLock<Vec<f64>>> = (0..n).map(|_| OnceLock::new()).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let j = next.fetch_add(1, Ordering::Relaxed);
                    if j >= n {
                        break;
                    }
                    let curve = jobs[j].execute();
                    slots[j].set(curve).expect("job slot written twice");
                });
            }
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().expect("scheduler finished with a missing result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::job_seed;
    use crate::kernels::gpu::GpuSpec;
    use crate::methodology::{NamedFactory, SpaceSetup};
    use crate::searchspace::Application;
    use crate::tuning::Cache;

    fn curves_with(threads: usize, runs: usize) -> Vec<Vec<f64>> {
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let setup = SpaceSetup::new(&cache);
        let factory = NamedFactory("sa".into());
        let space_id = cache.id();
        let jobs: Vec<TuningJob> = (0..runs)
            .map(|r| TuningJob {
                source: &cache,
                setup: &setup,
                factory: &factory,
                seed: job_seed(42, &space_id, "sa", r as u64),
                group: 0,
            })
            .collect();
        Scheduler::new(threads).run(&jobs)
    }

    #[test]
    fn empty_batch_is_fine() {
        assert!(Scheduler::new(4).run(&[]).is_empty());
    }

    #[test]
    fn output_is_identical_across_thread_counts() {
        let single = curves_with(1, 6);
        let pooled = curves_with(8, 6);
        assert_eq!(single.len(), 6);
        assert_eq!(single, pooled, "scheduler output must not depend on thread count");
    }

    #[test]
    fn width_is_clamped_and_default_is_settable() {
        assert_eq!(Scheduler::new(0).threads(), 1);
        assert_eq!(Scheduler::with_threads(Some(3)).threads(), 3);
        assert!(Scheduler::with_threads(None).threads() >= 1);
        // The process default reaches auto() (and never affects results —
        // see output_is_identical_across_thread_counts).
        Scheduler::set_default_width(Some(2));
        assert_eq!(Scheduler::auto().threads(), 2);
        Scheduler::set_default_width(None);
        assert!(Scheduler::auto().threads() >= 1);
    }
}
