//! Tuning jobs: the unit of work the L3 scheduler executes.
//!
//! A [`TuningJob`] is one seeded tuning run — a (backend source, optimizer
//! factory, fully-derived seed) triple. The source mints one fresh
//! [`EvalBackend`](crate::tuning::EvalBackend) per run: a shared cached
//! space in simulation mode, or a shared measured-variant source on the
//! real-tune path — either way the job graph is identical. Batches of
//! jobs are what the coordinator parallelizes over: every figure/table of
//! the paper's evaluation is a cross product of spaces × optimizers ×
//! seeds, and [`grid_jobs`] expands that product into a flat,
//! order-independent list.
//!
//! Determinism contract: a job's result depends only on its `(source,
//! setup, factory, seed)` fields, never on which worker ran it or when.
//! Seeds are derived with [`job_seed`] from the experiment base seed and
//! the job's coordinates in the grid, so the same grid yields
//! bit-identical results regardless of thread count, execution order, or
//! how the batch was split.

use std::sync::Arc;

use super::registry::SpaceEntry;
use crate::methodology::{runner::single_run, OptimizerFactory, SpaceSetup};
use crate::tuning::BackendSource;
use crate::util::rng::{avalanche, fnv1a};

/// One seeded tuning run against an evaluation-backend source.
pub struct TuningJob<'a> {
    /// Mints the run's evaluation backend (shared across the batch).
    pub source: &'a dyn BackendSource,
    /// Precomputed baseline/budget/sample-times of that space.
    pub setup: &'a SpaceSetup,
    /// Fresh-instance factory for the optimizer under test.
    pub factory: &'a dyn OptimizerFactory,
    /// Fully-derived seed; determines the run bit-for-bit.
    pub seed: u64,
    /// Caller-assigned reassembly group (see [`super::report::collate`]).
    pub group: usize,
}

impl TuningJob<'_> {
    /// Execute the run and return its performance curve.
    pub fn execute(&self) -> Vec<f64> {
        let mut opt = self.factory.build();
        single_run(self.source, self.setup, opt.as_mut(), self.seed)
    }
}

/// Derive the seed of one job from the experiment base seed and the job's
/// grid coordinates (space identity, optimizer label, run index).
///
/// Mixes each coordinate through FNV-1a and finishes with the SplitMix64
/// avalanche, so structurally close jobs (same space, adjacent run indices)
/// get statistically independent seeds, and permuting the grid or adding
/// optimizers/spaces never changes any other job's seed.
pub fn job_seed(base: u64, space_id: &str, opt_label: &str, run: u64) -> u64 {
    let mut h = base ^ 0x9E3779B97F4A7C15;
    h = h.wrapping_mul(0x100000001B3) ^ fnv1a(space_id.as_bytes());
    h = h.wrapping_mul(0x100000001B3) ^ fnv1a(opt_label.as_bytes());
    h = h.wrapping_mul(0x100000001B3) ^ run;
    avalanche(h)
}

/// Expand the (optimizer × space × seed) cross product into a flat job
/// batch. Jobs are grouped factory-major: job `(fi, si, r)` gets group
/// `fi * entries.len() + si`, so [`super::report::collate`] with
/// `factories.len() * entries.len()` groups reassembles per-(optimizer,
/// space) run lists in input order.
///
/// Seeds are derived from `factory.label()` — not the tuple's display
/// label — so a factory submitted in a grid gets the exact seeds
/// `run_many` would give it on each space (the display label may differ,
/// e.g. `gemm-info` for a genome whose own name seeds the runs).
pub fn grid_jobs<'a>(
    entries: &'a [Arc<SpaceEntry>],
    factories: &'a [(String, &'a dyn OptimizerFactory)],
    runs: usize,
    base_seed: u64,
) -> Vec<TuningJob<'a>> {
    let mut jobs = Vec::with_capacity(entries.len() * factories.len() * runs);
    for (fi, (_, factory)) in factories.iter().enumerate() {
        let seed_label = factory.label();
        for (si, e) in entries.iter().enumerate() {
            let space_id = e.cache.space_id();
            for r in 0..runs {
                jobs.push(TuningJob {
                    source: &e.cache,
                    setup: &e.setup,
                    factory: *factory,
                    seed: job_seed(base_seed, &space_id, &seed_label, r as u64),
                    group: fi * entries.len() + si,
                });
            }
        }
    }
    jobs
}

/// Expand an (optimizer × source × seed) grid over arbitrary backend
/// sources — the measured-path twin of [`grid_jobs`], used when the
/// spaces under test are not registry caches (e.g. lazily measured
/// variant spaces sharing one measurement store).
pub fn source_jobs<'a>(
    sources: &'a [(&'a dyn BackendSource, SpaceSetup)],
    factories: &'a [(String, &'a dyn OptimizerFactory)],
    runs: usize,
    base_seed: u64,
) -> Vec<TuningJob<'a>> {
    let mut jobs = Vec::with_capacity(sources.len() * factories.len() * runs);
    for (fi, (_, factory)) in factories.iter().enumerate() {
        let seed_label = factory.label();
        for (si, (source, setup)) in sources.iter().enumerate() {
            let space_id = source.space_id();
            for r in 0..runs {
                jobs.push(TuningJob {
                    source: *source,
                    setup,
                    factory: *factory,
                    seed: job_seed(base_seed, &space_id, &seed_label, r as u64),
                    group: fi * sources.len() + si,
                });
            }
        }
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_are_deterministic_and_coordinate_sensitive() {
        let s = job_seed(1, "gemm@A100", "ga", 0);
        assert_eq!(s, job_seed(1, "gemm@A100", "ga", 0));
        assert_ne!(s, job_seed(2, "gemm@A100", "ga", 0));
        assert_ne!(s, job_seed(1, "gemm@A4000", "ga", 0));
        assert_ne!(s, job_seed(1, "gemm@A100", "sa", 0));
        assert_ne!(s, job_seed(1, "gemm@A100", "ga", 1));
    }

    #[test]
    fn adjacent_runs_get_unrelated_seeds() {
        // Consecutive run indices must not map to nearby seeds (optimizer
        // RNG streams would correlate).
        let a = job_seed(7, "hotspot@W6600", "de", 10);
        let b = job_seed(7, "hotspot@W6600", "de", 11);
        assert!(a.abs_diff(b) > 1 << 20, "seeds too close: {} vs {}", a, b);
    }
}
