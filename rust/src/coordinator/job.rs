//! Tuning jobs: the unit of work the L3 scheduler executes.
//!
//! A [`TuningJob`] is one seeded tuning run — a (backend source, optimizer
//! factory, fully-derived seed) triple. The source mints one fresh
//! [`EvalBackend`](crate::tuning::EvalBackend) per run: a shared cached
//! space in simulation mode, or a shared measured-variant source on the
//! real-tune path — either way the job graph is identical. Batches of
//! jobs are what the coordinator parallelizes over: every figure/table of
//! the paper's evaluation is a cross product of spaces × optimizers ×
//! seeds, and [`grid_jobs`] expands that product into a flat,
//! order-independent list.
//!
//! Determinism contract: a job's result depends only on its `(source,
//! setup, factory, seed)` fields, never on which worker ran it or when.
//! Seeds are derived with [`job_seed`] from the experiment base seed and
//! the job's coordinates in the grid, so the same grid yields
//! bit-identical results regardless of thread count, execution order, or
//! how the batch was split.

use std::sync::Arc;

use super::executor::{FnSource, JobSource, Priority, SourcedJob};
use super::registry::SpaceEntry;
use crate::methodology::{runner::single_run_cancellable, OptimizerFactory, SpaceSetup};
use crate::tuning::BackendSource;
use crate::util::cancel::CancelToken;
use crate::util::rng::{avalanche, fnv1a};

/// One seeded tuning run against an evaluation-backend source. `Copy`
/// (the fields are shared references plus scalars), so sources can mint
/// jobs from borrowed grid state on demand.
#[derive(Clone, Copy)]
pub struct TuningJob<'a> {
    /// Mints the run's evaluation backend (shared across the batch).
    pub source: &'a dyn BackendSource,
    /// Precomputed baseline/budget/sample-times of that space.
    pub setup: &'a SpaceSetup,
    /// Fresh-instance factory for the optimizer under test.
    pub factory: &'a dyn OptimizerFactory,
    /// Fully-derived seed; determines the run bit-for-bit.
    pub seed: u64,
    /// Caller-assigned reassembly group (see [`super::report::collate`]).
    pub group: usize,
}

impl TuningJob<'_> {
    /// Execute the run and return its performance curve.
    pub fn execute(&self) -> Vec<f64> {
        self.execute_cancellable(&CancelToken::new())
            .expect("a fresh token cannot cancel the run")
    }

    /// Execute the run under a cooperative cancellation token. `None` if
    /// the run *observed* the fired token at a budget check (its partial
    /// trajectory is discarded — a truncated curve must never pass as a
    /// completed result); `Some(curve)` for a run that completed without
    /// observing it, bit-identical to the uncancelled run.
    pub fn execute_cancellable(&self, cancel: &CancelToken) -> Option<Vec<f64>> {
        let mut opt = self.factory.build();
        single_run_cancellable(self.source, self.setup, opt.as_mut(), self.seed, cancel)
    }

    /// Nominal evaluation cost of the run in integer microseconds: the
    /// space's time budget (`budget_s × 1e6`, rounded). Integer so sums
    /// over jobs are associative — a total accumulated per shard or per
    /// session is bit-identical to the single-batch total.
    pub fn cost_us(&self) -> u64 {
        (self.setup.budget_s * 1e6).round() as u64
    }
}

/// Derive the seed of one job from the experiment base seed and the job's
/// grid coordinates (space identity, optimizer label, run index).
///
/// Mixes each coordinate through FNV-1a and finishes with the SplitMix64
/// avalanche, so structurally close jobs (same space, adjacent run indices)
/// get statistically independent seeds, and permuting the grid or adding
/// optimizers/spaces never changes any other job's seed.
pub fn job_seed(base: u64, space_id: &str, opt_label: &str, run: u64) -> u64 {
    let mut h = base ^ 0x9E3779B97F4A7C15;
    h = h.wrapping_mul(0x100000001B3) ^ fnv1a(space_id.as_bytes());
    h = h.wrapping_mul(0x100000001B3) ^ fnv1a(opt_label.as_bytes());
    h = h.wrapping_mul(0x100000001B3) ^ run;
    avalanche(h)
}

/// Expand the (optimizer × space × seed) cross product into a flat job
/// batch. Jobs are grouped factory-major: job `(fi, si, r)` gets group
/// `fi * entries.len() + si`, so [`super::report::collate`] with
/// `factories.len() * entries.len()` groups reassembles per-(optimizer,
/// space) run lists in input order.
///
/// Seeds are derived from `factory.label()` — not the tuple's display
/// label — so a factory submitted in a grid gets the exact seeds
/// `run_many` would give it on each space (the display label may differ,
/// e.g. `gemm-info` for a genome whose own name seeds the runs).
pub fn grid_jobs<'a>(
    entries: &'a [Arc<SpaceEntry>],
    factories: &'a [(String, &'a dyn OptimizerFactory)],
    runs: usize,
    base_seed: u64,
) -> Vec<TuningJob<'a>> {
    collect_jobs(&mut grid_source(entries, factories, runs, base_seed))
}

/// The one factory-major decomposition behind both streamed grids: flat
/// index `i` decodes to `(factory fi, entry si, run r)` with group
/// `fi * n_entries + si`; `entry_at` resolves `si` to its backend source
/// and setup. Keeping [`grid_source`] and [`source_jobs_source`] on this
/// single core means the index arithmetic, seed derivation and group
/// formula cannot drift apart.
fn product_source<'a, G>(
    n_entries: usize,
    factories: &'a [(String, &'a dyn OptimizerFactory)],
    runs: usize,
    base_seed: u64,
    space_ids: Vec<String>,
    entry_at: G,
) -> FnSource<impl FnMut(usize) -> SourcedJob<'a> + Send + 'a>
where
    G: Fn(usize) -> (&'a dyn BackendSource, &'a SpaceSetup) + Send + 'a,
{
    let seed_labels: Vec<String> = factories.iter().map(|(_, f)| f.label()).collect();
    let per_factory = n_entries * runs;
    FnSource::new(n_entries * factories.len() * runs, move |i| {
        let (fi, rem) = (i / per_factory, i % per_factory);
        let (si, r) = (rem / runs, rem % runs);
        let (source, setup) = entry_at(si);
        TuningJob {
            source,
            setup,
            factory: factories[fi].1,
            seed: job_seed(base_seed, &space_ids[si], &seed_labels[fi], r as u64),
            group: fi * n_entries + si,
        }
        .into()
    })
}

/// The streaming twin of [`grid_jobs`]: the identical factory-major job
/// sequence (same slots, seeds and groups — [`grid_jobs`] is literally
/// this source collected), generated lazily from the flat index so the
/// executor's bounded queue, not the grid size, bounds memory.
pub fn grid_source<'a>(
    entries: &'a [Arc<SpaceEntry>],
    factories: &'a [(String, &'a dyn OptimizerFactory)],
    runs: usize,
    base_seed: u64,
) -> FnSource<impl FnMut(usize) -> SourcedJob<'a> + Send + 'a> {
    product_source(
        entries.len(),
        factories,
        runs,
        base_seed,
        entries.iter().map(|e| e.cache.space_id()).collect(),
        |si| (&entries[si].cache as &dyn BackendSource, &entries[si].setup),
    )
}

/// Drain a source into the materialized job list (the eager views over
/// the lazy generators; also handy in tests).
pub fn collect_jobs<'a>(source: &mut dyn JobSource<'a>) -> Vec<TuningJob<'a>> {
    std::iter::from_fn(|| source.next_job().map(|sj| sj.job)).collect()
}

/// A [`TuningJob`] that owns its world: the registry entry and optimizer
/// spec are held by `Arc` instead of borrowed, so the job can outlive the
/// stack frame that minted it. This is the unit the `serve` daemon's
/// persistent pool executes — borrowed `TuningJob`s force every batch to
/// pin a caller stack frame for its whole lifetime (the `Executor`'s
/// scoped-thread model), while owned jobs let one long-lived pool drain
/// batches submitted by many short-lived sessions.
///
/// Determinism: [`Self::as_job`] reborrows the exact `(source, setup,
/// factory, seed, group)` quintuple a borrowed grid would carry, so an
/// owned job's curve is bit-identical to its borrowed counterpart.
#[derive(Clone)]
pub struct OwnedJob {
    pub entry: Arc<SpaceEntry>,
    pub spec: Arc<crate::optimizers::OptimizerSpec>,
    pub seed: u64,
    pub group: usize,
    pub priority: Priority,
}

impl OwnedJob {
    /// The borrowed view the execution seams consume. The `OptimizerSpec`
    /// itself is the factory (it implements
    /// [`OptimizerFactory`]), so seeds derived from `spec.label()`
    /// match the direct CLI grid exactly.
    pub fn as_job(&self) -> TuningJob<'_> {
        TuningJob {
            source: &self.entry.cache,
            setup: &self.entry.setup,
            factory: &*self.spec,
            seed: self.seed,
            group: self.group,
        }
    }

    /// Nominal evaluation cost in integer microseconds (see
    /// [`TuningJob::cost_us`]).
    pub fn cost_us(&self) -> u64 {
        self.as_job().cost_us()
    }

    /// The owned twin of [`grid_jobs`]: the identical factory-major
    /// (optimizer × space × seed) sequence — same slots, seeds, groups —
    /// materialized as owned jobs (all at priority 0; callers band them
    /// afterwards). Pinned against [`grid_jobs`] in this module's tests so
    /// the two expansions cannot drift.
    pub fn grid(
        entries: &[Arc<SpaceEntry>],
        specs: &[Arc<crate::optimizers::OptimizerSpec>],
        runs: usize,
        base_seed: u64,
    ) -> Vec<OwnedJob> {
        let space_ids: Vec<String> = entries.iter().map(|e| e.cache.space_id()).collect();
        let mut jobs = Vec::with_capacity(entries.len() * specs.len() * runs);
        for (fi, spec) in specs.iter().enumerate() {
            let seed_label = spec.label();
            for (si, entry) in entries.iter().enumerate() {
                for r in 0..runs {
                    jobs.push(OwnedJob {
                        entry: Arc::clone(entry),
                        spec: Arc::clone(spec),
                        seed: job_seed(base_seed, &space_ids[si], &seed_label, r as u64),
                        group: fi * entries.len() + si,
                        priority: 0,
                    });
                }
            }
        }
        jobs
    }
}

/// Expand an (optimizer × source × seed) grid over arbitrary backend
/// sources — the measured-path twin of [`grid_jobs`], used when the
/// spaces under test are not registry caches (e.g. lazily measured
/// variant spaces sharing one measurement store).
pub fn source_jobs<'a>(
    sources: &'a [(&'a dyn BackendSource, SpaceSetup)],
    factories: &'a [(String, &'a dyn OptimizerFactory)],
    runs: usize,
    base_seed: u64,
) -> Vec<TuningJob<'a>> {
    collect_jobs(&mut source_jobs_source(sources, factories, runs, base_seed))
}

/// The streaming twin of [`source_jobs`] (same relationship as
/// [`grid_source`] to [`grid_jobs`]).
pub fn source_jobs_source<'a>(
    sources: &'a [(&'a dyn BackendSource, SpaceSetup)],
    factories: &'a [(String, &'a dyn OptimizerFactory)],
    runs: usize,
    base_seed: u64,
) -> FnSource<impl FnMut(usize) -> SourcedJob<'a> + Send + 'a> {
    product_source(
        sources.len(),
        factories,
        runs,
        base_seed,
        sources.iter().map(|(s, _)| s.space_id()).collect(),
        |si| (sources[si].0, &sources[si].1),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_are_deterministic_and_coordinate_sensitive() {
        let s = job_seed(1, "gemm@A100", "ga", 0);
        assert_eq!(s, job_seed(1, "gemm@A100", "ga", 0));
        assert_ne!(s, job_seed(2, "gemm@A100", "ga", 0));
        assert_ne!(s, job_seed(1, "gemm@A4000", "ga", 0));
        assert_ne!(s, job_seed(1, "gemm@A100", "sa", 0));
        assert_ne!(s, job_seed(1, "gemm@A100", "ga", 1));
    }

    #[test]
    fn grid_source_matches_the_verbatim_nested_loop() {
        // `grid_jobs` is the collected `grid_source`; pin the lazy index
        // arithmetic against a verbatim port of the pre-streaming loop.
        use crate::coordinator::registry::{CacheKey, CacheRegistry};
        use crate::methodology::NamedFactory;
        let reg = CacheRegistry::new();
        let entries = vec![
            reg.entry(CacheKey::parse("convolution@A4000").unwrap()),
            reg.entry(CacheKey::parse("convolution@W6600").unwrap()),
        ];
        let named: Vec<(String, NamedFactory)> = ["sa", "random"]
            .iter()
            .map(|n| (n.to_string(), NamedFactory(n.to_string())))
            .collect();
        let factories: Vec<(String, &dyn OptimizerFactory)> =
            named.iter().map(|(l, f)| (l.clone(), f as &dyn OptimizerFactory)).collect();
        let runs = 3;
        let jobs = grid_jobs(&entries, &factories, runs, 17);
        let mut expected: Vec<(u64, usize)> = Vec::new();
        for (fi, (_, factory)) in factories.iter().enumerate() {
            let seed_label = factory.label();
            for (si, e) in entries.iter().enumerate() {
                let space_id = e.cache.space_id();
                for r in 0..runs {
                    expected.push((
                        job_seed(17, &space_id, &seed_label, r as u64),
                        fi * entries.len() + si,
                    ));
                }
            }
        }
        let got: Vec<(u64, usize)> = jobs.iter().map(|j| (j.seed, j.group)).collect();
        assert_eq!(got, expected);

        // And the source_jobs flavor, against its own verbatim loop (the
        // shared core makes them agree, but pin each public surface).
        let sources: Vec<(&dyn BackendSource, SpaceSetup)> = entries
            .iter()
            .map(|e| (&e.cache as &dyn BackendSource, SpaceSetup::new(&e.cache)))
            .collect();
        let sjobs = source_jobs(&sources, &factories, runs, 17);
        let mut sexpected: Vec<(u64, usize)> = Vec::new();
        for (fi, (_, factory)) in factories.iter().enumerate() {
            let seed_label = factory.label();
            for (si, (source, _)) in sources.iter().enumerate() {
                let space_id = source.space_id();
                for r in 0..runs {
                    sexpected.push((
                        job_seed(17, &space_id, &seed_label, r as u64),
                        fi * sources.len() + si,
                    ));
                }
            }
        }
        let sgot: Vec<(u64, usize)> = sjobs.iter().map(|j| (j.seed, j.group)).collect();
        assert_eq!(sgot, sexpected);
    }

    #[test]
    fn owned_grid_matches_the_borrowed_grid_exactly() {
        // `OwnedJob::grid` must mint the same factory-major sequence as
        // `grid_jobs` — same seeds, same groups, same curves — or the
        // daemon's served reports drift from the direct CLI's.
        use crate::coordinator::registry::{CacheKey, CacheRegistry};
        use crate::optimizers::OptimizerSpec;
        let reg = CacheRegistry::new();
        let entries = vec![
            reg.entry(CacheKey::parse("convolution@A4000").unwrap()),
            reg.entry(CacheKey::parse("convolution@W6600").unwrap()),
        ];
        let specs: Vec<Arc<OptimizerSpec>> = ["sa", "random"]
            .iter()
            .map(|n| Arc::new(OptimizerSpec::parse(n).unwrap()))
            .collect();
        let factories: Vec<(String, &dyn OptimizerFactory)> = specs
            .iter()
            .map(|s| (s.label(), &**s as &dyn OptimizerFactory))
            .collect();
        let runs = 2;
        let borrowed = grid_jobs(&entries, &factories, runs, 23);
        let owned = OwnedJob::grid(&entries, &specs, runs, 23);
        assert_eq!(owned.len(), borrowed.len());
        for (o, b) in owned.iter().zip(&borrowed) {
            assert_eq!(o.seed, b.seed);
            assert_eq!(o.group, b.group);
            assert_eq!(o.priority, 0);
            assert_eq!(o.cost_us(), b.cost_us());
        }
        // Spot-check execution identity on the first job of each group.
        let first_of_group: Vec<usize> =
            (0..4).map(|g| owned.iter().position(|j| j.group == g).unwrap()).collect();
        for &i in &first_of_group {
            assert_eq!(owned[i].as_job().execute(), borrowed[i].execute());
        }
    }

    #[test]
    fn adjacent_runs_get_unrelated_seeds() {
        // Consecutive run indices must not map to nearby seeds (optimizer
        // RNG streams would correlate).
        let a = job_seed(7, "hotspot@W6600", "de", 10);
        let b = job_seed(7, "hotspot@W6600", "de", 11);
        assert!(a.abs_diff(b) > 1 << 20, "seeds too close: {} vs {}", a, b);
    }
}
