//! Optimizer portfolio racing: bandit budget reallocation over the
//! executor's priority/cancel seam.
//!
//! The paper's central observation is that no single optimizer dominates
//! across (kernel, GPU, budget) triples. A **race** exploits that at
//! runtime: many optimizers (the *arms* — any registry spec, including
//! LLaMEA genomes) attack the same space as one streamed batch, and a
//! UCB1 bandit reallocates evaluation budget toward whoever is winning
//! instead of draining the full grid uniformly.
//!
//! ## Vocabulary
//!
//! - **Arm**: one `OptimizerSpec` in the portfolio. Each arm's seed is
//!   `job_seed(seed, space, label, 0)` — exactly the seed a
//!   `coordinate --runs 1` grid gives that optimizer on that space.
//! - **Rung**: one Hyperband-style budget level. Rung `k` of `R` runs
//!   every surviving arm as a *complete, uninterrupted* tuning job at
//!   budget `B / eta^(R−1−k)`; the final rung uses the space's canonical
//!   [`SpaceSetup`] verbatim (same budget, same sample grid), so a
//!   finalist's curve is bit-identical to its standalone run.
//! - **Decision**: at each rung boundary the bandit ingests one reward
//!   per arm — observed score improvement per modeled second spent
//!   ([`rung_rewards`]), min-max normalized across the rung — and keeps
//!   the top `⌈n/eta⌉` by UCB ([`crate::hypertune::halving_keep`], the
//!   same rule as hypertune's successive halving), always including the
//!   incumbent (current best score). Survivors' job [`Priority`]s are
//!   escalated by UCB rank; each eliminated arm has a pre-fired
//!   [`CancelToken`] attached to one last next-rung job, so its
//!   cancellation flows through the real executor seam (counted in the
//!   batch's `JobsSummary`) instead of being silently skipped.
//! - **Winner**: the best final-rung score (ties to the lowest arm
//!   ordinal).
//!
//! A single surviving arm short-circuits the remaining intermediate
//! rungs and jumps straight to the final full-budget rung — the
//! "hopeless rungs are never drained" half of Hyperband.
//!
//! ## Determinism contract
//!
//! Bandit decisions consume only the deterministic modeled signals — the
//! simulated-clock trajectory (`spent_s`, scores from performance
//! curves) — never wall-clock or `obs` measurements. Decisions happen
//! only at rung boundaries, after every roster job has a slot-indexed
//! outcome (pre-fired tokens cancel deterministically at the first
//! budget check), so a race outcome is a pure function of
//! `(entry, specs, eta, rungs, seed)`: byte-identical reports for any
//! `--threads` width, and a curve that completes under racing is
//! bit-identical to its standalone run (cancellation varies *which* arms
//! finish, never a finished curve — the PR 5 invariant).
//!
//! Instrumentation (`race.decision` spans, `race.escalations` /
//! `race.cancellations` counters) is strictly out-of-band, like every
//! other `obs` hook.

use std::sync::{Arc, Mutex};

use super::executor::{
    Executor, FnSource, JobOutcome, JobsSummary, Priority, Progress, ProgressSink, SourcedJob,
};
use super::job::{job_seed, TuningJob};
use super::registry::SpaceEntry;
use crate::hypertune::halving_keep;
use crate::methodology::curve::sample_times;
use crate::methodology::{Baseline, OptimizerFactory, SpaceSetup};
use crate::obs;
use crate::optimizers::{Optimizer, OptimizerSpec};
use crate::tuning::{BackendSource, TuningContext};
use crate::util::cancel::CancelToken;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::table::{f, Table};

/// Title of the race report (the analog of `COORDINATE_TITLE`).
pub const RACE_TITLE: &str = "LLaMEA-KT portfolio race";

/// Race parameters. `eta`/`rungs` shape the budget ladder; `seed` feeds
/// [`job_seed`]; `cancel` is the external (Ctrl-C) token — per-arm racing
/// tokens are managed internally.
#[derive(Clone)]
pub struct RaceConfig {
    /// Halving reduction factor (clamped to ≥ 2).
    pub eta: usize,
    /// Number of budget rungs (clamped to ≥ 1); the final rung runs at
    /// the space's full canonical budget.
    pub rungs: usize,
    /// Base seed for [`job_seed`] derivation.
    pub seed: u64,
    /// Worker count (`None` = process default). Never changes output.
    pub threads: Option<usize>,
    /// External cancellation (SIGINT); fires `interrupted` outcomes.
    pub cancel: Option<CancelToken>,
}

impl Default for RaceConfig {
    fn default() -> RaceConfig {
        RaceConfig { eta: 2, rungs: 3, seed: 0, threads: None, cancel: None }
    }
}

/// A UCB1 bandit over a fixed arm set. Deterministic: no randomness —
/// `rank_subset` breaks ties by ascending arm ordinal, and unplayed arms
/// rank first (infinite optimism), also by ordinal.
#[derive(Debug, Clone, PartialEq)]
pub struct Bandit {
    sums: Vec<f64>,
    plays: Vec<u64>,
    total: u64,
}

impl Bandit {
    pub fn new(arms: usize) -> Bandit {
        Bandit { sums: vec![0.0; arms], plays: vec![0; arms], total: 0 }
    }

    pub fn arms(&self) -> usize {
        self.plays.len()
    }

    /// Ingest one reward observation (non-finite rewards count as 0).
    pub fn update(&mut self, arm: usize, reward: f64) {
        self.sums[arm] += if reward.is_finite() { reward } else { 0.0 };
        self.plays[arm] += 1;
        self.total += 1;
    }

    /// The UCB1 index: mean reward plus the exploration bonus
    /// `sqrt(2 ln T / n_arm)`; infinite for unplayed arms.
    pub fn ucb(&self, arm: usize) -> f64 {
        let n = self.plays[arm];
        if n == 0 {
            return f64::INFINITY;
        }
        let mean = self.sums[arm] / n as f64;
        mean + (2.0 * (self.total.max(1) as f64).ln() / n as f64).sqrt()
    }

    /// Rank a subset of arms by UCB, best first; ties (including the
    /// all-infinite cold start) break by ascending arm ordinal.
    pub fn rank_subset(&self, arms: &[usize]) -> Vec<usize> {
        let mut ranked: Vec<usize> = arms.to_vec();
        ranked.sort_by(|&a, &b| self.ucb(b).total_cmp(&self.ucb(a)).then(a.cmp(&b)));
        ranked
    }
}

/// Deterministic per-run statistics captured from the tuning context by
/// the probe wrapper — all modeled (simulated clock), never wall-clock.
#[derive(Debug, Clone, Copy)]
pub struct ArmStats {
    pub evals: u64,
    pub unique_evals: u64,
    /// Modeled seconds consumed (`ctx.elapsed_s()`).
    pub spent_s: f64,
    pub best_ms: f64,
}

/// One rung's reward inputs for a single arm: `(arm, score, prev_score,
/// spent_s)` — this rung's score, the arm's previous-rung score (0 on the
/// first rung), and the modeled seconds the rung consumed.
pub type RewardInput = (usize, f64, f64, f64);

/// The bandit reward of each arm for one rung: raw reward is score
/// improvement per modeled second (`max(0, score − prev) / spent`),
/// min-max normalized to `[0, 1]` across the rung so one space's score
/// scale never drowns the exploration bonus. A degenerate rung (all
/// equal) rewards everyone 0.5.
pub fn rung_rewards(inputs: &[RewardInput]) -> Vec<(usize, f64)> {
    let raw: Vec<(usize, f64)> = inputs
        .iter()
        .map(|&(arm, score, prev, spent)| (arm, (score - prev).max(0.0) / spent.max(1e-9)))
        .collect();
    let lo = raw.iter().map(|&(_, r)| r).fold(f64::INFINITY, f64::min);
    let hi = raw.iter().map(|&(_, r)| r).fold(f64::NEG_INFINITY, f64::max);
    raw.iter()
        .map(|&(arm, r)| (arm, if hi > lo { (r - lo) / (hi - lo) } else { 0.5 }))
        .collect()
}

/// One rung-boundary decision: feed the rewards to the bandit, rank the
/// live arms by UCB, keep [`halving_keep`] survivors — always including
/// the incumbent (best `last_score`, ties to the lowest ordinal), which
/// displaces the worst-ranked survivor if the bandit dropped it. Returns
/// `(survivors, eliminated)`, both ascending. Pure — replayable from a
/// recorded reward trajectory.
pub fn decide(
    bandit: &mut Bandit,
    live: &[usize],
    rewards: &[(usize, f64)],
    last_score: &[f64],
    eta: usize,
) -> (Vec<usize>, Vec<usize>) {
    for &(arm, r) in rewards {
        bandit.update(arm, r);
    }
    let ranked = bandit.rank_subset(live);
    let keep = halving_keep(live.len(), eta);
    let mut survivors: Vec<usize> = ranked.iter().take(keep).copied().collect();
    let incumbent = live
        .iter()
        .copied()
        .max_by(|&a, &b| last_score[a].total_cmp(&last_score[b]).then(b.cmp(&a)));
    if let Some(inc) = incumbent {
        if !survivors.contains(&inc) {
            survivors.pop();
            survivors.push(inc);
        }
    }
    survivors.sort_unstable();
    let eliminated: Vec<usize> =
        live.iter().copied().filter(|a| !survivors.contains(a)).collect();
    (survivors, eliminated)
}

/// The record of one rung boundary, kept in the outcome so decisions can
/// be replayed (and are, in `integration_race.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    pub rung: usize,
    /// The rung's per-arm budget (modeled seconds).
    pub budget_s: f64,
    /// Normalized rewards fed to the bandit, by arm ordinal.
    pub rewards: Vec<(usize, f64)>,
    pub survivors: Vec<usize>,
    pub eliminated: Vec<usize>,
}

/// Everything the race learned about one arm.
#[derive(Debug, Clone)]
pub struct ArmResult {
    pub label: String,
    /// Cumulative across rungs (modeled signals from [`ArmStats`]).
    pub evals: u64,
    pub unique_evals: u64,
    pub spent_s: f64,
    /// Score of each completed rung, in rung order.
    pub scores: Vec<f64>,
    pub cancelled_jobs: usize,
    pub failed_jobs: usize,
    /// Rung index of the decision that eliminated the arm.
    pub eliminated_at: Option<usize>,
    /// Final-rung performance curve — present only for finalists, and
    /// bit-identical to the arm's standalone `coordinate --runs 1` run.
    pub curve: Option<Vec<f64>>,
    /// Final-rung score (`stats::mean` of `curve`).
    pub score: Option<f64>,
}

/// The outcome of one race on one space.
#[derive(Debug, Clone)]
pub struct RaceOutcome {
    pub space: String,
    pub arms: Vec<ArmResult>,
    pub decisions: Vec<Decision>,
    /// Winning arm ordinal (`None` only for interrupted/degenerate races).
    pub winner: Option<usize>,
    pub escalations: u64,
    pub cancellations: u64,
    pub jobs: JobsSummary,
    pub interrupted: bool,
}

impl RaceOutcome {
    /// The race's best-found score: the winner's final-rung score.
    pub fn best_score(&self) -> Option<f64> {
        self.winner.and_then(|w| self.arms[w].score)
    }
}

/// The probe wrapper: runs the arm's real optimizer with the arm's
/// racing token attached (alongside the executor's batch token — the
/// multi-token `TuningContext` seam), then stashes the run's modeled
/// statistics for the bandit. Transparent otherwise: the inner optimizer
/// sees the exact context a standalone run would, so completed curves
/// stay bit-identical.
struct ProbedOptimizer {
    inner: Box<dyn Optimizer>,
    token: CancelToken,
    out: Arc<Mutex<Option<ArmStats>>>,
}

impl Optimizer for ProbedOptimizer {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        ctx.set_cancel_token(self.token.clone());
        self.inner.run(ctx);
        let stats = ArmStats {
            evals: ctx.eval_calls(),
            unique_evals: ctx.unique_evals(),
            spent_s: ctx.elapsed_s(),
            best_ms: ctx.best().map(|(_, v)| v).unwrap_or(f64::INFINITY),
        };
        *self.out.lock().unwrap_or_else(|e| e.into_inner()) = Some(stats);
    }
}

/// Per-roster-slot factory: builds the arm's optimizer wrapped in the
/// probe. `label()` delegates to the spec so seeds derived from it match
/// the plain `coordinate` grid exactly.
struct ArmFactory {
    spec: OptimizerSpec,
    token: CancelToken,
    stats: Arc<Mutex<Option<ArmStats>>>,
}

impl OptimizerFactory for ArmFactory {
    fn build(&self) -> Box<dyn Optimizer> {
        Box::new(ProbedOptimizer {
            inner: self.spec.build(),
            token: self.token.clone(),
            out: Arc::clone(&self.stats),
        })
    }

    fn label(&self) -> String {
        self.spec.label()
    }
}

/// Race a portfolio on one space (no progress consumer).
pub fn run_race(entry: &SpaceEntry, specs: &[OptimizerSpec], cfg: &RaceConfig) -> RaceOutcome {
    run_race_observed(entry, specs, cfg, &|_: &Progress| {})
}

/// Race a portfolio on one space, streaming each rung's [`Progress`]
/// events to `sink`. See the module docs for the algorithm and the
/// determinism contract.
pub fn run_race_observed(
    entry: &SpaceEntry,
    specs: &[OptimizerSpec],
    cfg: &RaceConfig,
    sink: &ProgressSink,
) -> RaceOutcome {
    let n = specs.len();
    let rungs = cfg.rungs.max(1);
    let eta = cfg.eta.max(2);
    let space_id = entry.cache.space_id();
    let seeds: Vec<u64> =
        specs.iter().map(|s| job_seed(cfg.seed, &space_id, &s.label(), 0)).collect();
    let arms: Vec<ArmResult> = specs
        .iter()
        .map(|s| ArmResult {
            label: s.label(),
            evals: 0,
            unique_evals: 0,
            spent_s: 0.0,
            scores: Vec::new(),
            cancelled_jobs: 0,
            failed_jobs: 0,
            eliminated_at: None,
            curve: None,
            score: None,
        })
        .collect();
    let mut out = RaceOutcome {
        space: space_id,
        arms,
        decisions: Vec::new(),
        winner: None,
        escalations: 0,
        cancellations: 0,
        jobs: JobsSummary::default(),
        interrupted: false,
    };
    if n == 0 {
        return out;
    }
    let mut bandit = Bandit::new(n);
    let mut live: Vec<usize> = (0..n).collect();
    // Arms eliminated at the previous decision: each gets one more job
    // next rung with a pre-fired token, so its cancellation is observed
    // at the first budget check and flows through the executor seam.
    let mut doomed: Vec<usize> = Vec::new();
    let mut last_priority: Vec<Priority> = vec![Priority::MIN; n];
    let mut rung = 0usize;
    while rung < rungs {
        let is_final = rung + 1 == rungs;
        if !is_final && live.len() == 1 && doomed.is_empty() {
            // A lone survivor has nothing left to race: skip the
            // intermediate rungs and score it at full budget.
            rung = rungs - 1;
            continue;
        }
        // Budget ladder: B / eta^(R−1−k); the final rung reuses the
        // canonical setup verbatim so finalist curves are bit-identical
        // to standalone runs (same budget AND same sample-time grid).
        let scaled;
        let setup: &SpaceSetup = if is_final {
            &entry.setup
        } else {
            let denom = (eta as f64).powi((rungs - 1 - rung) as i32);
            let b = entry.setup.budget_s / denom;
            scaled = SpaceSetup {
                baseline: Baseline::from_cache(&entry.cache),
                budget_s: b,
                times: sample_times(b, entry.setup.times.len()),
            };
            &scaled
        };
        // Roster: survivors by UCB rank (priority escalates every rung a
        // survivor outlives — the rung offset keeps later-rung jobs above
        // earlier levels), then the doomed arms at the bottom.
        let ranked = bandit.rank_subset(&live);
        let mut roster: Vec<(usize, Priority, CancelToken)> = Vec::new();
        for (r_i, &arm) in ranked.iter().enumerate() {
            let prio = (rung * n + (live.len() - r_i)) as Priority;
            if prio > last_priority[arm] && last_priority[arm] != Priority::MIN {
                out.escalations += 1;
                obs::counter("race.escalations", 1);
            }
            last_priority[arm] = prio;
            roster.push((arm, prio, CancelToken::new()));
        }
        for &arm in &doomed {
            let token = CancelToken::new();
            token.cancel(); // pre-fired: observed at the first budget check
            roster.push((arm, Priority::MIN, token));
        }
        doomed.clear();
        let slots: Vec<Arc<Mutex<Option<ArmStats>>>> =
            roster.iter().map(|_| Arc::new(Mutex::new(None))).collect();
        let factories: Vec<ArmFactory> = roster
            .iter()
            .zip(&slots)
            .map(|((arm, _, token), slot)| ArmFactory {
                spec: specs[*arm].clone(),
                token: token.clone(),
                stats: Arc::clone(slot),
            })
            .collect();
        let mut ex = Executor::with_threads(cfg.threads);
        if let Some(token) = &cfg.cancel {
            ex = ex.cancel_via(token.clone());
        }
        let mut source = FnSource::new(roster.len(), |i| {
            let (arm, prio, _) = &roster[i];
            SourcedJob {
                job: TuningJob {
                    source: &entry.cache,
                    setup,
                    factory: &factories[i],
                    seed: seeds[*arm],
                    group: *arm,
                },
                priority: *prio,
            }
        });
        let batch = ex.run_observed(&mut source, sink);
        out.jobs.absorb(batch.summary());
        let mut span = obs::span("race.decision")
            .kv("rung", rung)
            .kv("roster", roster.len())
            .kv("budget_s", setup.budget_s);
        // Harvest slot-ordered outcomes.
        let mut rung_spent: Vec<f64> = vec![0.0; n];
        for (slot, (arm, _, _)) in roster.iter().enumerate() {
            match &batch.handles[slot].outcome {
                JobOutcome::Completed(curve) => {
                    let score = stats::mean(curve);
                    out.arms[*arm].scores.push(score);
                    if let Some(st) = slots[slot].lock().unwrap_or_else(|e| e.into_inner()).take()
                    {
                        out.arms[*arm].evals += st.evals;
                        out.arms[*arm].unique_evals += st.unique_evals;
                        out.arms[*arm].spent_s += st.spent_s;
                        rung_spent[*arm] = st.spent_s;
                    }
                    if is_final {
                        out.arms[*arm].score = Some(score);
                        out.arms[*arm].curve = Some(curve.clone());
                    }
                }
                JobOutcome::Cancelled => {
                    out.arms[*arm].cancelled_jobs += 1;
                    out.cancellations += 1;
                    obs::counter("race.cancellations", 1);
                }
                JobOutcome::Failed(_) => out.arms[*arm].failed_jobs += 1,
            }
        }
        if cfg.cancel.as_ref().is_some_and(|t| t.is_cancelled()) {
            out.interrupted = true;
            span.note("outcome", "interrupted");
            return out;
        }
        // Arms still rankable (a panicked arm drops out of the race — it
        // has no score to rank on).
        let live_done: Vec<usize> =
            live.iter().copied().filter(|&a| !out.arms[a].scores.is_empty()).collect();
        if live_done.is_empty() {
            span.note("outcome", "dead");
            return out;
        }
        if is_final {
            let winner = live_done
                .iter()
                .copied()
                .filter(|&a| out.arms[a].score.is_some())
                .max_by(|&a, &b| {
                    let sa = out.arms[a].score.unwrap_or(f64::NEG_INFINITY);
                    let sb = out.arms[b].score.unwrap_or(f64::NEG_INFINITY);
                    sa.total_cmp(&sb).then(b.cmp(&a))
                });
            out.winner = winner;
            if let Some(w) = winner {
                span.note("winner", w);
            }
        } else {
            let inputs: Vec<RewardInput> = live_done
                .iter()
                .map(|&a| {
                    let s = &out.arms[a].scores;
                    let cur = *s.last().unwrap();
                    let prev = if s.len() >= 2 { s[s.len() - 2] } else { 0.0 };
                    (a, cur, prev, rung_spent[a])
                })
                .collect();
            let rewards = rung_rewards(&inputs);
            let last: Vec<f64> = (0..n)
                .map(|a| out.arms[a].scores.last().copied().unwrap_or(f64::NEG_INFINITY))
                .collect();
            let (survivors, eliminated) = decide(&mut bandit, &live_done, &rewards, &last, eta);
            span.note("survivors", survivors.len());
            span.note("eliminated", eliminated.len());
            for &a in &eliminated {
                out.arms[a].eliminated_at = Some(rung);
            }
            out.decisions.push(Decision {
                rung,
                budget_s: setup.budget_s,
                rewards,
                survivors: survivors.clone(),
                eliminated: eliminated.clone(),
            });
            doomed = eliminated;
            live = survivors;
        }
        drop(span);
        rung += 1;
    }
    out
}

/// The per-space `"race"` report block: winner, counters, per-arm
/// accounting and the decision trace. A pure function of the outcome —
/// no wall-clock, no thread counts — so report bytes are identical for
/// any `--threads` width.
pub fn race_json(outcome: &RaceOutcome) -> Json {
    let mut j = Json::obj();
    j.set("space", outcome.space.clone());
    if let Some(w) = outcome.winner {
        j.set("winner", outcome.arms[w].label.clone());
    }
    j.set("escalations", outcome.escalations);
    j.set("cancellations", outcome.cancellations as u64);
    if outcome.interrupted {
        j.set("interrupted", true);
    }
    j.set("jobs", outcome.jobs.to_json());
    let mut arms: Vec<Json> = Vec::with_capacity(outcome.arms.len());
    for a in &outcome.arms {
        let mut row = Json::obj();
        row.set("label", a.label.clone());
        row.set("evals", a.evals);
        row.set("unique_evals", a.unique_evals);
        row.set("spent_s", a.spent_s);
        row.set("scores", a.scores.clone());
        row.set("cancelled_jobs", a.cancelled_jobs);
        if let Some(r) = a.eliminated_at {
            row.set("eliminated_at", r);
        }
        if let Some(s) = a.score {
            row.set("score", s);
        }
        arms.push(row);
    }
    j.set("arms", Json::Arr(arms));
    let mut decisions: Vec<Json> = Vec::with_capacity(outcome.decisions.len());
    for d in &outcome.decisions {
        let mut row = Json::obj();
        row.set("rung", d.rung);
        row.set("budget_s", d.budget_s);
        let label = |&a: &usize| Json::from(outcome.arms[a].label.clone());
        row.set("survivors", Json::Arr(d.survivors.iter().map(label).collect()));
        row.set("eliminated", Json::Arr(d.eliminated.iter().map(label).collect()));
        let mut rw: Vec<Json> = Vec::with_capacity(d.rewards.len());
        for &(a, r) in &d.rewards {
            let mut e = Json::obj();
            e.set("arm", outcome.arms[a].label.clone());
            e.set("reward", r);
            rw.push(e);
        }
        row.set("rewards", Json::Arr(rw));
        decisions.push(row);
    }
    j.set("decisions", Json::Arr(decisions));
    j
}

/// The full `race --out` report: header, aggregate `"jobs"` counters and
/// one [`race_json`] block per raced space.
pub fn race_report(outcomes: &[RaceOutcome], cfg: &RaceConfig) -> Json {
    let mut j = Json::obj();
    j.set("title", RACE_TITLE);
    j.set(
        "spaces",
        Json::Arr(outcomes.iter().map(|o| Json::from(o.space.clone())).collect()),
    );
    j.set("eta", cfg.eta.max(2));
    j.set("rungs", cfg.rungs.max(1));
    j.set("seed", cfg.seed);
    if outcomes.iter().any(|o| o.interrupted) {
        j.set("interrupted", true);
    }
    let mut jobs = JobsSummary::default();
    for o in outcomes {
        jobs.absorb(o.jobs);
    }
    j.set("jobs", jobs.to_json());
    j.set("race", Json::Arr(outcomes.iter().map(race_json).collect()));
    j
}

/// Render one race outcome for the CLI.
pub fn race_table(outcome: &RaceOutcome) -> Table {
    let title = format!("{} — {}", RACE_TITLE, outcome.space);
    let mut t = Table::new(&title, &["Arm", "Rungs", "Evals", "Spent s", "Score P", "Status"]);
    for (i, a) in outcome.arms.iter().enumerate() {
        let status = if outcome.winner == Some(i) {
            "winner".to_string()
        } else if let Some(r) = a.eliminated_at {
            format!("eliminated @ rung {}", r)
        } else if a.failed_jobs > 0 {
            "failed".to_string()
        } else {
            "finalist".to_string()
        };
        let score = a.score.or(a.scores.last().copied());
        t.row(vec![
            a.label.clone(),
            format!("{}", a.scores.len()),
            format!("{}", a.evals),
            f(a.spent_s, 1),
            score.map(|s| f(s, 3)).unwrap_or_else(|| "-".into()),
            status,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::{CacheKey, CacheRegistry};

    #[test]
    fn bandit_is_deterministic_and_optimistic() {
        let mut b = Bandit::new(4);
        // Cold start: all infinite, ordinal order.
        assert_eq!(b.rank_subset(&[2, 0, 3, 1]), vec![0, 1, 2, 3]);
        b.update(0, 0.1);
        b.update(1, 0.9);
        b.update(2, 0.5);
        // Unplayed arm 3 stays first (infinite optimism), then by UCB.
        let ranked = b.rank_subset(&[0, 1, 2, 3]);
        assert_eq!(ranked[0], 3);
        assert_eq!(ranked[1], 1, "highest observed mean ranks next");
        assert!(b.ucb(1) > b.ucb(0));
        // Same updates → same ranking, bit for bit.
        let mut c = Bandit::new(4);
        c.update(0, 0.1);
        c.update(1, 0.9);
        c.update(2, 0.5);
        assert_eq!(b, c);
    }

    #[test]
    fn rewards_are_normalized_per_rung() {
        let r = rung_rewards(&[(0, 2.0, 1.0, 10.0), (1, 3.0, 1.0, 10.0), (2, 1.0, 1.0, 10.0)]);
        assert_eq!(r[1], (1, 1.0), "biggest improvement per second gets 1");
        assert_eq!(r[2], (2, 0.0), "no improvement gets 0");
        assert!(r[0].1 > 0.0 && r[0].1 < 1.0);
        // Degenerate rung: everyone equal → 0.5 each.
        let d = rung_rewards(&[(0, 1.0, 0.0, 5.0), (1, 1.0, 0.0, 5.0)]);
        assert!(d.iter().all(|&(_, v)| v == 0.5));
    }

    #[test]
    fn decide_keeps_the_incumbent() {
        // Arm 2 has the best score but the worst reward history; the
        // incumbent rule must keep it in the survivor set anyway.
        let mut b = Bandit::new(4);
        for _ in 0..3 {
            b.update(0, 0.9);
            b.update(1, 0.8);
            b.update(2, 0.0);
            b.update(3, 0.7);
        }
        let live = [0, 1, 2, 3];
        let last = [0.4, 0.3, 0.9, 0.2];
        let (survivors, eliminated) = decide(&mut b, &live, &[], &last, 2);
        assert_eq!(survivors.len(), 2);
        assert!(survivors.contains(&2), "incumbent dropped: {:?}", survivors);
        assert_eq!(survivors.len() + eliminated.len(), live.len());
    }

    #[test]
    fn race_is_deterministic_and_crowns_a_winner() {
        let reg = CacheRegistry::new();
        let entry = reg.entry(CacheKey::parse("convolution@A4000").unwrap());
        let specs: Vec<OptimizerSpec> = ["sa", "random", "greedy_ils"]
            .iter()
            .map(|n| OptimizerSpec::parse(n).unwrap())
            .collect();
        let cfg = RaceConfig { eta: 2, rungs: 2, seed: 11, ..RaceConfig::default() };
        let a = run_race(&entry, &specs, &cfg);
        let b = run_race(&entry, &specs, &cfg);
        assert_eq!(
            race_json(&a).to_string(),
            race_json(&b).to_string(),
            "race reports must be byte-identical run to run"
        );
        let w = a.winner.expect("uninterrupted race crowns a winner");
        assert!(a.arms[w].score.is_some() && a.arms[w].curve.is_some());
        assert!(!a.interrupted);
        // Every eliminated arm produced exactly one executor-observed
        // cancellation (the pre-fired doomed job).
        let eliminated = a.arms.iter().filter(|x| x.eliminated_at.is_some()).count();
        assert_eq!(a.cancellations as usize, eliminated);
        assert_eq!(a.jobs.cancelled, eliminated);
        assert_eq!(a.jobs.failed, 0);
    }

    #[test]
    fn lone_arm_skips_straight_to_the_final_rung() {
        let reg = CacheRegistry::new();
        let entry = reg.entry(CacheKey::parse("convolution@A4000").unwrap());
        let specs = vec![OptimizerSpec::parse("random").unwrap()];
        let cfg = RaceConfig { eta: 2, rungs: 4, seed: 3, ..RaceConfig::default() };
        let out = run_race(&entry, &specs, &cfg);
        assert_eq!(out.winner, Some(0));
        assert_eq!(out.arms[0].scores.len(), 1, "intermediate rungs skipped");
        assert_eq!(out.jobs.completed, 1);
        assert!(out.decisions.is_empty());
    }
}
