//! The L3 coordinator: job-graph scheduling of tuning runs over shared,
//! memoized search spaces (the paper's three-level view of auto-tuning at
//! scale — L1 kernel measurement, L2 per-space optimization, L3
//! cross-experiment orchestration).
//!
//! The paper's evaluation is a large cross product — optimizers ×
//! applications × GPUs × seeds — and every harness entry point is some
//! slice of it. The coordinator decomposes that product into its three
//! orthogonal concerns:
//!
//! - [`registry`]: a process-wide [`registry::CacheRegistry`] that lazily
//!   builds and memoizes each (application, GPU) exhaustive cache and its
//!   methodology setup exactly once, sharing `Arc`s across the generation
//!   stage, Tables 2–3, Fig. 7 and Figs. 8–9.
//! - [`job`]: a [`job::TuningJob`] is one seeded run over any
//!   `BackendSource` (a registry cache, or a measured-variant source on
//!   the real-tune path); [`job::grid_jobs`] expands a (spaces ×
//!   optimizers × seeds) grid into a flat batch with per-job seeds derived
//!   by [`job::job_seed`] from the job's grid coordinates — never from
//!   execution order. [`job::source_jobs`] is the same expansion over
//!   arbitrary backend sources.
//! - [`scheduler`]: a [`scheduler::Scheduler`] worker pool that drains a
//!   batch via an atomic cursor, parallelizing across every axis at once
//!   while keeping results byte-identical for any thread count.
//! - [`report`]: reassembles flat results into per-(optimizer, space)
//!   groups, aggregates them with the methodology's score, and renders the
//!   `coordinate` subcommand's tables.
//!
//! `methodology::run_many` is a thin single-space wrapper over the
//! scheduler, and `harness::experiments` expresses each figure/table as a
//! job batch against the shared registry, so new execution backends
//! (sharding, async, distributed) only need to reimplement this module's
//! seam.

pub mod job;
pub mod registry;
pub mod report;
pub mod scheduler;

pub use job::{grid_jobs, job_seed, source_jobs, TuningJob};
pub use registry::{CacheKey, CacheRegistry, SpaceEntry};
pub use report::{collate, grid_aggregates, score_table, scores_json};
pub use scheduler::Scheduler;
