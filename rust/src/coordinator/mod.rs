//! The L3 coordinator: streaming execution of tuning-job graphs over
//! shared, memoized search spaces (the paper's three-level view of
//! auto-tuning at scale — L1 kernel measurement, L2 per-space
//! optimization, L3 cross-experiment orchestration).
//!
//! The paper's evaluation is a large cross product — optimizers ×
//! applications × GPUs × seeds — and every harness entry point is some
//! slice of it. The coordinator decomposes that product into its
//! orthogonal concerns:
//!
//! - [`registry`]: a process-wide [`registry::CacheRegistry`] that lazily
//!   builds and memoizes each (application, GPU) exhaustive cache and its
//!   methodology setup exactly once, sharing `Arc`s across the generation
//!   stage, Tables 2–3, Fig. 7 and Figs. 8–9.
//! - [`job`]: a [`job::TuningJob`] is one seeded run over any
//!   `BackendSource` (a registry cache, or a measured-variant source on
//!   the real-tune path), with its seed derived by [`job::job_seed`] from
//!   the job's grid coordinates — never from execution order.
//!   [`job::grid_source`] / [`job::source_jobs_source`] generate (spaces ×
//!   optimizers × seeds) grids **lazily** from the flat index;
//!   [`job::grid_jobs`] / [`job::source_jobs`] are their collected, eager
//!   views.
//! - [`executor`]: the execution engine. An [`executor::Executor`] pulls
//!   jobs from a backpressured [`executor::JobSource`] (at most
//!   `queue_cap` jobs pulled-but-unfinished), schedules them by
//!   [`executor::Priority`] (execution order only — results are
//!   slot-indexed), cancels cooperatively through a
//!   [`CancelToken`](crate::util::cancel::CancelToken) (completed jobs
//!   stay bit-identical to their drain-all counterparts; cancelled jobs
//!   are discarded, never truncated-and-kept), isolates per-job panics
//!   (`catch_unwind` → [`executor::JobOutcome::Failed`]), and streams
//!   [`executor::Progress`] events to an optional consumer (the CLI live
//!   line, sweep counters).
//! - [`race`]: portfolio racing over the executor seam — many optimizers
//!   on one space as Hyperband-style budget rungs, a UCB1 bandit
//!   reallocating evaluation budget by observed improvement-per-cost,
//!   escalating winners' priorities and cancelling losers through
//!   pre-fired tokens (see the module's determinism contract).
//! - [`scheduler`]: the drain-all compatibility wrapper
//!   ([`scheduler::Scheduler::run`] = run every job, return plain
//!   curves) kept over the executor during the execution-API transition.
//! - [`report`]: reassembles slot-ordered results into per-(optimizer,
//!   space) groups ([`report::collate_groups`] over batch handles, with
//!   validated group ids), aggregates them with the methodology's score,
//!   and renders the `coordinate` subcommand's tables and JSON (including
//!   the `"jobs"` completion block for partial runs).
//! - [`shard`]: multi-process execution. `--shard K/N` partitions a grid
//!   by flat index (round-robin, seeds are grid-derived so any partition
//!   is valid), each shard writes a partial report of raw curves, and
//!   [`shard::merge_reports`] (the `merge` subcommand) validates the
//!   shard set and collates the partials into exactly the
//!   single-process report, byte for byte.
//!
//! ## Determinism contract
//!
//! A job's result is a pure function of its `(source, setup, factory,
//! seed)`; results land in slots indexed by stream position. Therefore,
//! for a fixed job stream, completed results are byte-identical for any
//! worker count, queue bound, priority assignment, or progress-consumer
//! timing; under cancellation the *set* of completed slots may vary but
//! never a completed slot's curve. `methodology::run_many`,
//! `harness::experiments`, `hypertune::MetaTuning` (the nested fan-out
//! shares one bounded executor rather than spawning ad-hoc scopes) and
//! `llamea::evolution::fitness_batch` all submit through this seam, so
//! new execution backends (sharding, distributed workers) only need to
//! reimplement this module.

pub mod executor;
pub mod job;
pub mod race;
pub mod registry;
pub mod report;
pub mod scheduler;
pub mod shard;

pub use executor::{
    BatchResult, BatchRunner, Executor, FnSource, IterSource, JobHandle, JobOutcome, JobSource,
    JobsSummary, Priority, Progress, ProgressSink, SourcedJob,
};
pub use job::{
    collect_jobs, grid_jobs, grid_source, job_seed, source_jobs, source_jobs_source, OwnedJob,
    TuningJob,
};
pub use race::{
    decide, race_json, race_report, race_table, run_race, run_race_observed, rung_rewards,
    ArmResult, ArmStats, Bandit, Decision, RaceConfig, RaceOutcome, RACE_TITLE,
};
pub use registry::{CacheEvent, CacheKey, CacheOutcome, CacheRegistry, SpaceEntry};
pub use report::{
    collate, collate_groups, coordinate_report, coordinate_results, grid_aggregates, score_table,
    scores_json, COORDINATE_TITLE,
};
pub use scheduler::Scheduler;
pub use shard::{merge_reports, partial_coordinate_json, ShardJob, ShardSpec};
