//! Reassembly of flat scheduler output into per-(optimizer, space) curve
//! groups, aggregate scores, and rendered tables.

use super::executor::{BatchResult, JobsSummary};
use super::job::TuningJob;
use crate::methodology::{aggregate, Aggregate};
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Regroup a flat batch result by each job's `group` index. Job order is
/// preserved within a group, so a group's curves are in run order — exactly
/// what [`aggregate`] expects per space.
pub fn collate(n_groups: usize, jobs: &[TuningJob], curves: Vec<Vec<f64>>) -> Vec<Vec<Vec<f64>>> {
    let groups: Vec<usize> = jobs.iter().map(|j| j.group).collect();
    collate_groups(n_groups, &groups, curves)
}

/// [`collate`] over bare group ids — the streaming-executor view, where
/// per-slot groups come from the batch handles
/// ([`super::executor::BatchResult::groups`]) instead of a materialized
/// job list. Group ids are validated up front: a malformed id fails with
/// a message naming the offending job and group, not an opaque
/// out-of-bounds index.
pub fn collate_groups(
    n_groups: usize,
    groups: &[usize],
    curves: Vec<Vec<f64>>,
) -> Vec<Vec<Vec<f64>>> {
    assert_eq!(groups.len(), curves.len(), "one curve per job");
    for (ji, &g) in groups.iter().enumerate() {
        assert!(
            g < n_groups,
            "job {} has group {}, but the batch declares only {} group(s)",
            ji,
            g,
            n_groups
        );
    }
    let mut out = vec![Vec::new(); n_groups];
    for (&g, curve) in groups.iter().zip(curves) {
        out[g].push(curve);
    }
    out
}

/// Aggregate a factory-major collated grid (as produced by
/// [`super::job::grid_jobs`] + [`collate`]) into one [`Aggregate`] per
/// optimizer label, over its `n_spaces` spaces.
pub fn grid_aggregates(
    labels: &[String],
    n_spaces: usize,
    grouped: Vec<Vec<Vec<f64>>>,
) -> Vec<(String, Aggregate)> {
    assert_eq!(grouped.len(), labels.len() * n_spaces, "grid shape mismatch");
    let mut it = grouped.into_iter();
    labels
        .iter()
        .map(|label| {
            let per_space: Vec<Vec<Vec<f64>>> = it.by_ref().take(n_spaces).collect();
            (label.clone(), aggregate(&per_space))
        })
        .collect()
}

/// Render per-optimizer aggregate scores as a table (the `coordinate`
/// subcommand's report).
pub fn score_table(title: &str, results: &[(String, Aggregate)]) -> Table {
    let mut t = Table::new(title, &["Optimizer", "Score P", "± std over spaces"]);
    for (label, agg) in results {
        t.row(vec![label.clone(), f(agg.score, 3), f(agg.score_std, 3)]);
    }
    t
}

/// The score table as JSON (the `coordinate --out` payload): per-optimizer
/// aggregate score, std over spaces, per-space scores keyed by the space
/// ids, and the batch's `"jobs"` completion block (`{completed,
/// cancelled, failed}` — so partial runs diff meaningfully downstream).
/// Every field is a pure function of the grid inputs and outcomes, so
/// files are byte-identical for any executor width; written through
/// [`crate::util::json::write_file`], shared with `sweep --out`.
pub fn scores_json(
    title: &str,
    space_ids: &[String],
    results: &[(String, Aggregate)],
    jobs: &JobsSummary,
) -> Json {
    let mut j = Json::obj();
    j.set("title", title);
    j.set("spaces", Json::Arr(space_ids.iter().map(|s| Json::from(s.as_str())).collect()));
    j.set("jobs", jobs.to_json());
    let mut rows: Vec<Json> = Vec::with_capacity(results.len());
    for (label, agg) in results {
        let mut row = Json::obj();
        row.set("optimizer", label.as_str());
        row.set("score", agg.score);
        row.set("score_std", agg.score_std);
        row.set("per_space", agg.per_space_scores.clone());
        rows.push(row);
    }
    j.set("scores", Json::Arr(rows));
    j
}

/// Title of the `coordinate` score report — one constant shared by the
/// CLI and the serve daemon, because the served report must be
/// byte-identical to the direct run's.
pub const COORDINATE_TITLE: &str = "Coordinator: aggregate score P per optimizer";

/// Per-optimizer aggregates from a (possibly partial) factory-major
/// batch: the scoreable subset — an optimizer makes the list iff every
/// one of its spaces has at least one completed run (aggregation over an
/// empty group is undefined). For a fully-completed batch this is every
/// optimizer, with aggregates identical to the historical
/// `expect_curves` + [`collate_groups`] + [`grid_aggregates`] path.
pub fn coordinate_results(
    labels: &[String],
    n_spaces: usize,
    batch: &BatchResult,
) -> Vec<(String, Aggregate)> {
    let n_groups = labels.len() * n_spaces;
    let (groups, curves) = batch.completed();
    let grouped = collate_groups(n_groups, &groups, curves);
    let mut results = Vec::with_capacity(labels.len());
    for (li, label) in labels.iter().enumerate() {
        let per_space = &grouped[li * n_spaces..(li + 1) * n_spaces];
        if per_space.iter().all(|runs| !runs.is_empty()) {
            results.push((label.clone(), aggregate(per_space)));
        }
    }
    results
}

/// The one report-assembly path behind `coordinate --out` and the serve
/// daemon's served coordinate sessions: collate a factory-major batch,
/// aggregate per optimizer, render [`scores_json`]. A batch whose every
/// job completed produces **exactly** the historical report bytes. A
/// cancelled or partially-drained batch degrades to the completed-prefix
/// view instead of panicking: `"interrupted": true` is appended, the
/// `"jobs"` block keeps honest counters, and a score row appears only
/// for optimizers with at least one completed run on *every* space
/// (aggregation over an empty space group is undefined). Completed
/// curves are bit-identical to their drain-all counterparts either way,
/// so a partial report is a strict prefix truth, never an approximation.
pub fn coordinate_report(
    title: &str,
    space_ids: &[String],
    labels: &[String],
    batch: &BatchResult,
) -> Json {
    let summary = batch.summary();
    let complete = batch.fully_drained() && summary.all_completed();
    let results = coordinate_results(labels, space_ids.len(), batch);
    let mut j = scores_json(title, space_ids, &results, &summary);
    if !complete {
        j.set("interrupted", true);
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::{CacheKey, CacheRegistry};
    use crate::coordinator::{grid_jobs, Scheduler};
    use crate::methodology::{NamedFactory, OptimizerFactory};

    #[test]
    fn grid_roundtrip_collates_in_order() {
        let reg = CacheRegistry::new();
        let entries = vec![reg.entry(CacheKey::parse("convolution@A4000").unwrap())];
        let named: Vec<(String, NamedFactory)> = ["random", "sa"]
            .iter()
            .map(|n| (n.to_string(), NamedFactory(n.to_string())))
            .collect();
        let factories: Vec<(String, &dyn OptimizerFactory)> = named
            .iter()
            .map(|(l, fac)| (l.clone(), fac as &dyn OptimizerFactory))
            .collect();
        let runs = 3;
        let jobs = grid_jobs(&entries, &factories, runs, 9);
        assert_eq!(jobs.len(), 2 * runs);
        let curves = Scheduler::new(2).run(&jobs);
        let grouped = collate(factories.len() * entries.len(), &jobs, curves);
        assert_eq!(grouped.len(), 2);
        assert!(grouped.iter().all(|g| g.len() == runs));
        let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
        let results = grid_aggregates(&labels, entries.len(), grouped);
        assert_eq!(results[0].0, "random");
        assert_eq!(results[1].0, "sa");
        assert!(results.iter().all(|(_, a)| a.score.is_finite()));
        let table = score_table("test", &results);
        assert!(table.to_text().contains("random"));
        // The JSON view carries the same labels and scores, plus the
        // batch completion block.
        let ids = vec!["convolution@A4000".to_string()];
        let jobs_block = crate::coordinator::executor::JobsSummary {
            completed: 2 * runs,
            cancelled: 0,
            failed: 0,
            cost_us: 6_000_000,
        };
        let json = scores_json("test", &ids, &results, &jobs_block).to_string();
        assert!(json.contains("\"optimizer\":\"random\""), "{}", json);
        assert!(json.contains("\"spaces\":[\"convolution@A4000\"]"), "{}", json);
        assert!(
            json.contains(
                "\"jobs\":{\"completed\":6,\"cancelled\":0,\"failed\":0,\"cost_us\":6000000}"
            ),
            "{}",
            json
        );
    }

    #[test]
    #[should_panic(expected = "job 1 has group 7, but the batch declares only 2 group(s)")]
    fn collate_names_the_offending_job_and_group() {
        collate_groups(2, &[0, 7], vec![vec![0.0], vec![0.0]]);
    }
}
