//! The shared cache registry: one build of every (application, GPU) space,
//! memoized behind `Arc`s, shared by every experiment stage.
//!
//! Building a `Cache` exhaustively evaluates the performance model over the
//! whole constrained space — by far the most expensive setup step. The seed
//! code rebuilt all 24 caches inside *each* harness entry point; the
//! registry builds each exactly once per process (lazily, on first use) and
//! hands out `Arc<SpaceEntry>` clones, so the generation stage, Table 2/3,
//! Fig. 7 and Figs. 8–9 all share one copy.
//!
//! Concurrency: the per-key `OnceLock` guarantees at-most-once construction
//! even when many scheduler workers request the same key simultaneously;
//! distinct keys build in parallel (the map mutex is only held to look up
//! the key's cell, never during a build). `builds()` exposes the
//! construction counter so tests can assert the exactly-once property.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::kernels::gpu::{GpuSpec, ALL_GPUS};
use crate::methodology::SpaceSetup;
use crate::searchspace::{Application, SearchSpace};
use crate::tuning::Cache;

/// Identity of one pre-explored search space: (application, GPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub app: Application,
    /// Canonical GPU name (the `GpuSpec::name` of a testbed device).
    pub gpu: &'static str,
}

impl CacheKey {
    pub fn new(app: Application, gpu: &'static GpuSpec) -> CacheKey {
        CacheKey { app, gpu: gpu.name }
    }

    /// Parse an `application@gpu` spec (the CLI's `--space` syntax).
    pub fn parse(spec: &str) -> Option<CacheKey> {
        let (app_s, gpu_s) = spec.split_once('@')?;
        let app = Application::from_name(app_s)?;
        let gpu = GpuSpec::by_name(gpu_s)?;
        Some(CacheKey::new(app, gpu))
    }

    /// Human-readable identifier, e.g. `gemm@A100` (matches `Cache::id`).
    pub fn id(&self) -> String {
        format!("{}@{}", self.app.name(), self.gpu)
    }
}

/// A memoized space: the exhaustive cache plus its methodology setup
/// (baseline, budget, sample times), computed once and shared.
pub struct SpaceEntry {
    pub key: CacheKey,
    pub cache: Cache,
    pub setup: SpaceSetup,
}

type Cell<T> = Arc<OnceLock<T>>;

/// Lazily-built, memoized registry of caches and search spaces.
pub struct CacheRegistry {
    /// Per-application enumerated spaces (shared across that app's GPUs).
    spaces: Mutex<HashMap<Application, Cell<Arc<SearchSpace>>>>,
    /// Per-(application, GPU) cache + setup.
    entries: Mutex<HashMap<CacheKey, Cell<Arc<SpaceEntry>>>>,
    cache_builds: AtomicUsize,
    space_builds: AtomicUsize,
}

impl CacheRegistry {
    pub fn new() -> CacheRegistry {
        CacheRegistry {
            spaces: Mutex::new(HashMap::new()),
            entries: Mutex::new(HashMap::new()),
            cache_builds: AtomicUsize::new(0),
            space_builds: AtomicUsize::new(0),
        }
    }

    /// The process-wide registry every harness entry point shares.
    pub fn global() -> &'static CacheRegistry {
        static GLOBAL: OnceLock<CacheRegistry> = OnceLock::new();
        GLOBAL.get_or_init(CacheRegistry::new)
    }

    /// The application's enumerated search space, built at most once.
    pub fn space(&self, app: Application) -> Arc<SearchSpace> {
        let cell = self.spaces.lock().unwrap().entry(app).or_default().clone();
        cell.get_or_init(|| {
            self.space_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(app.build_space())
        })
        .clone()
    }

    /// The key's cache + setup, built at most once; concurrent callers of
    /// the same key block on one build, distinct keys build in parallel.
    pub fn entry(&self, key: CacheKey) -> Arc<SpaceEntry> {
        let cell = self.entries.lock().unwrap().entry(key).or_default().clone();
        cell.get_or_init(|| {
            let gpu = GpuSpec::by_name(key.gpu).expect("unknown GPU in cache key");
            let cache = Cache::build_with_space(key.app, gpu, self.space(key.app));
            let setup = SpaceSetup::new(&cache);
            self.cache_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(SpaceEntry { key, cache, setup })
        })
        .clone()
    }

    /// Register an externally-built cache — e.g. a *measured* space
    /// assembled by `runtime::measure_kernel` — under `key`, making it
    /// schedulable through the same job graph as the simulated spaces.
    /// Like every registry cell, the first registration wins; the entry
    /// (new or pre-existing) is returned.
    pub fn insert(&self, key: CacheKey, cache: Cache) -> Arc<SpaceEntry> {
        let cell = self.entries.lock().unwrap().entry(key).or_default().clone();
        cell.get_or_init(move || {
            let setup = SpaceSetup::new(&cache);
            self.cache_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(SpaceEntry { key, cache, setup })
        })
        .clone()
    }

    /// Number of caches constructed so far (tests assert exactly-once).
    pub fn builds(&self) -> usize {
        self.cache_builds.load(Ordering::Relaxed)
    }

    /// Number of search-space enumerations so far.
    pub fn space_builds(&self) -> usize {
        self.space_builds.load(Ordering::Relaxed)
    }

    /// The full 4×6 evaluation grid in stable application-major order
    /// (matching `tuning::build_all_caches`).
    pub fn all_entries(&self) -> Vec<Arc<SpaceEntry>> {
        let names: Vec<&str> = ALL_GPUS.iter().map(|g| g.name).collect();
        self.entries_for(&names)
    }

    /// Entries for a GPU subset (e.g. the train or test split), all
    /// applications, application-major order.
    pub fn entries_for(&self, gpu_names: &[&str]) -> Vec<Arc<SpaceEntry>> {
        let mut out = Vec::with_capacity(Application::ALL.len() * gpu_names.len());
        for app in Application::ALL {
            for name in gpu_names {
                let gpu = GpuSpec::by_name(name).expect("unknown GPU");
                out.push(self.entry(CacheKey::new(app, gpu)));
            }
        }
        out
    }
}

impl Default for CacheRegistry {
    fn default() -> Self {
        CacheRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_is_memoized_and_shares_the_space() {
        let reg = CacheRegistry::new();
        let key = CacheKey::parse("convolution@A4000").unwrap();
        let a = reg.entry(key);
        let b = reg.entry(key);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.builds(), 1);
        // A second GPU of the same application reuses the enumerated space.
        let c = reg.entry(CacheKey::parse("convolution@A100").unwrap());
        assert_eq!(reg.builds(), 2);
        assert_eq!(reg.space_builds(), 1);
        assert!(Arc::ptr_eq(&a.cache.space, &c.cache.space));
    }

    #[test]
    fn concurrent_same_key_builds_once() {
        let reg = CacheRegistry::new();
        let key = CacheKey::parse("convolution@A4000").unwrap();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    let e = reg.entry(key);
                    assert_eq!(e.key, key);
                    assert!(e.cache.len() > 0);
                });
            }
        });
        assert_eq!(reg.builds(), 1, "concurrent access must build once");
    }

    #[test]
    fn external_caches_can_join_the_registry() {
        use crate::kernels::gpu::CPU_HOST;
        let reg = CacheRegistry::new();
        let cache = Cache::build(
            crate::searchspace::Application::Convolution,
            GpuSpec::by_name("A4000").unwrap(),
        );
        let key = CacheKey::new(cache.app, &CPU_HOST);
        let a = reg.insert(key, cache);
        assert_eq!(reg.builds(), 1);
        assert!(a.setup.budget_s > 0.0);
        // First insert wins; a second insert returns the existing entry.
        let cache2 = Cache::build(
            crate::searchspace::Application::Convolution,
            GpuSpec::by_name("A4000").unwrap(),
        );
        let b = reg.insert(key, cache2);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.builds(), 1);
        // And the entry is visible through the normal lookup.
        assert!(Arc::ptr_eq(&a, &reg.entry(key)));
    }

    #[test]
    fn parse_rejects_unknown_specs() {
        assert!(CacheKey::parse("gemm@A100").is_some());
        assert!(CacheKey::parse("gemm").is_none());
        assert!(CacheKey::parse("gemm@H100").is_none());
        assert!(CacheKey::parse("nope@A100").is_none());
        assert_eq!(CacheKey::parse("gemm@A100").unwrap().id(), "gemm@A100");
    }
}
