//! The shared cache registry: one build of every (application, GPU) space,
//! memoized behind `Arc`s, shared by every experiment stage.
//!
//! Building a `Cache` exhaustively evaluates the performance model over the
//! whole constrained space — by far the most expensive setup step. The seed
//! code rebuilt all 24 caches inside *each* harness entry point; the
//! registry builds each exactly once per process (lazily, on first use) and
//! hands out `Arc<SpaceEntry>` clones, so the generation stage, Table 2/3,
//! Fig. 7 and Figs. 8–9 all share one copy.
//!
//! # Warm path (`--cache-dir`)
//!
//! With a cache directory configured ([`CacheRegistry::set_cache_dir`]),
//! the one-time-per-process cost becomes one-time-per-*machine*: on a
//! registry miss the store (`crate::persist`) is consulted first. A valid
//! file — format version, checksums, build fingerprint and recomputed
//! summary stats all passing — is loaded (mmap-backed, zero-copy; falling
//! back to an owned read where mapping is unavailable) and the exhaustive
//! model sweep is skipped entirely. Any rejection, for any reason, falls
//! back to a cold build whose result is then atomically written back
//! (temp file + rename), overwriting the stale file. Spaces persist
//! per-application (`space_<app>.llkt`, config arena + all three CSR
//! neighbor tables — eagerly built at save time so warm processes also
//! skip graph construction); caches per key (`cache_<app>@<gpu>.llkt`).
//! Save failures only warn: the store is an optimization, never a
//! correctness dependency, and a loaded cache is byte-identical to a
//! built one (pinned by `rust/tests/integration_persist.rs`), so every
//! downstream report is unaffected by warm vs cold.
//!
//! Measured caches entering through [`CacheRegistry::insert`] are *not*
//! persisted: their bytes come from real hardware, not from anything a
//! build fingerprint could derive, so the store could never validate them.
//!
//! Concurrency: the per-key `OnceLock` guarantees at-most-once construction
//! (and at-most-once *load*) even when many scheduler workers request the
//! same key simultaneously; distinct keys build in parallel (the map mutex
//! is only held to look up the key's cell, never during a build or load).
//! `builds()`/`loads()` expose the counters so tests can assert the
//! exactly-once property, and [`CacheRegistry::caches_json`] reports
//! per-key outcomes for the `"caches"` block of `coordinate`/`sweep`
//! reports.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::kernels::gpu::{GpuSpec, ALL_GPUS};
use crate::methodology::SpaceSetup;
use crate::obs;
use crate::persist::{self, LoadError, LoadMode};
use crate::searchspace::{Application, SearchSpace};
use crate::tuning::Cache;
use crate::util::json::Json;

/// Identity of one pre-explored search space: (application, GPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub app: Application,
    /// Canonical GPU name (the `GpuSpec::name` of a testbed device).
    pub gpu: &'static str,
}

impl CacheKey {
    pub fn new(app: Application, gpu: &'static GpuSpec) -> CacheKey {
        CacheKey { app, gpu: gpu.name }
    }

    /// Parse an `application@gpu` spec (the CLI's `--space` syntax).
    pub fn parse(spec: &str) -> Option<CacheKey> {
        let (app_s, gpu_s) = spec.split_once('@')?;
        let app = Application::from_name(app_s)?;
        let gpu = GpuSpec::by_name(gpu_s)?;
        Some(CacheKey::new(app, gpu))
    }

    /// Human-readable identifier, e.g. `gemm@A100` (matches `Cache::id`).
    pub fn id(&self) -> String {
        format!("{}@{}", self.app.name(), self.gpu)
    }
}

/// A memoized space: the exhaustive cache plus its methodology setup
/// (baseline, budget, sample times), computed once and shared.
pub struct SpaceEntry {
    pub key: CacheKey,
    pub cache: Cache,
    pub setup: SpaceSetup,
}

/// How a registry object came to exist this process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Cold: enumerated/model-evaluated in this process.
    Built,
    /// Warm: loaded from the persistent store.
    Loaded,
}

impl CacheOutcome {
    pub fn label(&self) -> &'static str {
        match self {
            CacheOutcome::Built => "built",
            CacheOutcome::Loaded => "loaded",
        }
    }
}

/// One build/load event, for the `"caches"` report block.
#[derive(Debug, Clone)]
pub struct CacheEvent {
    /// `gemm@A100` for caches, `space:gemm` for space enumerations.
    pub id: String,
    pub outcome: CacheOutcome,
    /// Wall seconds spent building or loading.
    pub seconds: f64,
}

type Cell<T> = Arc<OnceLock<T>>;

/// Lazily-built, memoized registry of caches and search spaces.
pub struct CacheRegistry {
    /// Per-application enumerated spaces (shared across that app's GPUs).
    spaces: Mutex<HashMap<Application, Cell<Arc<SearchSpace>>>>,
    /// Per-(application, GPU) cache + setup.
    entries: Mutex<HashMap<CacheKey, Cell<Arc<SpaceEntry>>>>,
    /// Persistent-store directory; `None` disables the warm path.
    cache_dir: Mutex<Option<PathBuf>>,
    cache_builds: AtomicUsize,
    cache_loads: AtomicUsize,
    space_builds: AtomicUsize,
    space_loads: AtomicUsize,
    events: Mutex<Vec<CacheEvent>>,
}

impl CacheRegistry {
    pub fn new() -> CacheRegistry {
        CacheRegistry {
            spaces: Mutex::new(HashMap::new()),
            entries: Mutex::new(HashMap::new()),
            cache_dir: Mutex::new(None),
            cache_builds: AtomicUsize::new(0),
            cache_loads: AtomicUsize::new(0),
            space_builds: AtomicUsize::new(0),
            space_loads: AtomicUsize::new(0),
            events: Mutex::new(Vec::new()),
        }
    }

    /// Registry with the persistent warm path enabled.
    pub fn with_cache_dir(dir: PathBuf) -> CacheRegistry {
        let reg = CacheRegistry::new();
        reg.set_cache_dir(Some(dir));
        reg
    }

    /// The process-wide registry every harness entry point shares.
    pub fn global() -> &'static CacheRegistry {
        static GLOBAL: OnceLock<CacheRegistry> = OnceLock::new();
        GLOBAL.get_or_init(CacheRegistry::new)
    }

    /// Enable (or disable, with `None`) the persistent warm path. Only
    /// affects keys not yet resolved; already-memoized cells keep their
    /// objects.
    pub fn set_cache_dir(&self, dir: Option<PathBuf>) {
        *self.cache_dir.lock().unwrap() = dir;
    }

    fn record(&self, id: String, outcome: CacheOutcome, seconds: f64) {
        self.events.lock().unwrap().push(CacheEvent { id, outcome, seconds });
    }

    /// The application's enumerated search space, resolved at most once:
    /// store load when a valid file exists, else build + save-back.
    pub fn space(&self, app: Application) -> Arc<SearchSpace> {
        let cell = self.spaces.lock().unwrap().entry(app).or_default().clone();
        cell.get_or_init(|| {
            let mut sp = obs::span("registry.space");
            if obs::enabled() {
                sp.note("id", obs::sym(app.name()));
            }
            let dir = self.cache_dir.lock().unwrap().clone();
            let t0 = Instant::now();
            if let Some(dir) = &dir {
                let path = persist::space_path(dir, app);
                match persist::load_space(&path, app, LoadMode::Mmap) {
                    Ok(space) => {
                        self.space_loads.fetch_add(1, Ordering::Relaxed);
                        self.record(
                            format!("space:{}", app.name()),
                            CacheOutcome::Loaded,
                            t0.elapsed().as_secs_f64(),
                        );
                        sp.note("outcome", "loaded");
                        sp.note("fingerprint", "valid");
                        return Arc::new(space);
                    }
                    Err(LoadError::Missing) => sp.note("fingerprint", "missing"),
                    Err(e) => {
                        sp.note("fingerprint", "rejected");
                        eprintln!(
                            "cache store: rejecting {} ({e}); rebuilding",
                            path.display()
                        )
                    }
                }
            }
            self.space_builds.fetch_add(1, Ordering::Relaxed);
            let space = Arc::new(app.build_space());
            if let Some(dir) = &dir {
                let path = persist::space_path(dir, app);
                if let Err(e) = persist::save_space(&path, &space) {
                    eprintln!("cache store: cannot write {} ({e})", path.display());
                }
            }
            self.record(
                format!("space:{}", app.name()),
                CacheOutcome::Built,
                t0.elapsed().as_secs_f64(),
            );
            sp.note("outcome", "built");
            space
        })
        .clone()
    }

    /// The key's cache + setup, resolved at most once (store load when a
    /// valid file exists, else build + save-back); concurrent callers of
    /// the same key block on one resolution, distinct keys in parallel.
    pub fn entry(&self, key: CacheKey) -> Arc<SpaceEntry> {
        let cell = self.entries.lock().unwrap().entry(key).or_default().clone();
        cell.get_or_init(|| {
            let gpu = GpuSpec::by_name(key.gpu).expect("unknown GPU in cache key");
            let space = self.space(key.app);
            let mut sp = obs::span("registry.cache");
            if obs::enabled() {
                sp.note("id", obs::sym(&key.id()));
            }
            let dir = self.cache_dir.lock().unwrap().clone();
            let t0 = Instant::now();
            if let Some(dir) = &dir {
                let path = persist::cache_path(dir, key.app, key.gpu);
                match persist::load_cache(&path, key.app, gpu, Arc::clone(&space), LoadMode::Mmap)
                {
                    Ok(cache) => {
                        self.cache_loads.fetch_add(1, Ordering::Relaxed);
                        let setup = SpaceSetup::new(&cache);
                        self.record(key.id(), CacheOutcome::Loaded, t0.elapsed().as_secs_f64());
                        sp.note("outcome", "loaded");
                        sp.note("fingerprint", "valid");
                        return Arc::new(SpaceEntry { key, cache, setup });
                    }
                    Err(LoadError::Missing) => sp.note("fingerprint", "missing"),
                    Err(e) => {
                        sp.note("fingerprint", "rejected");
                        eprintln!(
                            "cache store: rejecting {} ({e}); rebuilding",
                            path.display()
                        )
                    }
                }
            }
            let cache = Cache::build_with_space(key.app, gpu, space);
            sp.note("outcome", "built");
            self.cache_builds.fetch_add(1, Ordering::Relaxed);
            if let Some(dir) = &dir {
                let path = persist::cache_path(dir, key.app, key.gpu);
                if let Err(e) = persist::save_cache(&path, &cache) {
                    eprintln!("cache store: cannot write {} ({e})", path.display());
                }
            }
            let setup = SpaceSetup::new(&cache);
            self.record(key.id(), CacheOutcome::Built, t0.elapsed().as_secs_f64());
            Arc::new(SpaceEntry { key, cache, setup })
        })
        .clone()
    }

    /// Register an externally-built cache — e.g. a *measured* space
    /// assembled by `runtime::measure_kernel` — under `key`, making it
    /// schedulable through the same job graph as the simulated spaces.
    /// Like every registry cell, the first registration wins; the entry
    /// (new or pre-existing) is returned. Never persisted (measured bytes
    /// have no derivable fingerprint).
    pub fn insert(&self, key: CacheKey, cache: Cache) -> Arc<SpaceEntry> {
        let cell = self.entries.lock().unwrap().entry(key).or_default().clone();
        cell.get_or_init(move || {
            let setup = SpaceSetup::new(&cache);
            self.cache_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(SpaceEntry { key, cache, setup })
        })
        .clone()
    }

    /// Number of caches constructed so far (tests assert exactly-once; a
    /// fully warm run reports 0).
    pub fn builds(&self) -> usize {
        self.cache_builds.load(Ordering::Relaxed)
    }

    /// Number of caches loaded from the persistent store so far.
    pub fn loads(&self) -> usize {
        self.cache_loads.load(Ordering::Relaxed)
    }

    /// Number of search-space enumerations so far.
    pub fn space_builds(&self) -> usize {
        self.space_builds.load(Ordering::Relaxed)
    }

    /// Number of spaces loaded from the persistent store so far.
    pub fn space_loads(&self) -> usize {
        self.space_loads.load(Ordering::Relaxed)
    }

    /// Snapshot of all build/load events so far.
    pub fn events(&self) -> Vec<CacheEvent> {
        self.events.lock().unwrap().clone()
    }

    /// The `"caches"` block of `coordinate`/`sweep` reports: counters plus
    /// per-key outcomes with wall seconds. Entries are sorted by id —
    /// resolution order is nondeterministic under parallel setup — and
    /// the whole block is run *metadata*: wall seconds (and built-vs-
    /// loaded) legitimately differ between warm and cold runs, so the
    /// byte-identity contract covers reports with this block stripped
    /// (`merge` emits none), see `rust/tests/integration_persist.rs`.
    pub fn caches_json(&self) -> Json {
        let mut events = self.events();
        events.sort_by(|a, b| a.id.cmp(&b.id));
        let mut block = Json::obj();
        block.set("builds", self.builds());
        block.set("loads", self.loads());
        block.set("space_builds", self.space_builds());
        block.set("space_loads", self.space_loads());
        let mut rows = Json::Arr(Vec::new());
        for e in events {
            let mut row = Json::obj();
            row.set("id", e.id.as_str());
            row.set("outcome", e.outcome.label());
            row.set("seconds", e.seconds);
            rows.push(row);
        }
        block.set("entries", rows);
        block
    }

    /// The full 4×6 evaluation grid in stable application-major order
    /// (matching `tuning::build_all_caches`).
    pub fn all_entries(&self) -> Vec<Arc<SpaceEntry>> {
        let names: Vec<&str> = ALL_GPUS.iter().map(|g| g.name).collect();
        self.entries_for(&names)
    }

    /// Entries for a GPU subset (e.g. the train or test split), all
    /// applications, application-major order.
    pub fn entries_for(&self, gpu_names: &[&str]) -> Vec<Arc<SpaceEntry>> {
        let mut out = Vec::with_capacity(Application::ALL.len() * gpu_names.len());
        for app in Application::ALL {
            for name in gpu_names {
                let gpu = GpuSpec::by_name(name).expect("unknown GPU");
                out.push(self.entry(CacheKey::new(app, gpu)));
            }
        }
        out
    }
}

impl Default for CacheRegistry {
    fn default() -> Self {
        CacheRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_is_memoized_and_shares_the_space() {
        let reg = CacheRegistry::new();
        let key = CacheKey::parse("convolution@A4000").unwrap();
        let a = reg.entry(key);
        let b = reg.entry(key);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.builds(), 1);
        // A second GPU of the same application reuses the enumerated space.
        let c = reg.entry(CacheKey::parse("convolution@A100").unwrap());
        assert_eq!(reg.builds(), 2);
        assert_eq!(reg.space_builds(), 1);
        assert!(Arc::ptr_eq(&a.cache.space, &c.cache.space));
    }

    #[test]
    fn concurrent_same_key_builds_once() {
        let reg = CacheRegistry::new();
        let key = CacheKey::parse("convolution@A4000").unwrap();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    let e = reg.entry(key);
                    assert_eq!(e.key, key);
                    assert!(e.cache.len() > 0);
                });
            }
        });
        assert_eq!(reg.builds(), 1, "concurrent access must build once");
    }

    #[test]
    fn external_caches_can_join_the_registry() {
        use crate::kernels::gpu::CPU_HOST;
        let reg = CacheRegistry::new();
        let cache = Cache::build(
            crate::searchspace::Application::Convolution,
            GpuSpec::by_name("A4000").unwrap(),
        );
        let key = CacheKey::new(cache.app, &CPU_HOST);
        let a = reg.insert(key, cache);
        assert_eq!(reg.builds(), 1);
        assert!(a.setup.budget_s > 0.0);
        // First insert wins; a second insert returns the existing entry.
        let cache2 = Cache::build(
            crate::searchspace::Application::Convolution,
            GpuSpec::by_name("A4000").unwrap(),
        );
        let b = reg.insert(key, cache2);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.builds(), 1);
        // And the entry is visible through the normal lookup.
        assert!(Arc::ptr_eq(&a, &reg.entry(key)));
    }

    #[test]
    fn parse_rejects_unknown_specs() {
        assert!(CacheKey::parse("gemm@A100").is_some());
        assert!(CacheKey::parse("gemm").is_none());
        assert!(CacheKey::parse("gemm@H100").is_none());
        assert!(CacheKey::parse("nope@A100").is_none());
        assert_eq!(CacheKey::parse("gemm@A100").unwrap().id(), "gemm@A100");
    }

    #[test]
    fn cold_run_records_built_events_and_caches_block() {
        let reg = CacheRegistry::new();
        reg.entry(CacheKey::parse("convolution@A4000").unwrap());
        let events = reg.events();
        assert_eq!(events.len(), 2); // space:convolution + convolution@A4000
        assert!(events.iter().all(|e| e.outcome == CacheOutcome::Built));
        let block = reg.caches_json();
        assert_eq!(block.get("builds").and_then(Json::as_usize), Some(1));
        assert_eq!(block.get("loads").and_then(Json::as_usize), Some(0));
        let rows = block.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        // Sorted by id: "convolution@A4000" < "space:convolution".
        assert_eq!(rows[0].get("id").and_then(Json::as_str), Some("convolution@A4000"));
    }
}
