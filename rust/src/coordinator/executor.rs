//! The streaming execution engine behind every tuning-job batch.
//!
//! [`Executor`] replaces the drain-everything `Scheduler::run` seam with a
//! worker pool driven by a **backpressured job stream**:
//!
//! - jobs come from a [`JobSource`] — an iterator-style seam, so grids and
//!   meta-batches stream into the pool instead of being materialized as a
//!   `Vec<TuningJob>` up front. The pool never holds more than `queue_cap`
//!   *jobs* pulled-but-unfinished; per-slot bookkeeping (a small
//!   [`JobHandle`] record and, for completed jobs, the result curve) still
//!   accumulates over the whole stream — streaming bounds the
//!   pre-execution materialization, not the result storage;
//! - each streamed job carries a [`Priority`]; free workers always take
//!   the highest-priority queued job (ties go to the lower slot). Because
//!   every job's seed is pre-derived, priorities reorder *execution*,
//!   never results;
//! - a [`CancelToken`] cancels cooperatively: running jobs observe it at
//!   their next between-evaluations budget check and wind down, queued
//!   and unpulled jobs are never started. Every job that completes is
//!   bit-identical to its drain-all counterpart — cancellation changes
//!   *which* jobs complete, never *what* a completed job returns;
//! - a panicking job is isolated with `catch_unwind` and surfaces as
//!   [`JobOutcome::Failed`] in its own slot — the rest of the batch keeps
//!   its results (the old pool lost the whole `thread::scope`);
//! - [`Progress`] events (started / finished / cancelled / failed, with
//!   completed-so-far counters) stream to an optional consumer — the CLI
//!   live line, `sweep`'s job counters. Consumers only observe; event
//!   timing cannot change results (though a consumer may cancel).
//!
//! ## Determinism contract
//!
//! A job's result depends only on its `(source, setup, factory, seed)` —
//! the [`TuningJob`](super::job::TuningJob) contract — and results land in
//! **slot-indexed** handles (slot = position in the job stream). So for a
//! fixed job stream, the completed results are byte-identical for any
//! worker count, any `queue_cap`, any priority assignment, and any
//! progress-consumer timing. Under cancellation, the *set* of completed
//! slots may vary run to run, but each completed slot's curve is exactly
//! what the drain-all run produces for that slot
//! (`rust/tests/integration_coordinator.rs` pins all four properties).

use std::collections::BinaryHeap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use super::job::{OwnedJob, TuningJob};
use crate::obs;
use crate::util::cancel::CancelToken;
use crate::util::error::panic_message;
use crate::util::json::Json;
use crate::util::parallel;

/// Scheduling weight of one job: higher runs first (e.g. successive
/// halving's higher rungs, whose scores gate the next elimination).
/// Priorities never affect results, only the order work is picked up in —
/// and only within the executor's bounded lookahead window.
pub type Priority = i64;

/// One streamed job plus its scheduling metadata.
pub struct SourcedJob<'a> {
    pub job: TuningJob<'a>,
    pub priority: Priority,
}

impl<'a> From<TuningJob<'a>> for SourcedJob<'a> {
    fn from(job: TuningJob<'a>) -> SourcedJob<'a> {
        SourcedJob { job, priority: 0 }
    }
}

/// A backpressured stream of tuning jobs.
///
/// The executor pulls jobs on demand and never runs more than `queue_cap`
/// ahead of completion, so sources can generate huge grids lazily. The
/// slot (result index) of a job is its position in the stream; sources
/// must yield a deterministic sequence for the determinism contract to
/// hold. `Send` because the pool's workers share the source behind a lock
/// and whichever worker is free pulls next.
pub trait JobSource<'a>: Send {
    /// The next job, or `None` once the stream is exhausted (the executor
    /// stops polling after the first `None`).
    fn next_job(&mut self) -> Option<SourcedJob<'a>>;

    /// Bounds on the number of jobs remaining, iterator-style. Used for
    /// progress estimation and — only when *exact* (lower == upper) — to
    /// avoid spawning workers a small batch can never feed; never
    /// trusted for allocation or termination.
    fn size_hint(&self) -> (usize, Option<usize>) {
        (0, None)
    }
}

/// An indexed lazy source: `len` jobs computed on demand from their flat
/// index. The shape behind streamed grids (`grid_source`, the hypertune
/// fan-out): position arithmetic instead of a materialized `Vec`.
pub struct FnSource<F> {
    len: usize,
    next: usize,
    f: F,
}

impl<F> FnSource<F> {
    pub fn new(len: usize, f: F) -> FnSource<F> {
        FnSource { len, next: 0, f }
    }
}

impl<'a, F: FnMut(usize) -> SourcedJob<'a> + Send> JobSource<'a> for FnSource<F> {
    fn next_job(&mut self) -> Option<SourcedJob<'a>> {
        if self.next >= self.len {
            return None;
        }
        let i = self.next;
        self.next += 1;
        Some((self.f)(i))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.len - self.next;
        (left, Some(left))
    }
}

/// Any iterator of [`SourcedJob`]s as a [`JobSource`].
pub struct IterSource<I>(pub I);

impl<'a, I: Iterator<Item = SourcedJob<'a>> + Send> JobSource<'a> for IterSource<I> {
    fn next_job(&mut self) -> Option<SourcedJob<'a>> {
        self.0.next()
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.0.size_hint()
    }
}

/// How one job ended.
#[derive(Debug, Clone, PartialEq)]
pub enum JobOutcome {
    /// Ran to completion; the curve is bit-identical to the same job in a
    /// drain-all run.
    Completed(Vec<f64>),
    /// Never started, or observed the cancel token mid-run (partial output
    /// discarded — see `TuningContext::cancellation_observed`).
    Cancelled,
    /// The job panicked; the payload message, batch preserved.
    Failed(String),
}

impl JobOutcome {
    pub fn curve(&self) -> Option<&[f64]> {
        match self {
            JobOutcome::Completed(c) => Some(c),
            _ => None,
        }
    }

    pub fn is_completed(&self) -> bool {
        matches!(self, JobOutcome::Completed(_))
    }

    /// Short outcome tag for trace spans and displays.
    pub fn label(&self) -> &'static str {
        match self {
            JobOutcome::Completed(_) => "completed",
            JobOutcome::Cancelled => "cancelled",
            JobOutcome::Failed(_) => "failed",
        }
    }
}

/// Per-job record of an executor run: the job's slot (position in the
/// stream — results are reassembled by slot, never by completion order),
/// its reassembly group and scheduling metadata, its nominal evaluation
/// cost, and how it ended.
#[derive(Debug, Clone)]
pub struct JobHandle {
    pub slot: usize,
    pub group: usize,
    pub priority: Priority,
    pub seed: u64,
    /// Nominal evaluation cost of the job in integer microseconds
    /// (`budget_s × 1e6`, rounded). Integer so per-tenant sums are
    /// associative: a sharded or multi-session total is bit-identical to
    /// the single-batch total regardless of summation order.
    pub cost_us: u64,
    pub outcome: JobOutcome,
}

/// Completion counters of a batch (the `"jobs"` block of `coordinate
/// --out` / `sweep --out` reports, and the per-session accounting unit of
/// the `serve` daemon). `cost_us` sums the nominal evaluation cost of the
/// **completed** jobs only — the work a tenant actually consumed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JobsSummary {
    pub completed: usize,
    pub cancelled: usize,
    pub failed: usize,
    pub cost_us: u64,
}

impl JobsSummary {
    pub fn total(&self) -> usize {
        self.completed + self.cancelled + self.failed
    }

    pub fn all_completed(&self) -> bool {
        self.cancelled == 0 && self.failed == 0
    }

    /// Accumulate another batch's counters (sweeps run many batches).
    pub fn absorb(&mut self, other: JobsSummary) {
        self.completed += other.completed;
        self.cancelled += other.cancelled;
        self.failed += other.failed;
        self.cost_us += other.cost_us;
    }

    /// The `{"completed":…,"cancelled":…,"failed":…,"cost_us":…}` report
    /// block.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("completed", self.completed);
        j.set("cancelled", self.cancelled);
        j.set("failed", self.failed);
        j.set("cost_us", self.cost_us);
        j
    }
}

/// Everything one executor run produced, slot-indexed.
#[derive(Debug)]
pub struct BatchResult {
    /// One handle per job pulled from the source (a cancelled run stops
    /// pulling, so unpulled jobs have no handle — check
    /// [`Self::fully_drained`] before treating the handle count as the
    /// grid size), in slot order.
    pub handles: Vec<JobHandle>,
    /// Whether the source was pulled to exhaustion. `false` means
    /// cancellation (or fail-fast) stopped the run with jobs still
    /// unpulled: the handles cover a prefix window of the stream only.
    drained: bool,
}

impl BatchResult {
    /// Assemble a result from externally produced handles — the seam for
    /// execution engines outside this module (the serve pool). The engine
    /// asserts `fully_drained` itself: a materialized batch whose every
    /// job got a handle is drained by construction, even when some
    /// outcomes are `Cancelled`.
    pub fn from_handles(handles: Vec<JobHandle>, fully_drained: bool) -> BatchResult {
        BatchResult { handles, drained: fully_drained }
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    pub fn summary(&self) -> JobsSummary {
        let mut s = JobsSummary::default();
        for h in &self.handles {
            match h.outcome {
                JobOutcome::Completed(_) => {
                    s.completed += 1;
                    s.cost_us += h.cost_us;
                }
                JobOutcome::Cancelled => s.cancelled += 1,
                JobOutcome::Failed(_) => s.failed += 1,
            }
        }
        s
    }

    /// Each handle's reassembly group, in slot order (feeds
    /// [`super::report::collate_groups`]).
    pub fn groups(&self) -> Vec<usize> {
        self.handles.iter().map(|h| h.group).collect()
    }

    /// Whether the source was pulled to exhaustion (see `drained`).
    pub fn fully_drained(&self) -> bool {
        self.drained
    }

    /// Completed jobs only, in slot order: each job's reassembly group
    /// paired with its curve. The partial-tolerant counterpart of
    /// [`Self::expect_curves`]: a cancelled batch yields the
    /// completed-prefix view (every curve still bit-identical to its
    /// drain-all counterpart) instead of panicking. Callers feed the pair
    /// straight into [`super::report::collate_groups`].
    pub fn completed(&self) -> (Vec<usize>, Vec<Vec<f64>>) {
        let mut groups = Vec::new();
        let mut curves = Vec::new();
        for h in &self.handles {
            if let JobOutcome::Completed(curve) = &h.outcome {
                groups.push(h.group);
                curves.push(curve.clone());
            }
        }
        (groups, curves)
    }

    /// Drain-all view: every job's curve in slot order. Panics with a
    /// structured message if any job failed or was cancelled, **or** if
    /// the source was not pulled to exhaustion (an early cancellation
    /// must not pass off a prefix as the whole batch) — the compatibility
    /// surface for callers whose API is curves-only (`Scheduler::run`,
    /// `run_many`); callers that tolerate partial batches consume
    /// `handles` directly. A failure is reported in preference to the
    /// cancellations it triggered under fail-fast.
    pub fn expect_curves(self) -> Vec<Vec<f64>> {
        let summary = self.summary();
        if let Some((slot, group, e)) = self.handles.iter().find_map(|h| match &h.outcome {
            JobOutcome::Failed(e) => Some((h.slot, h.group, e.clone())),
            _ => None,
        }) {
            panic!(
                "job {} (group {}) failed: {} ({} of {} jobs completed)",
                slot, group, e, summary.completed, summary.total()
            );
        }
        if !self.drained || summary.cancelled > 0 {
            panic!(
                "batch cancelled: {} of {} pulled jobs completed{}",
                summary.completed,
                summary.total(),
                if self.drained { "" } else { "; the source was not fully drained" }
            );
        }
        self.handles
            .into_iter()
            .map(|h| match h.outcome {
                JobOutcome::Completed(curve) => curve,
                _ => unreachable!("non-completed outcomes rejected above"),
            })
            .collect()
    }
}

/// One execution event, streamed to the run's progress consumer as it
/// happens (from whichever worker is involved — consumers synchronize
/// themselves). Counters are consistent snapshots taken under the pool
/// lock.
#[derive(Debug, Clone, PartialEq)]
pub enum Progress {
    /// A worker picked the job up.
    Started { slot: usize },
    /// The job completed; `completed` counts completions so far and
    /// `elapsed_us` is the monotonic wall time since the batch started —
    /// a display-only rate signal (live counters derive jobs/s from it).
    /// Like every `Progress` field it is observational: wall-clock values
    /// ride in events and never feed back into results.
    Finished { slot: usize, completed: usize, elapsed_us: u64 },
    /// The job was cancelled before or during execution.
    Cancelled { slot: usize },
    /// The job panicked.
    Failed { slot: usize, error: String },
}

impl Progress {
    pub fn slot(&self) -> usize {
        match *self {
            Progress::Started { slot }
            | Progress::Finished { slot, .. }
            | Progress::Cancelled { slot }
            | Progress::Failed { slot, .. } => slot,
        }
    }
}

/// A progress consumer: called from worker threads, must synchronize its
/// own state. Consumers only observe (event timing never changes
/// results), though holding a [`CancelToken`] they may cancel.
pub type ProgressSink = dyn Fn(&Progress) + Sync;

fn no_progress(_: &Progress) {}

/// The streaming worker pool. Plain configuration — worker threads are
/// scoped to each [`Executor::run`] call (jobs borrow caches and setups,
/// so a persistent `'static` pool is impossible without copying them);
/// holding an `Executor` shares its width, queue bound and cancel token
/// across successive batches (the hypertune nested fan-out does exactly
/// that).
pub struct Executor {
    threads: usize,
    queue_cap: usize,
    cancel: CancelToken,
    fail_fast: bool,
}

impl Executor {
    /// Pool with exactly `threads` workers (clamped to ≥ 1) and the
    /// default lookahead window of `2 × threads` jobs.
    pub fn new(threads: usize) -> Executor {
        let threads = threads.max(1);
        Executor { threads, queue_cap: threads * 2, cancel: CancelToken::new(), fail_fast: false }
    }

    /// Pool sized to the process default
    /// ([`crate::util::parallel::default_width`]).
    pub fn auto() -> Executor {
        Executor::new(parallel::default_width())
    }

    /// `Some(n)` for an explicit width (the CLI's `--threads`/`--jobs`),
    /// `None` for the process default.
    pub fn with_threads(threads: Option<usize>) -> Executor {
        threads.map(Executor::new).unwrap_or_else(Executor::auto)
    }

    /// Bound the source lookahead: at most `cap` jobs pulled-but-unfinished
    /// at any moment (clamped to ≥ 1; a cap below the worker count idles
    /// the excess workers). This is the backpressure knob *and* the
    /// priority-reorder window.
    pub fn queue_cap(mut self, cap: usize) -> Executor {
        self.queue_cap = cap.max(1);
        self
    }

    /// Stop starting new jobs after the first [`JobOutcome::Failed`]
    /// (jobs already running finish normally; queued/unpulled ones are
    /// cancelled). The per-run abort is internal state, so a shared
    /// `Executor` is not poisoned for later batches. Drain-all surfaces
    /// set this: when `expect_curves` will discard everything on failure
    /// anyway, computing the rest of a large grid first is pure waste.
    pub fn fail_fast(mut self) -> Executor {
        self.fail_fast = true;
        self
    }

    /// The run's cancellation token. Hand clones to signal handlers or
    /// progress consumers; firing it stops new jobs from starting and
    /// winds down running ones at their next budget check.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Adopt an externally owned cancellation token instead of the fresh
    /// per-executor one — the CLI's SIGINT bridge
    /// ([`crate::util::signal::install_sigint`]) hands every executor the
    /// one process-wide token so a single Ctrl-C winds down whichever
    /// batch is in flight.
    pub fn cancel_via(mut self, token: CancelToken) -> Executor {
        self.cancel = token;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Drain the source with no progress consumer.
    pub fn run<'a>(&self, source: &mut dyn JobSource<'a>) -> BatchResult {
        self.run_observed(source, &no_progress)
    }

    /// Convenience: a pre-materialized batch at default priority.
    pub fn run_jobs(&self, jobs: &[TuningJob<'_>]) -> BatchResult {
        self.run_jobs_observed(jobs, &no_progress)
    }

    /// [`Self::run_jobs`] with a progress consumer.
    pub fn run_jobs_observed(
        &self,
        jobs: &[TuningJob<'_>],
        sink: &ProgressSink,
    ) -> BatchResult {
        let mut source = FnSource::new(jobs.len(), |i| jobs[i].into());
        self.run_observed(&mut source, sink)
    }

    /// Drain the source, streaming [`Progress`] events to `sink`.
    pub fn run_observed<'a>(
        &self,
        source: &mut dyn JobSource<'a>,
        sink: &ProgressSink,
    ) -> BatchResult {
        let cap = self.queue_cap.max(1);
        // Don't spawn workers a small batch can never feed — but only
        // when the hint is exact (indexed grids); a conservative upper
        // bound must not serialize a large stream.
        let threads = match source.size_hint() {
            (lower, Some(upper)) if lower == upper => self.threads.min(upper.max(1)),
            _ => self.threads,
        };
        let pool = Pool {
            state: Mutex::new(PoolState {
                source,
                drained: false,
                aborted: false,
                queue: BinaryHeap::new(),
                pulled: 0,
                finished: 0,
                slots: Vec::new(),
                completed: 0,
            }),
            wakeup: Condvar::new(),
            cap,
            cancel: &self.cancel,
            fail_fast: self.fail_fast,
            sink,
            t0: Instant::now(),
        };
        if threads <= 1 {
            // Inline fast path: same pull/refill/pick loop, no spawn. Keeps
            // single-width runs cheap while exercising identical logic.
            pool.worker();
        } else {
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(|| pool.worker());
                }
            });
        }
        pool.finish()
    }
}

/// The seam between batch producers and execution engines: anything that
/// can drain a materialized batch of [`OwnedJob`]s and return the
/// slot-indexed result. Two implementors exist — [`Executor`] (a scoped
/// worker pool per call, the CLI path) and the serve daemon's persistent
/// [`SharedPool`](crate::serve::pool::SharedPool) (one long-lived pool
/// multiplexing many sessions). Consumers like
/// [`MetaTuning`](crate::hypertune::MetaTuning) program against this
/// trait, so the same meta-sweep runs unchanged in-process or served —
/// and because results are slot-indexed and every seed pre-derived, the
/// two engines are bit-identical for completed jobs.
pub trait BatchRunner: Send + Sync {
    /// Drain `jobs` (slot = index in the slice), streaming [`Progress`]
    /// events to `sink`. Priorities come from each job's `priority` field.
    fn run_batch(&self, jobs: &[OwnedJob], sink: &ProgressSink) -> BatchResult;

    /// A token that cancels batches submitted through this runner.
    fn batch_cancel_token(&self) -> CancelToken;
}

impl BatchRunner for Executor {
    fn run_batch(&self, jobs: &[OwnedJob], sink: &ProgressSink) -> BatchResult {
        let mut source = FnSource::new(jobs.len(), |i| SourcedJob {
            job: jobs[i].as_job(),
            priority: jobs[i].priority,
        });
        self.run_observed(&mut source, sink)
    }

    fn batch_cancel_token(&self) -> CancelToken {
        self.cancel_token()
    }
}

/// A queued, pulled-but-unstarted job. Max-heap order: higher priority
/// first, then lower slot — so with equal priorities the pool picks jobs
/// in stream order.
struct QueueEntry<'a> {
    priority: Priority,
    slot: usize,
    job: TuningJob<'a>,
    /// Enqueue time for the queue-wait trace span; `None` when
    /// observability is off (no clock read on the disabled path).
    enqueued: Option<Instant>,
}

impl PartialEq for QueueEntry<'_> {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.slot == other.slot
    }
}
impl Eq for QueueEntry<'_> {}
impl PartialOrd for QueueEntry<'_> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueueEntry<'_> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority.cmp(&other.priority).then(other.slot.cmp(&self.slot))
    }
}

/// Slot-indexed bookkeeping for one pulled job.
struct SlotState {
    group: usize,
    priority: Priority,
    seed: u64,
    cost_us: u64,
    outcome: Option<JobOutcome>,
}

struct PoolState<'a, 's> {
    source: &'s mut dyn JobSource<'a>,
    drained: bool,
    /// Per-run fail-fast latch: set on the first failed job when the
    /// executor was built with [`Executor::fail_fast`]; stops pulling and
    /// starting like a fired cancel token, without touching the (possibly
    /// shared) token itself.
    aborted: bool,
    queue: BinaryHeap<QueueEntry<'a>>,
    /// Jobs pulled from the source so far (also the next slot index).
    pulled: usize,
    /// Jobs with a recorded outcome. The backpressure invariant the pool
    /// maintains: `pulled - finished <= cap` at every pull.
    finished: usize,
    slots: Vec<SlotState>,
    completed: usize,
}

struct Pool<'a, 's, 'p> {
    state: Mutex<PoolState<'a, 's>>,
    wakeup: Condvar,
    cap: usize,
    cancel: &'p CancelToken,
    fail_fast: bool,
    sink: &'p ProgressSink,
    /// Batch start, the origin for `Progress::Finished::elapsed_us`.
    t0: Instant,
}

impl<'a> Pool<'a, '_, '_> {
    /// One worker: pull/refill under the lock, execute outside it, repeat
    /// until the source is drained or the token fires. Runs on scoped
    /// threads — or inline on the caller's thread for width 1.
    fn worker(&self) {
        loop {
            let entry = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if self.cancel.is_cancelled() || st.aborted {
                        break None;
                    }
                    // Refill the bounded queue. The source is polled at
                    // most `cap` jobs ahead of completion — this is the
                    // backpressure seam.
                    while !st.drained && st.pulled - st.finished < self.cap {
                        match st.source.next_job() {
                            Some(sj) => {
                                let slot = st.pulled;
                                st.pulled += 1;
                                st.slots.push(SlotState {
                                    group: sj.job.group,
                                    priority: sj.priority,
                                    seed: sj.job.seed,
                                    cost_us: sj.job.cost_us(),
                                    outcome: None,
                                });
                                st.queue.push(QueueEntry {
                                    priority: sj.priority,
                                    slot,
                                    job: sj.job,
                                    enqueued: if obs::enabled() {
                                        Some(Instant::now())
                                    } else {
                                        None
                                    },
                                });
                            }
                            None => st.drained = true,
                        }
                    }
                    if let Some(e) = st.queue.pop() {
                        break Some(e);
                    }
                    if st.drained {
                        break None;
                    }
                    // Queue empty but the window is full of running jobs:
                    // wait for a completion to reopen it. A waiting worker
                    // implies another is running a job, and every
                    // completion (and worker exit) notifies — no deadlock.
                    // The stall span makes backpressure (source-poll window
                    // exhausted) visible in traces.
                    let stall = obs::span("executor.stall");
                    st = self.wakeup.wait(st).unwrap();
                    drop(stall);
                }
            };
            let Some(entry) = entry else {
                self.wakeup.notify_all();
                return;
            };
            if let Some(enqueued) = entry.enqueued {
                drop(obs::span_at("executor.queue_wait", enqueued).kv("slot", entry.slot));
            }
            (self.sink)(&Progress::Started { slot: entry.slot });
            let mut job_span = obs::span("executor.job")
                .kv("slot", entry.slot)
                .kv("priority", entry.priority);
            let outcome = execute_isolated(&entry.job, self.cancel);
            job_span.note("outcome", outcome.label());
            drop(job_span);
            let event = {
                let mut st = self.state.lock().unwrap();
                st.finished += 1;
                let event = match &outcome {
                    JobOutcome::Completed(_) => {
                        st.completed += 1;
                        Progress::Finished {
                            slot: entry.slot,
                            completed: st.completed,
                            elapsed_us: self.t0.elapsed().as_micros() as u64,
                        }
                    }
                    JobOutcome::Cancelled => Progress::Cancelled { slot: entry.slot },
                    JobOutcome::Failed(e) => {
                        if self.fail_fast {
                            st.aborted = true;
                        }
                        Progress::Failed { slot: entry.slot, error: e.clone() }
                    }
                };
                st.slots[entry.slot].outcome = Some(outcome);
                event
            };
            self.wakeup.notify_all();
            (self.sink)(&event);
        }
    }

    /// After all workers exit: mark jobs a cancellation (or fail-fast
    /// abort) left in the queue, then freeze the slot table into handles.
    fn finish(self) -> BatchResult {
        let mut st = self.state.into_inner().unwrap();
        while let Some(e) = st.queue.pop() {
            st.slots[e.slot].outcome = Some(JobOutcome::Cancelled);
            (self.sink)(&Progress::Cancelled { slot: e.slot });
        }
        let handles = st
            .slots
            .into_iter()
            .enumerate()
            .map(|(slot, s)| JobHandle {
                slot,
                group: s.group,
                priority: s.priority,
                seed: s.seed,
                cost_us: s.cost_us,
                outcome: s.outcome.expect("pulled job left without an outcome"),
            })
            .collect();
        BatchResult { handles, drained: st.drained }
    }
}

/// Run one job with per-job panic isolation and cooperative cancellation.
/// `pub(crate)` so the serve pool maps outcomes through the identical
/// code path — the two engines must not diverge on edge semantics
/// (pre-checked cancellation, discarded partial curves, panic payloads).
pub(crate) fn execute_isolated(job: &TuningJob<'_>, cancel: &CancelToken) -> JobOutcome {
    if cancel.is_cancelled() {
        return JobOutcome::Cancelled;
    }
    match catch_unwind(AssertUnwindSafe(|| job.execute_cancellable(cancel))) {
        Ok(Some(curve)) => JobOutcome::Completed(curve),
        Ok(None) => JobOutcome::Cancelled,
        Err(payload) => JobOutcome::Failed(panic_message(payload.as_ref())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::job_seed;
    use crate::kernels::gpu::GpuSpec;
    use crate::methodology::{NamedFactory, SpaceSetup};
    use crate::searchspace::Application;
    use crate::tuning::Cache;

    #[test]
    fn queue_orders_by_priority_then_slot() {
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let setup = SpaceSetup::new(&cache);
        let factory = NamedFactory("sa".into());
        let entry = |priority: Priority, slot: usize| QueueEntry {
            priority,
            slot,
            job: TuningJob { source: &cache, setup: &setup, factory: &factory, seed: 0, group: 0 },
            enqueued: None,
        };
        let mut heap = BinaryHeap::new();
        heap.push(entry(0, 2));
        heap.push(entry(5, 3));
        heap.push(entry(0, 0));
        let order: Vec<usize> = std::iter::from_fn(|| heap.pop().map(|e| e.slot)).collect();
        assert_eq!(order, vec![3, 0, 2], "highest priority first, then lowest slot");
    }

    #[test]
    fn summary_counts_and_json_block() {
        let mut s = JobsSummary { completed: 3, cancelled: 1, failed: 0, cost_us: 300 };
        assert_eq!(s.total(), 4);
        assert!(!s.all_completed());
        s.absorb(JobsSummary { completed: 2, cancelled: 0, failed: 1, cost_us: 200 });
        assert_eq!(s, JobsSummary { completed: 5, cancelled: 1, failed: 1, cost_us: 500 });
        assert_eq!(
            s.to_json().to_string(),
            r#"{"completed":5,"cancelled":1,"failed":1,"cost_us":500}"#
        );
    }

    #[test]
    fn executor_drains_a_streamed_grid_identically_to_the_batch_path() {
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let setup = SpaceSetup::new(&cache);
        let factory = NamedFactory("sa".into());
        let space_id = cache.id();
        let job_at = |r: usize| TuningJob {
            source: &cache,
            setup: &setup,
            factory: &factory,
            seed: job_seed(42, &space_id, "sa", r as u64),
            group: r % 2,
        };
        let jobs: Vec<TuningJob> = (0..6).map(job_at).collect();
        let batch = Executor::new(4).run_jobs(&jobs);
        assert_eq!(batch.len(), 6);
        assert_eq!(batch.groups(), vec![0, 1, 0, 1, 0, 1]);
        assert!(batch.summary().all_completed());
        // Streamed (lazy, tiny lookahead) equals materialized, equals serial.
        let mut lazy = FnSource::new(6, |i| job_at(i).into());
        let streamed = Executor::new(4).queue_cap(2).run(&mut lazy);
        let serial = Executor::new(1).run_jobs(&jobs);
        assert_eq!(batch.expect_curves(), streamed.expect_curves());
        let serial_curves = serial.expect_curves();
        let direct: Vec<Vec<f64>> = jobs.iter().map(|j| j.execute()).collect();
        assert_eq!(serial_curves, direct);
    }

    #[test]
    fn fail_fast_aborts_the_stream_and_expect_curves_reports_the_failure() {
        use crate::methodology::OptimizerFactory;
        struct Bomb;
        impl crate::optimizers::Optimizer for Bomb {
            fn name(&self) -> &str {
                "bomb"
            }
            fn run(&mut self, _ctx: &mut crate::tuning::TuningContext) {
                panic!("bomb optimizer detonated");
            }
        }
        struct BombFactory;
        impl OptimizerFactory for BombFactory {
            fn build(&self) -> Box<dyn crate::optimizers::Optimizer> {
                Box::new(Bomb)
            }
            fn label(&self) -> String {
                "bomb".into()
            }
        }
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let setup = SpaceSetup::new(&cache);
        let good = NamedFactory("random".into());
        let bomb = BombFactory;
        let space_id = cache.id();
        let mut src = FnSource::new(6, |i| {
            TuningJob {
                source: &cache,
                setup: &setup,
                factory: if i == 1 {
                    &bomb as &dyn OptimizerFactory
                } else {
                    &good as &dyn OptimizerFactory
                },
                seed: job_seed(3, &space_id, "random", i as u64),
                group: 0,
            }
            .into()
        });
        // Width 1, default window 2: job 0 completes, job 1 fails and
        // latches the abort, the one queued job is cancelled, the rest of
        // the stream is never pulled.
        let batch = Executor::new(1).fail_fast().run(&mut src);
        assert!(!batch.fully_drained(), "fail-fast must stop pulling the source");
        let s = batch.summary();
        assert_eq!((s.completed, s.cancelled, s.failed), (1, 1, 1));
        let err = catch_unwind(AssertUnwindSafe(|| batch.expect_curves())).unwrap_err();
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("job 1 (group 0) failed"), "{}", msg);
        assert!(msg.contains("bomb optimizer detonated"), "{}", msg);
    }

    #[test]
    fn progress_events_cover_every_slot() {
        let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
        let setup = SpaceSetup::new(&cache);
        let factory = NamedFactory("random".into());
        let space_id = cache.id();
        let jobs: Vec<TuningJob> = (0..4)
            .map(|r| TuningJob {
                source: &cache,
                setup: &setup,
                factory: &factory,
                seed: job_seed(7, &space_id, "random", r as u64),
                group: 0,
            })
            .collect();
        let events = Mutex::new(Vec::new());
        let batch = Executor::new(2)
            .run_jobs_observed(&jobs, &|p: &Progress| events.lock().unwrap().push(p.clone()));
        assert!(batch.summary().all_completed());
        let events = events.into_inner().unwrap();
        let started: Vec<usize> = events
            .iter()
            .filter(|e| matches!(e, Progress::Started { .. }))
            .map(Progress::slot)
            .collect();
        let finished: Vec<usize> = events
            .iter()
            .filter(|e| matches!(e, Progress::Finished { .. }))
            .map(Progress::slot)
            .collect();
        assert_eq!(started.len(), 4);
        assert_eq!(finished.len(), 4);
        // The completed counter reaches the batch size exactly once.
        let max_completed = events
            .iter()
            .filter_map(|e| match e {
                Progress::Finished { completed, .. } => Some(*completed),
                _ => None,
            })
            .max();
        assert_eq!(max_completed, Some(4));
    }
}
