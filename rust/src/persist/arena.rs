//! Owned-or-mapped flat arenas.
//!
//! [`Arena<T>`] is the storage type behind every flat array the persistent
//! store serializes (`SearchSpace` config rows, CSR neighbor tables,
//! `Cache::mean_ms`/`compile_s`). It dereferences to `&[T]` so all existing
//! accessor seams keep working, and it comes in two flavors:
//!
//! - `Owned`: a plain `Vec<T>` — what fresh builds produce.
//! - `View`: a typed window into a shared byte buffer ([`Bytes`]), which is
//!   either the whole store file read into memory or an mmap of it. Loading
//!   a store file this way copies nothing: the arenas borrow the mapping.
//!
//! Safety rests on two invariants enforced at construction: the element
//! type is plain-old-data ([`Pod`]), and the view's byte offset is aligned
//! for `T` (section offsets are 16-byte aligned in the file, mmap bases are
//! page-aligned, and owned buffers are backed by `Vec<u64>`, so any `T` up
//! to 8-byte alignment is valid). The store is little-endian on disk and
//! refuses to operate on big-endian hosts rather than byte-swapping.

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

/// Marker for plain-old-data element types that can be reinterpreted from
/// raw little-endian bytes.
///
/// # Safety
///
/// Implementors must be `Copy`, have no padding, no invalid bit patterns,
/// and alignment ≤ 8.
pub unsafe trait Pod: Copy + 'static {}
unsafe impl Pod for u16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}

/// View a typed slice as raw bytes (for serialization; little-endian hosts
/// only — the store gates on endianness before calling this).
pub fn slice_bytes<T: Pod>(s: &[T]) -> &[u8] {
    // Safety: Pod guarantees no padding; any byte pattern is readable.
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, std::mem::size_of_val(s)) }
}

/// A read-only, page-aligned memory map of an entire file.
///
/// The offline environment has no `libc` crate, but every `std` binary on
/// unix already links the C library, so `mmap`/`munmap` are declared
/// directly. Non-unix targets return `Unsupported` and callers fall back to
/// reading the file into an owned buffer.
pub struct Mmap {
    #[cfg(unix)]
    ptr: *mut std::os::raw::c_void,
    len: usize,
}

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};
    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

impl Mmap {
    /// Map a whole file read-only. Fails on empty files (zero-length maps
    /// are invalid) and on non-unix targets.
    pub fn map(file: &std::fs::File) -> std::io::Result<Mmap> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let len = file.metadata()?.len();
            if len == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "cannot map an empty file",
                ));
            }
            let len = usize::try_from(len).map_err(|_| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, "file too large to map")
            })?;
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Mmap { ptr, len })
        }
        #[cfg(not(unix))]
        {
            let _ = file;
            Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "mmap is unavailable on this target",
            ))
        }
    }

    pub fn bytes(&self) -> &[u8] {
        #[cfg(unix)]
        // Safety: the mapping is valid for `len` bytes until Drop.
        unsafe {
            std::slice::from_raw_parts(self.ptr as *const u8, self.len)
        }
        #[cfg(not(unix))]
        unreachable!("Mmap cannot be constructed on non-unix targets")
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        // Safety: ptr/len came from a successful mmap and are unmapped once.
        unsafe {
            sys::munmap(self.ptr, self.len);
        }
    }
}

// Safety: the mapping is immutable (PROT_READ, MAP_PRIVATE) for its whole
// lifetime, so shared references across threads are sound.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl fmt::Debug for Mmap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mmap({} bytes)", self.len)
    }
}

/// An 8-byte-aligned owned buffer holding a whole store file (the read —
/// non-mmap — load path). Backed by `Vec<u64>` so typed views up to 8-byte
/// alignment are valid at any 8-aligned offset.
#[derive(Debug)]
pub struct OwnedBytes {
    words: Vec<u64>,
    len: usize,
}

impl OwnedBytes {
    /// Read an entire file into an aligned buffer.
    pub fn read(file: &mut std::fs::File) -> std::io::Result<OwnedBytes> {
        use std::io::Read;
        let len = file.metadata()?.len();
        let len = usize::try_from(len).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "file too large to read")
        })?;
        let mut words = vec![0u64; len.div_ceil(8)];
        // Safety: u64 has no padding; viewing its buffer as bytes is sound.
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(words.as_mut_ptr() as *mut u8, words.len() * 8)
        };
        file.read_exact(&mut bytes[..len])?;
        Ok(OwnedBytes { words, len })
    }

    pub fn bytes(&self) -> &[u8] {
        // Safety: as above; only the first `len` bytes were filled from the
        // file (the tail of the last word stays zero).
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }
}

/// The shared backing buffer of a loaded store file.
#[derive(Debug, Clone)]
pub enum Bytes {
    Owned(Arc<OwnedBytes>),
    Mapped(Arc<Mmap>),
}

impl Bytes {
    pub fn as_slice(&self) -> &[u8] {
        match self {
            Bytes::Owned(b) => b.bytes(),
            Bytes::Mapped(m) => m.bytes(),
        }
    }
}

/// A flat array that is either owned or a zero-copy view into a loaded
/// store file. Dereferences to `&[T]`.
pub enum Arena<T: Pod> {
    Owned(Vec<T>),
    View {
        bytes: Bytes,
        /// Byte offset of the first element (aligned for `T`).
        offset: usize,
        /// Element count.
        len: usize,
    },
}

impl<T: Pod> Arena<T> {
    /// Build a view into `bytes` at `offset` covering `len` elements.
    /// Returns `None` if the range is out of bounds or misaligned for `T`.
    pub fn view(bytes: Bytes, offset: usize, len: usize) -> Option<Arena<T>> {
        let byte_len = len.checked_mul(std::mem::size_of::<T>())?;
        let end = offset.checked_add(byte_len)?;
        let buf = bytes.as_slice();
        if end > buf.len() {
            return None;
        }
        if (buf.as_ptr() as usize + offset) % std::mem::align_of::<T>() != 0 {
            return None;
        }
        Some(Arena::View { bytes, offset, len })
    }

    /// Copy a byte range into an owned arena (the non-zero-copy load mode).
    pub fn copied(raw: &[u8], len: usize) -> Option<Arena<T>> {
        if raw.len() != len.checked_mul(std::mem::size_of::<T>())? {
            return None;
        }
        let mut v = Vec::<T>::with_capacity(len);
        // Safety: Pod means any bit pattern is a valid T; the source length
        // matches exactly and the Vec buffer is properly aligned.
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), v.as_mut_ptr() as *mut u8, raw.len());
            v.set_len(len);
        }
        Some(Arena::Owned(v))
    }
}

impl<T: Pod> Deref for Arena<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        match self {
            Arena::Owned(v) => v,
            Arena::View { bytes, offset, len } => {
                let buf = bytes.as_slice();
                // Safety: bounds and alignment were checked in `view`; the
                // backing buffer is immutable and owned via Arc.
                unsafe {
                    std::slice::from_raw_parts(buf.as_ptr().add(*offset) as *const T, *len)
                }
            }
        }
    }
}

impl<T: Pod> From<Vec<T>> for Arena<T> {
    fn from(v: Vec<T>) -> Arena<T> {
        Arena::Owned(v)
    }
}

impl<T: Pod + PartialEq> PartialEq for Arena<T> {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

impl<T: Pod + fmt::Debug> fmt::Debug for Arena<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let kind = match self {
            Arena::Owned(_) => "owned",
            Arena::View { .. } => "view",
        };
        write!(f, "Arena<{kind}>({} elems)", self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_derefs() {
        let a: Arena<f32> = vec![1.0f32, 2.0, 3.0].into();
        assert_eq!(&a[..], &[1.0, 2.0, 3.0]);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn copied_roundtrip() {
        let src = [7u32, 8, 9];
        let a = Arena::<u32>::copied(slice_bytes(&src), 3).unwrap();
        assert_eq!(&a[..], &src[..]);
        assert!(Arena::<u32>::copied(slice_bytes(&src), 2).is_none());
    }

    #[test]
    fn view_into_owned_bytes() {
        // Simulate a loaded buffer: 16 bytes of header + 3 u32s.
        let mut words = vec![0u64; 4];
        let payload = [5u32, 6, 7];
        unsafe {
            std::ptr::copy_nonoverlapping(
                payload.as_ptr() as *const u8,
                (words.as_mut_ptr() as *mut u8).add(16),
                12,
            );
        }
        let bytes = Bytes::Owned(Arc::new(OwnedBytes { words, len: 28 }));
        let a = Arena::<u32>::view(bytes.clone(), 16, 3).unwrap();
        assert_eq!(&a[..], &[5, 6, 7]);
        // Out of bounds and misaligned views are refused.
        assert!(Arena::<u32>::view(bytes.clone(), 24, 3).is_none());
        assert!(Arena::<u32>::view(bytes, 17, 2).is_none());
    }

    #[test]
    fn arenas_compare_across_flavors() {
        let owned: Arena<u16> = vec![1u16, 2, 3].into();
        let copied = Arena::<u16>::copied(slice_bytes(&[1u16, 2, 3]), 3).unwrap();
        assert_eq!(owned, copied);
    }
}
