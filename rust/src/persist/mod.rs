//! Persistent zero-copy store for exhaustive caches and search spaces.
//!
//! Every `llamea-kt` process used to rebuild all exhaustive caches from
//! scratch — the dominant setup cost of the simulation methodology (the
//! paper replays cachefiles of exhaustively benchmarked spaces; a full
//! harness run needs 24 of them). This module makes that a one-time cost:
//! the flat arenas behind [`crate::searchspace::SearchSpace`] and
//! [`crate::tuning::cache::Cache`] serialize into a versioned, checksummed
//! container that later processes either read back into owned `Vec`s or
//! mmap and borrow zero-copy ([`arena::Arena`]).
//!
//! # File layout
//!
//! A store file is a fixed header, a section table, and raw little-endian
//! arena dumps (see [`format`] for exact offsets):
//!
//! ```text
//! magic "LLKTPERS" | format version | section count | build fingerprint
//! payload checksum | section table { id, elem size, offset, length }…
//! header checksum  | 16-byte-aligned sections…
//! ```
//!
//! Sections are 16-byte aligned from the start of the file so `&[u16]`,
//! `&[u32]`, `&[u64]`, `&[f32]` and `&[f64]` views into the mapping are
//! always correctly aligned. Space files carry the config arena plus the
//! three CSR neighbor tables; cache files carry `mean_ms`/`compile_s` and
//! a stored summary triple that loads recompute and assert (see [`store`]
//! for the section ids and the full fingerprint contract).
//!
//! # Safety/trust model
//!
//! A file is usable only if *all* of the following hold, checked in order:
//! plausible size → magic → exact format version → header checksum →
//! section bounds/alignment → payload checksum → build fingerprint →
//! structural invariants (config values in range, CSR monotone and
//! covering, arena lengths matching the space) → summary-stat equality
//! (caches). Any failure is a rejection; callers rebuild and atomically
//! overwrite (temp file + rename), so a stale, foreign, truncated or
//! corrupt file is never silently reused and readers never observe a
//! partial write.
//!
//! The warm path lives in [`crate::coordinator::registry::CacheRegistry`]
//! (`--cache-dir`): registry misses try the store first and fall back to
//! building + saving.

pub mod arena;
pub mod format;
pub mod store;

pub use arena::Arena;
pub use format::{LoadError, LoadMode, FORMAT_VERSION};
pub use store::{
    cache_fp, cache_path, expected_cache_fp, expected_space_fp, load_cache, load_space,
    prepare_cache_dir, save_cache, save_cache_tagged, save_space, save_space_tagged, space_fp,
    space_path,
};
