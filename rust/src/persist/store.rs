//! Typed save/load of `SearchSpace` and `Cache` over the container format,
//! plus the build-fingerprint contract.
//!
//! Two file kinds live in a cache directory:
//!
//! - `space_<app>.llkt` — one per application (all GPUs of an app share
//!   its space): the flat `u16` config arena plus all three CSR neighbor
//!   tables (`u64` offsets + `u32` neighbor data per [`NeighborKind`]).
//! - `cache_<app>@<gpu>.llkt` — one per (application, GPU) pair:
//!   `mean_ms`/`compile_s` `f32` arenas plus the stored summary triple
//!   (`optimum_ms`, `median_ms`, `mean_eval_cost_s`) which loads
//!   *recompute from the arenas and assert equal* — an end-to-end
//!   integrity check beyond the byte checksums.
//!
//! # Fingerprint contract
//!
//! A store file is only reusable if every input that determines its arena
//! bytes is unchanged. The fingerprints hash exactly those inputs:
//!
//! - **space**: container format version; space name; every parameter
//!   (name, ordered value list, each value's exact bits and type tag);
//!   every constraint source string, in order.
//! - **cache**: the space fingerprint; application and GPU names;
//!   `space_salt(app, gpu)`; [`MODEL_REVISION`] (the performance-model
//!   identity); `RUNS_PER_EVAL`, `MEASUREMENT_SIGMA`, `FAILURE_COST_S`
//!   (the noise/cost constants folded into `mean_eval_cost_s` and the
//!   observation streams).
//!
//! Loading compares the file's fingerprint against the one computed from
//! the *current build*; any mismatch — stale spec, edited constraint,
//! bumped model revision, different salt or constants — rejects the file
//! and the caller rebuilds (and overwrites it). There is no path that
//! reuses a mismatched file.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::arena::slice_bytes;
use super::format::{self, LoadError, LoadMode, SectionOut, FORMAT_VERSION};
use crate::kernels::gpu::GpuSpec;
use crate::kernels::{space_salt, MODEL_REVISION};
use crate::obs;
use crate::searchspace::constraint::Constraint;
use crate::searchspace::param::{ParamSet, Value};
use crate::searchspace::{Application, NeighborKind, SearchSpace};
use crate::tuning::cache::{Cache, FAILURE_COST_S, MEASUREMENT_SIGMA, RUNS_PER_EVAL};
use crate::util::rng::avalanche;

// Section ids. Space files:
const SEC_SPACE_CONFIGS: u32 = 1;
const fn sec_csr_offsets(kind: usize) -> u32 {
    16 + 2 * kind as u32
}
const fn sec_csr_data(kind: usize) -> u32 {
    17 + 2 * kind as u32
}
// Cache files:
const SEC_MEAN_MS: u32 = 32;
const SEC_COMPILE_S: u32 = 33;
const SEC_SUMMARY: u32 = 34;

/// Incremental fingerprint builder (FNV-1a over a framed byte stream with
/// an avalanche finish). Every field is length- or tag-framed so distinct
/// input sequences cannot collide by concatenation.
struct Fp(u64);

impl Fp {
    fn new(domain: &str) -> Fp {
        let mut fp = Fp(0xcbf29ce484222325);
        fp.str(domain);
        fp.u64(FORMAT_VERSION as u64);
        fp
    }

    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001B3);
        }
    }

    fn u64(&mut self, x: u64) {
        self.bytes(&x.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }

    fn value(&mut self, v: &Value) {
        match v {
            Value::Int(i) => {
                self.u64(1);
                self.u64(*i as u64);
            }
            Value::Float(x) => {
                self.u64(2);
                self.u64(x.to_bits());
            }
            Value::Bool(b) => {
                self.u64(3);
                self.u64(*b as u64);
            }
            Value::Str(s) => {
                self.u64(4);
                self.str(s);
            }
        }
    }

    fn finish(self) -> u64 {
        avalanche(self.0)
    }
}

/// Fingerprint of a space definition (see the module docs for what it
/// covers). `sources` are the constraint source strings, in order.
pub fn space_fingerprint<'a>(
    name: &str,
    params: &ParamSet,
    sources: impl Iterator<Item = &'a str>,
) -> u64 {
    let mut fp = Fp::new("llamea-kt space");
    fp.str(name);
    fp.u64(params.dims() as u64);
    for p in &params.params {
        fp.str(&p.name);
        fp.u64(p.cardinality() as u64);
        for v in &p.values {
            fp.value(v);
        }
    }
    for s in sources {
        fp.str(s);
    }
    fp.finish()
}

/// Space fingerprint of a live, already-built space.
pub fn space_fp(space: &SearchSpace) -> u64 {
    space_fingerprint(
        &space.name,
        &space.params,
        space.constraints.iter().map(|c| c.source.as_str()),
    )
}

/// Space fingerprint of the current build's spec for `app` — what a
/// loaded `space_<app>.llkt` must carry.
pub fn expected_space_fp(app: Application) -> u64 {
    let spec = app.space_spec();
    space_fingerprint(spec.name, &spec.params, spec.constraints.iter().copied())
}

fn cache_fingerprint(space_fp: u64, app: Application, gpu: &GpuSpec, salt: u64) -> u64 {
    let mut fp = Fp::new("llamea-kt cache");
    fp.u64(space_fp);
    fp.str(app.name());
    fp.str(gpu.name);
    fp.u64(salt);
    fp.u64(MODEL_REVISION as u64);
    fp.u64(RUNS_PER_EVAL as u64);
    fp.u64(MEASUREMENT_SIGMA.to_bits());
    fp.u64(FAILURE_COST_S.to_bits());
    fp.finish()
}

/// Cache fingerprint of a live cache (what `save_cache` stamps).
pub fn cache_fp(cache: &Cache) -> u64 {
    cache_fingerprint(space_fp(&cache.space), cache.app, cache.gpu, cache.salt)
}

/// Cache fingerprint the current build expects for (app, gpu) — what a
/// loaded `cache_<app>@<gpu>.llkt` must carry.
pub fn expected_cache_fp(app: Application, gpu: &GpuSpec) -> u64 {
    cache_fingerprint(expected_space_fp(app), app, gpu, space_salt(app, gpu))
}

/// Canonical path of an application's space file inside a cache dir.
pub fn space_path(dir: &Path, app: Application) -> PathBuf {
    dir.join(format!("space_{}.llkt", app.name()))
}

/// Canonical path of a (app, gpu) cache file inside a cache dir.
pub fn cache_path(dir: &Path, app: Application, gpu_name: &str) -> PathBuf {
    dir.join(format!("cache_{}@{gpu_name}.llkt", app.name()))
}

/// Serialize a space (config arena + all three CSR tables, building any
/// table not yet built) and atomically install it at `path`.
pub fn save_space(path: &Path, space: &SearchSpace) -> std::io::Result<()> {
    save_space_tagged(path, space, space_fp(space))
}

/// [`save_space`] with an explicit fingerprint tag — the tamper seam the
/// fingerprint-rejection tests use; production callers want [`save_space`].
pub fn save_space_tagged(
    path: &Path,
    space: &SearchSpace,
    fingerprint: u64,
) -> std::io::Result<()> {
    let mut sp = obs::span("persist.save_space");
    let parts: Vec<(&[u64], &[u32])> = NeighborKind::ALL
        .iter()
        .map(|&k| space.graph_parts(k))
        .collect();
    let mut sections: Vec<SectionOut<'_>> =
        vec![(SEC_SPACE_CONFIGS, 2, slice_bytes(space.config_arena()))];
    for (slot, (offsets, rows)) in parts.iter().enumerate() {
        sections.push((sec_csr_offsets(slot), 8, slice_bytes(offsets)));
        sections.push((sec_csr_data(slot), 4, slice_bytes(rows)));
    }
    let out = format::write(path, FORMAT_VERSION, fingerprint, &sections);
    sp.note("outcome", if out.is_ok() { "ok" } else { "error" });
    out
}

/// Load a space for `app`, verifying fingerprint, checksums and every
/// structural invariant. `LoadMode::Mmap` yields arenas borrowing the
/// mapping (zero-copy); `LoadMode::Read` copies into owned `Vec`s.
pub fn load_space(path: &Path, app: Application, mode: LoadMode) -> Result<SearchSpace, LoadError> {
    let mut sp = obs::span("persist.load_space");
    let out = load_space_inner(path, app, mode);
    sp.note("outcome", load_outcome_label(&out));
    out
}

fn load_outcome_label<T>(out: &Result<T, LoadError>) -> &'static str {
    match out {
        Ok(_) => "ok",
        Err(LoadError::Missing) => "missing",
        Err(_) => "rejected",
    }
}

fn load_space_inner(
    path: &Path,
    app: Application,
    mode: LoadMode,
) -> Result<SearchSpace, LoadError> {
    let spec = app.space_spec();
    let expected = space_fingerprint(spec.name, &spec.params, spec.constraints.iter().copied());
    let loaded = format::read(path, mode)?;
    if loaded.fingerprint != expected {
        return Err(LoadError::Fingerprint {
            found: loaded.fingerprint,
            expected,
        });
    }
    let zero_copy = mode == LoadMode::Mmap;
    let data = loaded.arena::<u16>(SEC_SPACE_CONFIGS, zero_copy)?;
    let mut graphs = [None, None, None];
    for (slot, g) in graphs.iter_mut().enumerate() {
        // CSR tables are optional per kind: a file without one simply
        // rebuilds that table lazily.
        if loaded.has_section(sec_csr_offsets(slot)) && loaded.has_section(sec_csr_data(slot)) {
            *g = Some((
                loaded.arena::<u64>(sec_csr_offsets(slot), zero_copy)?,
                loaded.arena::<u32>(sec_csr_data(slot), zero_copy)?,
            ));
        }
    }
    // The spec is static and always parses; a failure here is a bug in the
    // builder, exactly as it would be for a cold build.
    let constraints: Vec<Constraint> = spec
        .constraints
        .iter()
        .map(|s| Constraint::parse(s, &spec.params).expect("builder constraint parses"))
        .collect();
    SearchSpace::from_parts(spec.name, spec.params, constraints, data, graphs)
        .map_err(LoadError::Corrupt)
}

/// Serialize a cache (arenas + stored summary triple) and atomically
/// install it at `path`.
pub fn save_cache(path: &Path, cache: &Cache) -> std::io::Result<()> {
    save_cache_tagged(path, cache, cache_fp(cache))
}

/// [`save_cache`] with an explicit fingerprint tag (test tamper seam).
pub fn save_cache_tagged(path: &Path, cache: &Cache, fingerprint: u64) -> std::io::Result<()> {
    let mut sp = obs::span("persist.save_cache");
    let summary = [cache.optimum_ms, cache.median_ms, cache.mean_eval_cost_s];
    let sections: Vec<SectionOut<'_>> = vec![
        (SEC_MEAN_MS, 4, slice_bytes(&cache.mean_ms)),
        (SEC_COMPILE_S, 4, slice_bytes(&cache.compile_s)),
        (SEC_SUMMARY, 8, slice_bytes(&summary)),
    ];
    let out = format::write(path, FORMAT_VERSION, fingerprint, &sections);
    sp.note("outcome", if out.is_ok() { "ok" } else { "error" });
    out
}

/// Load the cache for (app, gpu) against an already-resolved space,
/// verifying fingerprint and checksums, then recomputing the summary
/// statistics from the loaded arenas and asserting exact (bitwise f64)
/// equality with the stored triple.
pub fn load_cache(
    path: &Path,
    app: Application,
    gpu: &'static GpuSpec,
    space: Arc<SearchSpace>,
    mode: LoadMode,
) -> Result<Cache, LoadError> {
    let mut sp = obs::span("persist.load_cache");
    let out = load_cache_inner(path, app, gpu, space, mode);
    sp.note("outcome", load_outcome_label(&out));
    out
}

fn load_cache_inner(
    path: &Path,
    app: Application,
    gpu: &'static GpuSpec,
    space: Arc<SearchSpace>,
    mode: LoadMode,
) -> Result<Cache, LoadError> {
    let salt = space_salt(app, gpu);
    let expected = cache_fingerprint(space_fp(&space), app, gpu, salt);
    let loaded = format::read(path, mode)?;
    if loaded.fingerprint != expected {
        return Err(LoadError::Fingerprint {
            found: loaded.fingerprint,
            expected,
        });
    }
    let zero_copy = mode == LoadMode::Mmap;
    let mean_ms = loaded.arena::<f32>(SEC_MEAN_MS, zero_copy)?;
    let compile_s = loaded.arena::<f32>(SEC_COMPILE_S, zero_copy)?;
    let stored = loaded.arena::<f64>(SEC_SUMMARY, false)?;
    if stored.len() != 3 {
        return Err(LoadError::Corrupt(format!(
            "summary section holds {} values, expected 3",
            stored.len()
        )));
    }
    let cache = Cache::from_arenas(app, gpu, space, mean_ms, compile_s, salt)
        .map_err(LoadError::Corrupt)?;
    let recomputed = [cache.optimum_ms, cache.median_ms, cache.mean_eval_cost_s];
    if recomputed != stored[..] {
        return Err(LoadError::Corrupt(format!(
            "stored summary stats {:?} disagree with recomputation {:?}",
            &stored[..],
            recomputed
        )));
    }
    Ok(cache)
}

/// Resolve and validate a `--cache-dir` argument: accept an existing
/// directory, create a missing leaf whose parent exists, and reject
/// everything else with an actionable message (no raw io errors).
pub fn prepare_cache_dir(path: &Path) -> Result<PathBuf, String> {
    if path.as_os_str().is_empty() {
        return Err("--cache-dir: empty path".into());
    }
    match std::fs::metadata(path) {
        Ok(m) if m.is_dir() => Ok(path.to_path_buf()),
        Ok(_) => Err(format!(
            "--cache-dir {}: exists but is not a directory",
            path.display()
        )),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            let parent = match path.parent() {
                Some(p) if !p.as_os_str().is_empty() => p,
                _ => Path::new("."),
            };
            if parent.is_dir() {
                std::fs::create_dir(path).map_err(|e| {
                    format!("--cache-dir {}: cannot create: {e}", path.display())
                })?;
                Ok(path.to_path_buf())
            } else {
                Err(format!(
                    "--cache-dir {}: parent directory {} does not exist (create it first)",
                    path.display(),
                    parent.display()
                ))
            }
        }
        Err(e) => Err(format!("--cache-dir {}: {e}", path.display())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_sensitive_to_every_input() {
        let spec = Application::Convolution.space_spec();
        let base = space_fingerprint(spec.name, &spec.params, spec.constraints.iter().copied());
        // Name.
        assert_ne!(
            base,
            space_fingerprint("convolution2", &spec.params, spec.constraints.iter().copied())
        );
        // Constraint source text (even a whitespace-level edit).
        let mut edited: Vec<&str> = spec.constraints.to_vec();
        edited[0] = "block_size_x * block_size_y >= 33";
        assert_ne!(
            base,
            space_fingerprint(spec.name, &spec.params, edited.iter().copied())
        );
        // Dropping a constraint.
        assert_ne!(
            base,
            space_fingerprint(spec.name, &spec.params, spec.constraints[1..].iter().copied())
        );
        // Parameter values.
        let mut params = spec.params.clone();
        params.params[0].values[0] = Value::Int(17);
        assert_ne!(
            base,
            space_fingerprint(spec.name, &params, spec.constraints.iter().copied())
        );
    }

    #[test]
    fn cache_fingerprint_sensitive_to_salt_and_gpu() {
        let app = Application::Convolution;
        let sfp = expected_space_fp(app);
        let a = GpuSpec::by_name("A100").unwrap();
        let b = GpuSpec::by_name("A4000").unwrap();
        let fa = cache_fingerprint(sfp, app, a, space_salt(app, a));
        assert_eq!(fa, expected_cache_fp(app, a));
        // Different GPU → different fingerprint.
        assert_ne!(fa, expected_cache_fp(app, b));
        // Flipped salt alone → different fingerprint.
        assert_ne!(fa, cache_fingerprint(sfp, app, a, space_salt(app, a) ^ 1));
        // Different space fingerprint → different cache fingerprint.
        assert_ne!(fa, cache_fingerprint(sfp ^ 1, app, a, space_salt(app, a)));
    }

    #[test]
    fn live_space_fp_matches_spec_fp() {
        for app in Application::ALL {
            if app == Application::Hotspot {
                continue; // too large for a unit test; covered by spec identity
            }
            let space = app.build_space();
            assert_eq!(space_fp(&space), expected_space_fp(app), "{}", app.name());
        }
    }

    #[test]
    fn prepare_cache_dir_cases() {
        let base = std::env::temp_dir().join(format!("llkt-store-{}", std::process::id()));
        std::fs::create_dir_all(&base).unwrap();
        // Existing dir is accepted.
        assert_eq!(prepare_cache_dir(&base).unwrap(), base);
        // Missing leaf with existing parent is created.
        let leaf = base.join("cache");
        assert_eq!(prepare_cache_dir(&leaf).unwrap(), leaf);
        assert!(leaf.is_dir());
        // Missing parent is an actionable error, not a raw io failure.
        let deep = base.join("no-such-parent").join("cache");
        let err = prepare_cache_dir(&deep).unwrap_err();
        assert!(err.contains("parent directory"), "{err}");
        // A file in the way is rejected.
        let file = base.join("afile");
        std::fs::write(&file, b"x").unwrap();
        assert!(prepare_cache_dir(&file).unwrap_err().contains("not a directory"));
        std::fs::remove_dir_all(&base).unwrap();
    }
}
