//! The on-disk container format: header, section table, checksums.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       8     magic            "LLKTPERS"
//! 8       4     format version   (u32; readers reject other versions)
//! 12      4     section count    (u32)
//! 16      8     build fingerprint (u64; semantic identity, see store.rs)
//! 24      8     payload checksum (u64 over every section's bytes, in
//!                                 table order)
//! 32      24×n  section table:   { id u32, elem size u32,
//!                                  byte offset u64, byte length u64 }
//! 32+24n  8     header checksum  (u64 over bytes [0, 32+24n))
//! ...           payload sections, each 16-byte aligned from file start,
//!               zero-padded between sections
//! ```
//!
//! The header checksum makes truncation and header corruption detectable
//! before any offset is trusted; the payload checksum covers the arena
//! bytes themselves. Both use an FNV-style word hash with an avalanche
//! finish — integrity, not cryptography. Writes go to a temp file in the
//! destination directory followed by an atomic rename, so readers never
//! observe a half-written store.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::arena::{Arena, Bytes, Mmap, OwnedBytes, Pod};
use crate::util::rng::avalanche;

/// "LLKTPERS" as a little-endian u64.
pub const MAGIC: u64 = u64::from_le_bytes(*b"LLKTPERS");

/// Current container format version. Bump on any layout change; readers
/// reject every other version (the file is then rebuilt, never reused).
pub const FORMAT_VERSION: u32 = 1;

const FIXED_HEADER: usize = 32;
const SECTION_DESC: usize = 24;
const MAX_SECTIONS: u32 = 1024;

/// Why a store file could not be used. Everything except `Missing` is
/// worth a diagnostic; all variants mean "rebuild".
#[derive(Debug)]
pub enum LoadError {
    /// The file does not exist — the ordinary cold path.
    Missing,
    Io(std::io::Error),
    /// Structural corruption: bad magic, checksum mismatch, truncation,
    /// out-of-bounds sections, malformed arenas.
    Corrupt(String),
    /// A well-formed file from a different format version.
    Version { found: u32 },
    /// A well-formed file whose build fingerprint does not match the
    /// expected identity (stale spec, different salt/model/constants).
    Fingerprint { found: u64, expected: u64 },
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::Missing => write!(f, "file not found"),
            LoadError::Io(e) => write!(f, "io error: {e}"),
            LoadError::Corrupt(why) => write!(f, "corrupt store file: {why}"),
            LoadError::Version { found } => write!(
                f,
                "format version {found} (this build reads {FORMAT_VERSION})"
            ),
            LoadError::Fingerprint { found, expected } => write!(
                f,
                "build fingerprint {found:#018x} does not match expected {expected:#018x}"
            ),
        }
    }
}

impl From<std::io::Error> for LoadError {
    fn from(e: std::io::Error) -> LoadError {
        if e.kind() == std::io::ErrorKind::NotFound {
            LoadError::Missing
        } else {
            LoadError::Io(e)
        }
    }
}

/// Integrity checksum: FNV-style over u64 words with an avalanche finish.
pub fn checksum64(bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x100000001B3;
    let mut h = 0x9E3779B97F4A7C15u64 ^ (bytes.len() as u64).wrapping_mul(PRIME);
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        h = (h ^ w).wrapping_mul(PRIME);
        h ^= h >> 29;
    }
    for &b in chunks.remainder() {
        h = (h ^ b as u64).wrapping_mul(PRIME);
    }
    avalanche(h)
}

/// A section to be written: (section id, element size in bytes, raw bytes).
pub type SectionOut<'a> = (u32, u32, &'a [u8]);

fn align16(x: usize) -> usize {
    (x + 15) & !15
}

/// Serialize sections into a container and atomically install it at
/// `path` (temp file in the same directory + rename).
pub fn write(
    path: &Path,
    version: u32,
    fingerprint: u64,
    sections: &[SectionOut<'_>],
) -> std::io::Result<()> {
    assert!(
        sections.len() <= MAX_SECTIONS as usize,
        "too many sections ({})",
        sections.len()
    );
    if cfg!(target_endian = "big") {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "the persistent store writes little-endian arenas; big-endian hosts are unsupported",
        ));
    }
    let table_end = FIXED_HEADER + sections.len() * SECTION_DESC;
    let header_end = table_end + 8; // + header checksum

    // Lay out section offsets.
    let mut offsets = Vec::with_capacity(sections.len());
    let mut cursor = align16(header_end);
    for (_, elem, bytes) in sections {
        assert!(*elem > 0 && bytes.len() % *elem as usize == 0);
        offsets.push(cursor);
        cursor = align16(cursor + bytes.len());
    }

    let mut payload_hash = 0x9E3779B97F4A7C15u64;
    for (_, _, bytes) in sections {
        payload_hash = avalanche(payload_hash ^ checksum64(bytes));
    }

    let mut header = Vec::with_capacity(header_end);
    header.extend_from_slice(&MAGIC.to_le_bytes());
    header.extend_from_slice(&version.to_le_bytes());
    header.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    header.extend_from_slice(&fingerprint.to_le_bytes());
    header.extend_from_slice(&payload_hash.to_le_bytes());
    for ((id, elem, bytes), off) in sections.iter().zip(&offsets) {
        header.extend_from_slice(&id.to_le_bytes());
        header.extend_from_slice(&elem.to_le_bytes());
        header.extend_from_slice(&(*off as u64).to_le_bytes());
        header.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
    }
    let header_sum = checksum64(&header);
    header.extend_from_slice(&header_sum.to_le_bytes());

    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = temp_sibling(path);
    let result = (|| -> std::io::Result<()> {
        let mut f = File::create(&tmp)?;
        f.write_all(&header)?;
        let mut written = header.len();
        for ((_, _, bytes), off) in sections.iter().zip(&offsets) {
            f.write_all(&vec![0u8; off - written])?;
            f.write_all(bytes)?;
            written = off + bytes.len();
        }
        f.flush()?;
        std::fs::rename(&tmp, path)
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

fn temp_sibling(path: &Path) -> PathBuf {
    let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("store");
    let pid = std::process::id();
    path.with_file_name(format!(".{name}.tmp.{pid}"))
}

/// How to back a loaded file in memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadMode {
    /// Read the whole file into an owned, 8-byte-aligned buffer.
    Read,
    /// `mmap` the file (zero-copy); falls back to `Read` where mapping is
    /// unavailable (non-unix targets, exotic filesystems).
    Mmap,
}

#[derive(Debug, Clone, Copy)]
struct SectionDesc {
    id: u32,
    elem: u32,
    offset: usize,
    byte_len: usize,
}

/// A validated, loaded container. Arenas handed out borrow its backing
/// buffer (zero-copy) or copy out of it, per [`Loaded::arena`].
pub struct Loaded {
    bytes: Bytes,
    pub version: u32,
    pub fingerprint: u64,
    sections: Vec<SectionDesc>,
}

/// Open, validate, and index a container file. Checks (in order): size,
/// magic, version, section count, header checksum, section bounds and
/// alignment, payload checksum. Any failure is a rejection — there is no
/// partially-trusted state.
pub fn read(path: &Path, mode: LoadMode) -> Result<Loaded, LoadError> {
    if cfg!(target_endian = "big") {
        return Err(LoadError::Corrupt(
            "the persistent store is little-endian; big-endian hosts are unsupported".into(),
        ));
    }
    let mut file = File::open(path)?;
    let bytes = match mode {
        LoadMode::Mmap => match Mmap::map(&file) {
            Ok(m) => Bytes::Mapped(Arc::new(m)),
            Err(_) => Bytes::Owned(Arc::new(OwnedBytes::read(&mut file)?)),
        },
        LoadMode::Read => Bytes::Owned(Arc::new(OwnedBytes::read(&mut file)?)),
    };
    drop(file);

    let buf = bytes.as_slice();
    let corrupt = |why: &str| LoadError::Corrupt(why.to_string());
    if buf.len() < FIXED_HEADER + 8 {
        return Err(corrupt("shorter than the fixed header"));
    }
    let u64_at = |off: usize| u64::from_le_bytes(buf[off..off + 8].try_into().unwrap());
    let u32_at = |off: usize| u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
    if u64_at(0) != MAGIC {
        return Err(corrupt("bad magic"));
    }
    let version = u32_at(8);
    if version != FORMAT_VERSION {
        return Err(LoadError::Version { found: version });
    }
    let count = u32_at(12);
    if count > MAX_SECTIONS {
        return Err(corrupt("implausible section count"));
    }
    let fingerprint = u64_at(16);
    let payload_sum = u64_at(24);
    let table_end = FIXED_HEADER + count as usize * SECTION_DESC;
    if buf.len() < table_end + 8 {
        return Err(corrupt("truncated section table"));
    }
    if u64_at(table_end) != checksum64(&buf[..table_end]) {
        return Err(corrupt("header checksum mismatch"));
    }

    let mut sections = Vec::with_capacity(count as usize);
    for i in 0..count as usize {
        let base = FIXED_HEADER + i * SECTION_DESC;
        let desc = SectionDesc {
            id: u32_at(base),
            elem: u32_at(base + 4),
            offset: u64_at(base + 8) as usize,
            byte_len: u64_at(base + 16) as usize,
        };
        let end = desc
            .offset
            .checked_add(desc.byte_len)
            .ok_or_else(|| corrupt("section range overflow"))?;
        if desc.offset < table_end + 8 || end > buf.len() || desc.offset % 16 != 0 {
            return Err(corrupt("section out of bounds or misaligned"));
        }
        if desc.elem == 0 || desc.byte_len % desc.elem as usize != 0 {
            return Err(corrupt("section length not a multiple of its element size"));
        }
        sections.push(desc);
    }

    let mut payload_hash = 0x9E3779B97F4A7C15u64;
    for s in &sections {
        payload_hash = avalanche(payload_hash ^ checksum64(&buf[s.offset..s.offset + s.byte_len]));
    }
    if payload_hash != payload_sum {
        return Err(corrupt("payload checksum mismatch"));
    }

    Ok(Loaded {
        bytes,
        version,
        fingerprint,
        sections,
    })
}

impl Loaded {
    fn find(&self, id: u32) -> Option<&SectionDesc> {
        self.sections.iter().find(|s| s.id == id)
    }

    pub fn has_section(&self, id: u32) -> bool {
        self.find(id).is_some()
    }

    /// Extract a typed arena for section `id`. `zero_copy` views borrow
    /// the backing buffer; otherwise elements are copied into a `Vec<T>`.
    pub fn arena<T: Pod>(&self, id: u32, zero_copy: bool) -> Result<Arena<T>, LoadError> {
        let s = self
            .find(id)
            .ok_or_else(|| LoadError::Corrupt(format!("missing section {id}")))?;
        if s.elem as usize != std::mem::size_of::<T>() {
            return Err(LoadError::Corrupt(format!(
                "section {id} holds {}-byte elements, expected {}",
                s.elem,
                std::mem::size_of::<T>()
            )));
        }
        let len = s.byte_len / s.elem as usize;
        let arena = if zero_copy {
            Arena::view(self.bytes.clone(), s.offset, len)
        } else {
            Arena::copied(&self.bytes.as_slice()[s.offset..s.offset + s.byte_len], len)
        };
        arena.ok_or_else(|| LoadError::Corrupt(format!("section {id} view failed")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::arena::slice_bytes;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("llamea-kt-format-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}-{}.llkt", name, std::process::id()))
    }

    fn sample_sections() -> (Vec<u16>, Vec<f32>) {
        ((0..100u16).collect(), (0..50).map(|i| i as f32 * 0.5).collect())
    }

    #[test]
    fn roundtrip_both_modes() {
        let (a, b) = sample_sections();
        let path = tmp("roundtrip");
        write(
            &path,
            FORMAT_VERSION,
            0xABCD,
            &[(1, 2, slice_bytes(&a)), (2, 4, slice_bytes(&b))],
        )
        .unwrap();
        for mode in [LoadMode::Read, LoadMode::Mmap] {
            for zero_copy in [false, true] {
                let loaded = read(&path, mode).unwrap();
                assert_eq!(loaded.fingerprint, 0xABCD);
                let ra: Arena<u16> = loaded.arena(1, zero_copy).unwrap();
                let rb: Arena<f32> = loaded.arena(2, zero_copy).unwrap();
                assert_eq!(&ra[..], &a[..]);
                assert_eq!(&rb[..], &b[..]);
                assert!(!loaded.has_section(3));
            }
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_missing() {
        match read(Path::new("/nonexistent/llkt/store.llkt"), LoadMode::Read) {
            Err(LoadError::Missing) => {}
            other => panic!("expected Missing, got {other:?}"),
        }
    }

    #[test]
    fn wrong_version_rejected() {
        let (a, _) = sample_sections();
        let path = tmp("version");
        write(&path, FORMAT_VERSION + 1, 7, &[(1, 2, slice_bytes(&a))]).unwrap();
        match read(&path, LoadMode::Read) {
            Err(LoadError::Version { found }) => assert_eq!(found, FORMAT_VERSION + 1),
            other => panic!("expected Version, got {other:?}"),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncation_and_corruption_rejected() {
        let (a, b) = sample_sections();
        let path = tmp("corrupt");
        write(
            &path,
            FORMAT_VERSION,
            9,
            &[(1, 2, slice_bytes(&a)), (2, 4, slice_bytes(&b))],
        )
        .unwrap();
        let good = std::fs::read(&path).unwrap();

        // Truncations at every structural boundary.
        for cut in [10, FIXED_HEADER + 3, good.len() - 7] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(
                matches!(read(&path, LoadMode::Read), Err(LoadError::Corrupt(_))),
                "cut at {cut}"
            );
        }
        // Single-byte payload flip.
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(read(&path, LoadMode::Read), Err(LoadError::Corrupt(_))));
        // Header flip (magic).
        let mut bad = good.clone();
        bad[0] ^= 0x01;
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(read(&path, LoadMode::Read), Err(LoadError::Corrupt(_))));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn element_size_mismatch_rejected() {
        let (a, _) = sample_sections();
        let path = tmp("elem");
        write(&path, FORMAT_VERSION, 1, &[(1, 2, slice_bytes(&a))]).unwrap();
        let loaded = read(&path, LoadMode::Read).unwrap();
        assert!(loaded.arena::<f64>(1, true).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
