//! llamea-kt CLI — front end of the L3 coordinator.
//!
//! Every evaluation subcommand is a job graph handed to the coordinator
//! (`llamea_kt::coordinator`): tuning runs become `TuningJob`s (space ×
//! optimizer spec × derived seed) drained by a work-stealing worker pool,
//! and all (application, GPU) caches are built once in a process-wide
//! registry and shared across stages. `--threads N` fixes the pool width
//! (results are byte-identical for any width); `coordinate` exposes the
//! job-graph layer directly for ad-hoc grids.
//!
//! Subcommands:
//!   spaces                         print Table-1 style space statistics
//!   testbed                        print the six-GPU testbed
//!   tune --space A@G --opt NAME    one tuning run on a simulated space
//!   evolve --app NAME [--info]     one LLaMEA generation run
//!   real-tune [--kernel K]         measured PJRT tuning over AOT variants
//!   experiment <id|all> [--out D]  regenerate paper tables/figures
//!       ids: table1 fig5 fig6 table2 fig7 table3 fig8 fig9 all
//!   coordinate [--opts a,b:k=v,..] [--spaces app@gpu,..] [--runs N]
//!              [--jobs N]          run an ad-hoc optimizer × space × seed
//!                                  grid and report aggregate scores
//!   options: --runs N --gen-runs N --llm-calls N --seed S --threads N

use std::path::{Path, PathBuf};

use llamea_kt::coordinator::{
    collate, grid_aggregates, grid_jobs, score_table, CacheKey, CacheRegistry, Scheduler,
};
use llamea_kt::harness::{self, ExpOptions};
use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::llamea::{evolve, EvolutionConfig, MockLlm, SpaceInfo};
use llamea_kt::methodology::{OptimizerFactory, SpaceSetup};
use llamea_kt::optimizers::OptimizerSpec;
use llamea_kt::searchspace::Application;
use llamea_kt::tuning::{Cache, TuningContext};

fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn options(args: &[String]) -> ExpOptions {
    let mut o = ExpOptions::default();
    if let Some(v) = flag_value(args, "--runs") {
        o.runs = v.parse().expect("--runs");
    }
    if let Some(v) = flag_value(args, "--gen-runs") {
        o.gen_runs = v.parse().expect("--gen-runs");
    }
    if let Some(v) = flag_value(args, "--llm-calls") {
        o.llm_calls = v.parse().expect("--llm-calls");
    }
    if let Some(v) = flag_value(args, "--seed") {
        o.seed = v.parse().expect("--seed");
    }
    if let Some(v) = flag_value(args, "--threads") {
        o.threads = Some(v.parse().expect("--threads"));
        // Also govern the run_many-based paths (generation-stage fitness
        // evaluation, train/test split) that size their pools via auto().
        Scheduler::set_default_width(o.threads);
    }
    o
}

fn out_dir(args: &[String]) -> PathBuf {
    PathBuf::from(flag_value(args, "--out").unwrap_or_else(|| "results".into()))
}

fn cmd_spaces() {
    println!("{}", harness::table1(Path::new("results")).to_text());
}

fn cmd_tune(args: &[String]) {
    let spec = flag_value(args, "--space").unwrap_or_else(|| "convolution@A4000".into());
    let opt_name = flag_value(args, "--opt").unwrap_or_else(|| "hybrid_vndx".into());
    let seed: u64 = flag_value(args, "--seed").map(|s| s.parse().unwrap()).unwrap_or(1);
    let (app_s, gpu_s) = spec.split_once('@').expect("--space app@gpu");
    let app = Application::from_name(app_s).expect("unknown application");
    let gpu = GpuSpec::by_name(gpu_s).expect("unknown GPU");
    let t0 = std::time::Instant::now();
    let cache = Cache::build(app, gpu);
    let setup = SpaceSetup::new(&cache);
    println!(
        "space {} ({} configs), budget {:.0}s simulated, built in {:?}",
        cache.id(),
        cache.len(),
        setup.budget_s,
        t0.elapsed()
    );
    let mut opt = llamea_kt::optimizers::by_name(&opt_name).expect("unknown optimizer");
    let mut ctx = TuningContext::new(&cache, setup.budget_s, seed);
    opt.run(&mut ctx);
    let (best_i, best_v) = ctx.best().expect("no configuration found");
    println!(
        "{}: best {:.4} ms (optimum {:.4} ms) after {} unique evals",
        opt_name,
        best_v,
        cache.optimum_ms,
        ctx.unique_evals()
    );
    println!("best config: {}", cache.space.params.describe(cache.space.config(best_i)));
}

fn cmd_evolve(args: &[String]) {
    let app_s = flag_value(args, "--app").unwrap_or_else(|| "gemm".into());
    let app = Application::from_name(&app_s).expect("unknown application");
    let with_info = has_flag(args, "--info");
    let opts = options(args);
    let registry = CacheRegistry::global();
    let entries: Vec<_> = llamea_kt::kernels::gpu::TRAIN_GPUS
        .iter()
        .map(|g| registry.entry(CacheKey::new(app, GpuSpec::by_name(g).unwrap())))
        .collect();
    let caches: Vec<&Cache> = entries.iter().map(|e| &e.cache).collect();
    let info = with_info.then(|| SpaceInfo::from_cache(&entries[0].cache, &entries[0].setup));
    let mut config = EvolutionConfig::paper_defaults(app.name(), info);
    config.llm_call_budget = opts.llm_calls;
    let mut llm = MockLlm::new(opts.seed);
    let result = evolve(&config, &mut llm, &caches, opts.seed);
    println!(
        "evolved {} (fitness {:.3}) in {} LLM calls ({} failures, {} tokens)",
        result.best.genome.name,
        result.best.fitness,
        result.llm_calls,
        result.failures,
        result.tokens.total()
    );
    println!("{}", result.best.genome.summary());
    println!("fitness history: {:?}", result.fitness_history);
}

fn cmd_real_tune(args: &[String]) {
    let kernel = flag_value(args, "--kernel").unwrap_or_else(|| "gemm".into());
    let dir = PathBuf::from(flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into()));
    let set = llamea_kt::runtime::ArtifactSet::load(&dir).expect("loading manifest");
    let runtime = llamea_kt::runtime::PjrtRuntime::new().expect("PJRT client");
    println!("platform: {}", runtime.platform());
    let t0 = std::time::Instant::now();
    let measured =
        llamea_kt::runtime::measure_kernel(&runtime, &set, &kernel, 2, 7, 42).expect("measuring");
    println!(
        "measured {} variants of {} in {:?}",
        measured.measurements.len(),
        kernel,
        t0.elapsed()
    );
    let cache = &measured.cache;
    let mut sorted = measured.measurements.clone();
    sorted.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (name, ms, compile) in sorted.iter().take(5) {
        println!("  {:50} {:8.3} ms  (compile {:.2}s)", name, ms, compile);
    }
    println!("  ... optimum {:.3} ms, median {:.3} ms", cache.optimum_ms, cache.median_ms);
}

/// Run an ad-hoc (optimizer × space × seed) grid through the coordinator
/// and report aggregate scores. `--jobs N` (alias of `--threads`) fixes the
/// worker-pool width; output is identical for any width.
fn cmd_coordinate(args: &[String]) {
    let opts = options(args);
    let threads = flag_value(args, "--jobs")
        .map(|v| v.parse().expect("--jobs"))
        .or(opts.threads);
    Scheduler::set_default_width(threads);
    let runs: usize = flag_value(args, "--runs")
        .map(|v| v.parse().expect("--runs"))
        .unwrap_or(10);
    let specs: Vec<OptimizerSpec> = match flag_value(args, "--opts").as_deref() {
        None | Some("all") => llamea_kt::optimizers::all_names()
            .map(OptimizerSpec::named)
            .collect(),
        Some(list) => OptimizerSpec::parse_list(list)
            .unwrap_or_else(|| panic!("bad --opts list '{}'", list)),
    };
    let registry = CacheRegistry::global();
    let entries = match flag_value(args, "--spaces").as_deref() {
        None | Some("all") => registry.all_entries(),
        Some(list) => list
            .split(',')
            .map(|s| {
                registry.entry(
                    CacheKey::parse(s).unwrap_or_else(|| panic!("bad --spaces entry '{}'", s)),
                )
            })
            .collect(),
    };
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
    let jobs = grid_jobs(&entries, &factories, runs, opts.seed);
    let sched = Scheduler::with_threads(threads);
    eprintln!(
        "coordinating {} jobs ({} optimizers x {} spaces x {} seeds) on {} workers",
        jobs.len(),
        specs.len(),
        entries.len(),
        runs,
        sched.threads()
    );
    let t0 = std::time::Instant::now();
    let curves = sched.run(&jobs);
    let grouped = collate(factories.len() * entries.len(), &jobs, curves);
    let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
    let results = grid_aggregates(&labels, entries.len(), grouped);
    println!(
        "{}",
        score_table("Coordinator: aggregate score P per optimizer", &results).to_text()
    );
    eprintln!(
        "{} jobs over {} caches ({} built this process) in {:?}",
        jobs.len(),
        entries.len(),
        registry.builds(),
        t0.elapsed()
    );
}

fn cmd_experiment(args: &[String]) {
    let id = args.first().map(|s| s.as_str()).unwrap_or("all");
    let rest = &args[args.len().min(1)..];
    let opts = options(rest);
    let out = out_dir(rest);
    std::fs::create_dir_all(&out).ok();
    let t0 = std::time::Instant::now();
    match id {
        "table1" => println!("{}", harness::table1(&out).to_text()),
        "fig8" | "fig9" => {
            let (f8, f9) = harness::fig8_fig9(&opts, &out);
            println!("{}", f8.to_text());
            println!("{}", f9.to_text());
        }
        "fig5" | "fig6" | "table2" | "fig7" | "table3" | "generated" => {
            eprintln!(
                "generation stage ({} runs x {} LLM calls per condition)...",
                opts.gen_runs, opts.llm_calls
            );
            let generated = harness::generate_all(&opts, true);
            harness::dump_genomes(&generated, &out);
            println!("{}", harness::fig5(&generated, &out).to_text());
            let (t2, f7, t3) = harness::evaluate_generated(&generated, &opts, &out);
            println!("{}", t2.to_text());
            println!("{}", f7.to_text());
            println!("{}", t3.to_text());
        }
        "all" => {
            println!("{}", harness::table1(&out).to_text());
            println!("{}", harness::testbed_summary().to_text());
            eprintln!("generation stage...");
            let generated = harness::generate_all(&opts, true);
            harness::dump_genomes(&generated, &out);
            println!("{}", harness::fig5(&generated, &out).to_text());
            let (t2, f7, t3) = harness::evaluate_generated(&generated, &opts, &out);
            println!("{}", t2.to_text());
            println!("{}", f7.to_text());
            println!("{}", t3.to_text());
            let (f8, f9) = harness::fig8_fig9(&opts, &out);
            println!("{}", f8.to_text());
            println!("{}", f9.to_text());
            println!("{}", harness::train_test_split(&generated, &opts, &out).to_text());
        }
        other => {
            eprintln!("unknown experiment '{}'", other);
            std::process::exit(2);
        }
    }
    eprintln!("experiment {} done in {:?}; results in {}", id, t0.elapsed(), out.display());
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("spaces") => cmd_spaces(),
        Some("testbed") => println!("{}", harness::testbed_summary().to_text()),
        Some("tune") => cmd_tune(&args[1..]),
        Some("evolve") => cmd_evolve(&args[1..]),
        Some("real-tune") => cmd_real_tune(&args[1..]),
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("coordinate") => cmd_coordinate(&args[1..]),
        _ => {
            eprintln!(
                "usage: llamea-kt <spaces|testbed|tune|evolve|real-tune|experiment|coordinate> [options]\n\
                 see rust/src/main.rs header for details"
            );
            std::process::exit(2);
        }
    }
}
