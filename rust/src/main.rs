//! llamea-kt CLI — front end of the L3 coordinator.
//!
//! Every evaluation subcommand is a job graph handed to the coordinator
//! (`llamea_kt::coordinator`): tuning runs become `TuningJob`s (space ×
//! optimizer spec × derived seed) streamed into the `Executor`'s bounded
//! worker pool, and all (application, GPU) caches are built once in a
//! process-wide registry and shared across stages. While a batch drains,
//! its progress events feed a live stderr counter line (terminal only).
//! `--threads N` fixes the pool width (results are byte-identical for any
//! width); `coordinate` exposes the job-graph layer directly for ad-hoc
//! grids, and `coordinate --out`/`sweep --out` reports carry a
//! `"jobs": {completed, cancelled, failed, cost_us}` block for diffing
//! partial runs.
//!
//! Ctrl-C on `coordinate`, `sweep`, and `real-tune` is a cooperative
//! cancellation, not an abort: in-flight jobs observe the token, the
//! batch drains, and the report degrades to the completed prefix (marked
//! `"interrupted": true`) — every completed curve still bit-identical to
//! its drain-all counterpart. A second Ctrl-C kills the process.
//!
//! `serve` turns the same machinery into a long-lived daemon: one
//! process-wide cache registry and worker pool multiplexing concurrent
//! tuning sessions over newline-delimited JSON on TCP, with fair-share
//! scheduling, per-session cancellation, and admission control. `client`
//! is its command-line counterpart; a served coordinate report is
//! byte-identical to the direct CLI run of the same spec (modulo the
//! `"caches"` metadata block).
//!
//! Subcommands:
//!   spaces                         print Table-1 style space statistics
//!   testbed                        print the six-GPU testbed
//!   optimizers                     list the registry with each optimizer's
//!                                  hyperparameter domains (key, tuned
//!                                  default, sweepable values)
//!   tune --space A@G --opt NAME    one tuning run on a simulated space
//!   evolve --app NAME [--info]     one LLaMEA generation run
//!   real-tune [--kernel K]         measured PJRT tuning over AOT variants;
//!       [--opts a,b --runs N]      route the measured cache through the
//!                                  coordinator job graph
//!       [--lazy --budget-s B]      measure on demand through the
//!                                  MeasuredBackend instead of exhaustively
//!   experiment <id|all> [--out D]  regenerate paper tables/figures
//!       ids: table1 fig5 fig6 table2 fig7 table3 fig8 fig9 all
//!   coordinate [--opts a,b:k=v,..] [--spaces app@gpu,..] [--runs N]
//!              [--jobs N]          run an ad-hoc optimizer × space × seed
//!                                  grid and report aggregate scores
//!       [--backend measured        tune lazily-measured AOT variant spaces
//!        --artifacts DIR]          instead of simulated caches
//!       [--out FILE]               also write the score table as JSON
//!       [--shard K/N]              run only grid jobs with index % N == K
//!                                  and write a partial report (requires
//!                                  --out; collate with `merge`)
//!       [--workers h:p,h:p,..]     fan the grid across remote `worker`
//!                                  daemons instead of the local pool —
//!                                  the collated report is byte-identical
//!                                  to the single-process run (modulo
//!                                  "caches"); lost workers re-dispatch
//!                                  to survivors, duplicates dedup by
//!                                  index, Ctrl-C cancels the fleet
//!   race [--opts a,b:k=v,..]       race an optimizer portfolio on each
//!        [--spaces app@gpu,..]     space: Hyperband-style budget rungs
//!                                  with a UCB1 bandit keeping the top
//!                                  arms (priorities escalated, losers
//!                                  cancelled through the executor seam);
//!                                  the final rung runs at the canonical
//!                                  budget, so the winner's curve is
//!                                  bit-identical to its solo
//!                                  `coordinate --runs 1` run
//!       [--eta N]                  halving factor (default 2)
//!       [--rungs N]                budget levels (default 3)
//!       [--out FILE]               write the race report (a "race" block
//!                                  per space; byte-identical for any
//!                                  --threads width)
//!   sweep --opt NAME[:k=v,..]      meta-tune an optimizer's hyperparameters
//!                                  (overridden keys are pinned out of the
//!                                  sweep); spaces default to
//!                                  convolution@A4000
//!       [--meta grid|random|sha|   meta-search strategy — sha is
//!        <optimizer-spec>]         successive halving (default); any
//!                                  registry optimizer spec tunes the tuner
//!                                  through its own machinery
//!       [--meta-evals N]           meta-evaluation budget for
//!                                  random/sha/optimizer strategies
//!       [--spaces app@gpu,..]      inner spaces each meta-config is scored
//!                                  on  [--runs N] seeds per space
//!       [--out FILE]               write the leaderboard JSON (byte-
//!                                  identical for any --threads width)
//!       [--shard K/N]              evaluate only meta-ordinals with
//!                                  o % N == K (grid strategy only) and
//!                                  write a partial report (requires --out)
//!       [--workers h:p,h:p,..]     drain the sweep's inner batches
//!                                  through remote `worker` daemons
//!   merge <partial.json>.. --out F collate per-shard partial reports into
//!                                  exactly the single-process report,
//!                                  byte for byte
//!   serve --listen HOST:PORT       run the tuning daemon (port 0 picks a
//!                                  free port; the bound address is printed
//!                                  on stdout)
//!       [--threads N]              shared worker-pool width
//!       [--queue-cap N]            reject submissions that would push the
//!                                  pool past N outstanding jobs
//!       [--max-sessions N]         reject submissions past N concurrent
//!                                  running sessions
//!   worker --listen HOST:PORT      run a fleet worker daemon (port 0 picks
//!                                  a free port; the bound address is
//!                                  printed on stdout): executes batches
//!                                  dispatched by `coordinate`/`sweep`
//!                                  `--workers` coordinators and streams
//!                                  rows home; honors the global
//!                                  --cache-dir warm start
//!       [--threads N]              local pool width
//!   client <submit|status|cancel|tail> [--addr HOST:PORT]
//!       submit --kind coordinate|sweep [--spaces a@g,..] [--opts a,b]
//!              [--opt NAME] [--runs N] [--seed S] [--out FILE]
//!                                  submit a session, stream its progress,
//!                                  and write/print the served report
//!       status                     daemon + per-session accounting JSON
//!       cancel --session N         request cooperative cancellation
//!       tail --session N [--out F] re-attach to a session and block until
//!                                  its report
//!   options: --runs N --gen-runs N --llm-calls N --seed S --threads N
//!            --jobs N --backend cached|measured
//!            --cache-dir DIR (any subcommand: persist exhaustive caches
//!            and search spaces to DIR and warm-start from it — stale or
//!            foreign files are fingerprint-rejected and rebuilt; reports
//!            gain a "caches" block of per-key built|loaded outcomes)
//!            --trace FILE (any subcommand: record spans from every layer
//!            and write a Chrome trace-event JSON at exit — open in
//!            chrome://tracing or Perfetto; zero overhead when absent,
//!            and report bytes are identical with tracing on or off)
//!            --metrics (dump a Prometheus-text metrics snapshot to
//!            stderr at exit)
//!            --no-progress (force the live stderr counter line off; the
//!            final one-line summary still prints)

#![allow(clippy::type_complexity)]

use std::path::{Path, PathBuf};

use llamea_kt::coordinator::{
    coordinate_report, coordinate_results, grid_jobs, grid_source, merge_reports,
    partial_coordinate_json, race_report, race_table, run_race_observed, score_table, source_jobs,
    BatchRunner, CacheKey, CacheRegistry, Executor, OwnedJob, Progress, RaceConfig, Scheduler,
    ShardJob, ShardSpec, COORDINATE_TITLE,
};
use llamea_kt::harness::{self, BackendKind, ExpOptions};
use llamea_kt::hypertune::{
    leaderboard_table, sweep, sweep_json, sweep_partial_json, MetaStrategy, MetaTuning,
    SweepOutcome,
};
use llamea_kt::kernels::gpu::{GpuSpec, CPU_HOST};
use llamea_kt::llamea::{evolve, EvolutionConfig, MockLlm, SpaceInfo};
use llamea_kt::methodology::{OptimizerFactory, SpaceSetup};
use llamea_kt::obs;
use llamea_kt::optimizers::OptimizerSpec;
use llamea_kt::remote::{RemoteRunner, Worker, WorkerConfig};
use llamea_kt::runtime::{measured::NOMINAL_EVAL_COST_S, MeasuredSource, PjrtRuntime};
use llamea_kt::searchspace::Application;
use llamea_kt::serve::{client, ServeConfig, Server, SubmitSpec};
use llamea_kt::tuning::{BackendSource, Cache, TuningContext};
use llamea_kt::util::json::Json;
use llamea_kt::util::signal::install_sigint;
use llamea_kt::util::table::Table;

/// TTY detection for the live progress line, via the libc `isatty` the
/// same way `persist::arena` declares `mmap`: a hand-written extern so
/// the crate stays dependency-free on every unix.
#[cfg(unix)]
mod tty {
    use std::os::raw::c_int;
    extern "C" {
        fn isatty(fd: c_int) -> c_int;
    }
    /// Whether stderr (fd 2) is a terminal.
    pub fn stderr_is_tty() -> bool {
        // SAFETY: isatty only inspects the process's fd table.
        unsafe { isatty(2) == 1 }
    }
}

#[cfg(not(unix))]
mod tty {
    /// No TTY probe off unix: the live line stays off, the final
    /// summary still prints.
    pub fn stderr_is_tty() -> bool {
        false
    }
}

/// `--no-progress`: force the live rewritten line off even on a TTY
/// (set once in `main` before any batch runs).
static NO_PROGRESS: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Live progress counters over executor [`Progress`] events.
#[derive(Default)]
struct Counts {
    started: usize,
    completed: usize,
    cancelled: usize,
    failed: usize,
    /// Largest `Progress::Finished::elapsed_us` seen — monotonic time
    /// since the batch started, stamped by the pool itself so the
    /// jobs/s rate survives redirection and served sessions alike.
    elapsed_us: u64,
}

impl Counts {
    fn done(&self) -> usize {
        self.completed + self.cancelled + self.failed
    }

    /// `", N.N jobs/s"` once at least one job finished with a non-zero
    /// batch clock (rehydrated events from an old daemon carry 0).
    fn rate(&self) -> String {
        if self.completed == 0 || self.elapsed_us == 0 {
            return String::new();
        }
        format!(", {:.1} jobs/s", self.completed as f64 / (self.elapsed_us as f64 / 1e6))
    }
}

/// A live stderr progress line over executor [`Progress`] events: one
/// `\r`-rewritten counter line while a batch drains, active only when
/// stderr is a terminal (detected via `isatty`, so redirection/CI get
/// no control-character spam) and `--no-progress` is absent. A final
/// one-line summary with the jobs/s rate prints either way. Consumers
/// observe only — the line can never change results.
struct ProgressLine {
    /// Total jobs when the batch size is known up front (`None` for
    /// sweeps, whose fan-out depends on memo state).
    total: Option<usize>,
    enabled: bool,
    counts: std::sync::Mutex<Counts>,
}

impl ProgressLine {
    fn new(total: Option<usize>) -> ProgressLine {
        ProgressLine {
            total,
            enabled: tty::stderr_is_tty()
                && !NO_PROGRESS.load(std::sync::atomic::Ordering::Relaxed),
            counts: std::sync::Mutex::new(Counts::default()),
        }
    }

    fn total_suffix(&self) -> String {
        match self.total {
            Some(t) => format!("/{}", t),
            None => String::new(),
        }
    }

    fn observe(&self, event: &Progress) {
        let mut c = self.counts.lock().unwrap();
        match event {
            Progress::Started { .. } => c.started += 1,
            Progress::Finished { elapsed_us, .. } => {
                c.completed += 1;
                c.elapsed_us = c.elapsed_us.max(*elapsed_us);
            }
            Progress::Cancelled { .. } => c.cancelled += 1,
            Progress::Failed { .. } => c.failed += 1,
        }
        if !self.enabled {
            return;
        }
        let done = c.done();
        eprint!(
            "\r{}{} jobs done ({} running, {} cancelled, {} failed{})   ",
            done,
            self.total_suffix(),
            c.started.saturating_sub(done),
            c.cancelled,
            c.failed,
            c.rate()
        );
    }

    /// Replace the rewritten line with the final summary (call once,
    /// after the batch). Prints even when the live line was off, so a
    /// redirected run still records its throughput.
    fn finish(&self) {
        let c = self.counts.lock().unwrap();
        if self.enabled {
            // Clear the rewritten line before the summary replaces it.
            eprint!("\r{:79}\r", "");
        }
        eprintln!(
            "{}{} jobs done ({} cancelled, {} failed{})",
            c.done(),
            self.total_suffix(),
            c.cancelled,
            c.failed,
            c.rate()
        );
    }
}

/// Surface a batch that did not fully complete (visible even when the
/// progress line was suppressed because stderr is not a terminal).
/// Failed jobs are fatal, as the pre-redesign pool's panic was: scripts
/// consuming the exit status must not mistake a partial run for success.
/// Cancelled jobs only warn — cancellation is a deliberate request.
fn report_job_outcomes(summary: &llamea_kt::coordinator::JobsSummary) {
    if summary.failed > 0 {
        eprintln!(
            "error: {} of {} jobs failed ({} cancelled)",
            summary.failed,
            summary.total(),
            summary.cancelled
        );
        // Deliver the trace/metrics of the partial run before exiting:
        // a failing batch is exactly when the trace is wanted.
        obs::export::finalize();
        std::process::exit(1);
    }
    if !summary.all_completed() {
        eprintln!(
            "warning: {} of {} jobs were cancelled",
            summary.cancelled,
            summary.total()
        );
    }
}

fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn options(args: &[String]) -> ExpOptions {
    let mut o = ExpOptions::default();
    if let Some(v) = flag_value(args, "--runs") {
        o.runs = v.parse().expect("--runs");
    }
    if let Some(v) = flag_value(args, "--gen-runs") {
        o.gen_runs = v.parse().expect("--gen-runs");
    }
    if let Some(v) = flag_value(args, "--llm-calls") {
        o.llm_calls = v.parse().expect("--llm-calls");
    }
    if let Some(v) = flag_value(args, "--seed") {
        o.seed = v.parse().expect("--seed");
    }
    if let Some(v) = flag_value(args, "--threads") {
        o.threads = Some(v.parse().expect("--threads"));
        // Also govern the run_many-based paths (generation-stage fitness
        // evaluation, train/test split) that size their pools via auto().
        Scheduler::set_default_width(o.threads);
    }
    if let Some(v) = flag_value(args, "--backend") {
        o.backend = BackendKind::parse(&v)
            .unwrap_or_else(|| panic!("--backend must be 'cached' or 'measured', got '{}'", v));
    }
    o
}

fn out_dir(args: &[String]) -> PathBuf {
    PathBuf::from(flag_value(args, "--out").unwrap_or_else(|| "results".into()))
}

/// Parse `--shard K/N` if present (exit 2 on a malformed value).
fn shard_flag(args: &[String]) -> Option<ShardSpec> {
    flag_value(args, "--shard").map(|s| {
        ShardSpec::parse(&s).unwrap_or_else(|e| {
            eprintln!("{}", e);
            std::process::exit(2);
        })
    })
}

/// `--out` is mandatory for sharded runs: the partial report *is* the
/// deliverable (scores only exist on the merged whole).
fn shard_out(args: &[String]) -> String {
    flag_value(args, "--out").unwrap_or_else(|| {
        eprintln!("--shard requires --out FILE (the partial report is the shard's output)");
        std::process::exit(2);
    })
}

/// Write a report, appending the registry's `"caches"` block — run
/// metadata (built-vs-loaded outcomes with wall seconds), deliberately
/// outside the byte-identity contract: identity comparisons strip this
/// one key, and `merge` emits none.
fn write_report(path: &str, mut json: llamea_kt::util::json::Json) {
    json.set("caches", CacheRegistry::global().caches_json());
    llamea_kt::util::json::write_file(Path::new(path), &json)
        .unwrap_or_else(|e| panic!("writing {}: {}", path, e));
}

/// The warm/cold cache tally for the post-run stderr summary.
fn cache_tally(registry: &CacheRegistry) -> String {
    format!(
        "{} loaded from store, {} built this process",
        registry.loads() + registry.space_loads(),
        registry.builds() + registry.space_builds()
    )
}

fn cmd_spaces() {
    println!("{}", harness::table1(Path::new("results")).to_text());
}

fn cmd_tune(args: &[String]) {
    let spec = flag_value(args, "--space").unwrap_or_else(|| "convolution@A4000".into());
    let opt_name = flag_value(args, "--opt").unwrap_or_else(|| "hybrid_vndx".into());
    let seed: u64 = flag_value(args, "--seed").map(|s| s.parse().unwrap()).unwrap_or(1);
    let (app_s, gpu_s) = spec.split_once('@').expect("--space app@gpu");
    let app = Application::from_name(app_s).expect("unknown application");
    let gpu = GpuSpec::by_name(gpu_s).expect("unknown GPU");
    let t0 = std::time::Instant::now();
    let cache = Cache::build(app, gpu);
    let setup = SpaceSetup::new(&cache);
    println!(
        "space {} ({} configs), budget {:.0}s simulated, built in {:?}",
        cache.id(),
        cache.len(),
        setup.budget_s,
        t0.elapsed()
    );
    let mut opt = llamea_kt::optimizers::by_name(&opt_name).expect("unknown optimizer");
    let mut ctx = TuningContext::new(&cache, setup.budget_s, seed);
    opt.run(&mut ctx);
    let (best_i, best_v) = ctx.best().expect("no configuration found");
    println!(
        "{}: best {:.4} ms (optimum {:.4} ms) after {} unique evals",
        opt_name,
        best_v,
        cache.optimum_ms,
        ctx.unique_evals()
    );
    println!("best config: {}", cache.space.params.describe(cache.space.config(best_i)));
}

fn cmd_evolve(args: &[String]) {
    let app_s = flag_value(args, "--app").unwrap_or_else(|| "gemm".into());
    let app = Application::from_name(&app_s).expect("unknown application");
    let with_info = has_flag(args, "--info");
    let opts = options(args);
    let registry = CacheRegistry::global();
    let entries: Vec<_> = llamea_kt::kernels::gpu::TRAIN_GPUS
        .iter()
        .map(|g| registry.entry(CacheKey::new(app, GpuSpec::by_name(g).unwrap())))
        .collect();
    let caches: Vec<&Cache> = entries.iter().map(|e| &e.cache).collect();
    let info = with_info.then(|| SpaceInfo::from_cache(&entries[0].cache, &entries[0].setup));
    let mut config = EvolutionConfig::paper_defaults(app.name(), info);
    config.llm_call_budget = opts.llm_calls;
    let mut llm = MockLlm::new(opts.seed);
    let result = evolve(&config, &mut llm, &caches, opts.seed);
    println!(
        "evolved {} (fitness {:.3}) in {} LLM calls ({} failures, {} tokens)",
        result.best.genome.name,
        result.best.fitness,
        result.llm_calls,
        result.failures,
        result.tokens.total()
    );
    println!("{}", result.best.genome.summary());
    println!("fitness history: {:?}", result.fitness_history);
}

/// List the optimizer registry with each optimizer's typed hyperparameter
/// domains: key, tuned default, and the sweepable value grid (the
/// `--opts name:key=val` and `sweep --opt` surface; values outside the
/// grid are rejected at parse time).
fn cmd_optimizers() {
    let mut t = Table::new(
        "Registered optimizers (override via --opts name:key=val,...; sweep via `sweep --opt`)",
        &["Name", "Hyperparameter", "Default", "Sweepable values"],
    );
    for name in llamea_kt::optimizers::all_names() {
        let opt = llamea_kt::optimizers::by_name(name).unwrap();
        let domains = opt.hyperparam_domains();
        if domains.is_empty() {
            t.row(vec![name.to_string(), "(none exposed)".into(), String::new(), String::new()]);
            continue;
        }
        for (i, d) in domains.iter().enumerate() {
            let values =
                d.values.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ");
            t.row(vec![
                if i == 0 { name.to_string() } else { String::new() },
                d.key.to_string(),
                d.default.to_string(),
                values,
            ]);
        }
    }
    println!("{}", t.to_text());
}

/// Resolve a `--spaces` list against the global registry (`None`/`"all"` =
/// the full 4×6 grid; `default` is used when the flag is absent and
/// non-empty — the `sweep` path, where the full grid would be excessive).
fn space_entries(
    args: &[String],
    default: &str,
) -> Vec<std::sync::Arc<llamea_kt::coordinator::SpaceEntry>> {
    let registry = CacheRegistry::global();
    let value = flag_value(args, "--spaces");
    let list = match value.as_deref() {
        None if !default.is_empty() => default.to_string(),
        None | Some("all") => return registry.all_entries(),
        Some(list) => list.to_string(),
    };
    // No empty-segment filtering: a malformed list (`--spaces ""`,
    // `--spaces a@b,`) must fail loudly, not silently select nothing.
    list.split(',')
        .map(|s| {
            registry
                .entry(CacheKey::parse(s).unwrap_or_else(|| panic!("bad --spaces entry '{}'", s)))
        })
        .collect()
}

/// Parse `--opts` into specs (default: the given fallback list).
fn opt_specs(args: &[String], fallback: &[&str]) -> Vec<OptimizerSpec> {
    match flag_value(args, "--opts").as_deref() {
        None => fallback.iter().map(|n| OptimizerSpec::named(*n)).collect(),
        Some("all") => llamea_kt::optimizers::all_names().map(OptimizerSpec::named).collect(),
        Some(list) => OptimizerSpec::parse_list(list)
            .unwrap_or_else(|| panic!("bad --opts list '{}'", list)),
    }
}

fn pjrt_runtime_or_exit() -> PjrtRuntime {
    match PjrtRuntime::new() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("measured path unavailable: {}", e);
            std::process::exit(2);
        }
    }
}

fn cmd_real_tune(args: &[String]) {
    let kernel = flag_value(args, "--kernel").unwrap_or_else(|| "gemm".into());
    let dir = PathBuf::from(flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into()));
    let set = llamea_kt::runtime::ArtifactSet::load(&dir).expect("loading manifest");
    let runtime = pjrt_runtime_or_exit();
    println!("platform: {}", runtime.platform());
    let opts = options(args);
    let runs: usize = flag_value(args, "--runs").map(|v| v.parse().expect("--runs")).unwrap_or(3);

    if has_flag(args, "--lazy") {
        // Lazy mode: optimizers drive the MeasuredBackend directly; only
        // visited variants are compiled and timed, and the shared source
        // store dedups measurements across all seeds and optimizers.
        let budget_s: f64 =
            flag_value(args, "--budget-s").map(|v| v.parse().expect("--budget-s")).unwrap_or(60.0);
        let source = MeasuredSource::new(&runtime, &set, &kernel, 2, 7, opts.seed)
            .expect("building variant space");
        let specs = opt_specs(args, &["hybrid_vndx"]);
        let factories: Vec<(String, &dyn OptimizerFactory)> =
            specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
        let sources: Vec<(&dyn BackendSource, SpaceSetup)> = vec![(
            &source as &dyn BackendSource,
            SpaceSetup::uncalibrated(budget_s, NOMINAL_EVAL_COST_S),
        )];
        let jobs = source_jobs(&sources, &factories, runs, opts.seed);
        let t0 = std::time::Instant::now();
        let progress = ProgressLine::new(Some(jobs.len()));
        let batch = Executor::with_threads(opts.threads)
            .cancel_via(install_sigint())
            .run_jobs_observed(&jobs, &|ev| progress.observe(ev));
        progress.finish();
        report_job_outcomes(&batch.summary());
        let space_len = source.space().len();
        println!(
            "lazily measured {}/{} variants of {} in {:?} ({} jobs, budget {:.0}s each)",
            source.measured_count(),
            space_len,
            kernel,
            t0.elapsed(),
            jobs.len(),
            budget_s
        );
        for (name, ms, cost) in source.results().iter().take(5) {
            println!("  {:50} {:8.3} ms  (eval cost {:.2}s)", name, ms, cost);
        }
        for e in source.errors() {
            eprintln!("  measurement error: {}", e);
        }
        return;
    }

    let t0 = std::time::Instant::now();
    let measured =
        llamea_kt::runtime::measure_kernel(&runtime, &set, &kernel, 2, 7, 42).expect("measuring");
    println!(
        "measured {} variants of {} in {:?}",
        measured.measurements.len(),
        kernel,
        t0.elapsed()
    );
    let mut sorted = measured.measurements.clone();
    sorted.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (name, ms, compile) in sorted.iter().take(5) {
        println!("  {:50} {:8.3} ms  (compile {:.2}s)", name, ms, compile);
    }
    let cache = measured.cache;
    println!("  ... optimum {:.3} ms, median {:.3} ms", cache.optimum_ms, cache.median_ms);

    if flag_value(args, "--opts").is_some() {
        // Route the measured cache through the same registry/job-graph as
        // the simulated spaces: optimizers tune real measurements.
        let specs = opt_specs(args, &[]);
        let registry = CacheRegistry::global();
        let space_name = cache.space.name.clone();
        let entry = registry.insert(CacheKey::new(cache.app, &CPU_HOST), cache);
        // Kernels that don't map onto a known application all key as
        // (Gemm, CPU-PJRT); first registration wins, so a collision would
        // silently report another kernel's measurements. Refuse instead.
        if entry.cache.space.name != space_name {
            eprintln!(
                "registry key {} already holds measured space '{}' (this run measured '{}'); \
                 re-run in a fresh process",
                entry.key.id(),
                entry.cache.space.name,
                space_name
            );
            std::process::exit(2);
        }
        let entries = vec![entry];
        let factories: Vec<(String, &dyn OptimizerFactory)> =
            specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
        let jobs = grid_jobs(&entries, &factories, runs, opts.seed);
        let progress = ProgressLine::new(Some(jobs.len()));
        let batch = Executor::with_threads(opts.threads)
            .fail_fast()
            .cancel_via(install_sigint())
            .run_jobs_observed(&jobs, &|ev| progress.observe(ev));
        progress.finish();
        // Completed-prefix collation: a Ctrl-C mid-grid still reports
        // every optimizer whose runs all finished.
        let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
        let results = coordinate_results(&labels, entries.len(), &batch);
        println!(
            "{}",
            score_table("Measured space: aggregate score P per optimizer", &results).to_text()
        );
        report_job_outcomes(&batch.summary());
    }
}

/// Run an ad-hoc (optimizer × space × seed) grid through the coordinator
/// and report aggregate scores. `--jobs N` (alias of `--threads`) fixes the
/// worker-pool width; output is identical for any width. With `--backend
/// measured`, the grid runs over lazily-measured AOT variant spaces from
/// `--artifacts` instead of simulated caches.
fn cmd_coordinate(args: &[String]) {
    let opts = options(args);
    let threads = flag_value(args, "--jobs")
        .map(|v| v.parse().expect("--jobs"))
        .or(opts.threads);
    Scheduler::set_default_width(threads);
    let runs: usize = flag_value(args, "--runs")
        .map(|v| v.parse().expect("--runs"))
        .unwrap_or(10);
    let all_names: Vec<&str> = llamea_kt::optimizers::all_names().collect();
    let specs: Vec<OptimizerSpec> = opt_specs(args, &all_names);
    if opts.backend == BackendKind::Measured {
        coordinate_measured(args, &opts, &specs, threads, runs);
        return;
    }
    let registry = CacheRegistry::global();
    let entries = space_entries(args, "");
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
    let n_jobs = entries.len() * factories.len() * runs;
    let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
    let title = COORDINATE_TITLE;

    if let Some(workers) = workers_flag(args) {
        // Fleet run: same grid, same slots, same seeds — partitioned
        // across remote workers and collated by index, so the report is
        // byte-identical to the local run (modulo "caches", which now
        // reflects the workers' registries, not this process's).
        if shard_flag(args).is_some() {
            eprintln!("--workers and --shard are mutually exclusive (the fleet partitions dynamically)");
            std::process::exit(2);
        }
        let arc_specs: Vec<std::sync::Arc<OptimizerSpec>> =
            specs.iter().cloned().map(std::sync::Arc::new).collect();
        let jobs = OwnedJob::grid(&entries, &arc_specs, runs, opts.seed);
        eprintln!(
            "coordinating {} jobs ({} optimizers x {} spaces x {} seeds) over {} remote workers",
            n_jobs,
            specs.len(),
            entries.len(),
            runs,
            workers.len()
        );
        let t0 = std::time::Instant::now();
        let runner = RemoteRunner::new(workers).cancel_via(install_sigint());
        let progress = ProgressLine::new(Some(n_jobs));
        let batch = runner.run_batch(&jobs, &|ev| progress.observe(ev));
        progress.finish();
        let results = coordinate_results(&labels, entries.len(), &batch);
        println!("{}", score_table(title, &results).to_text());
        if let Some(path) = flag_value(args, "--out") {
            let ids: Vec<String> = entries.iter().map(|e| e.cache.id()).collect();
            write_report(&path, coordinate_report(title, &ids, &labels, &batch));
            eprintln!("score table written to {}", path);
        }
        report_worker_tallies(&runner);
        eprintln!("{} jobs over {} spaces in {:?}", n_jobs, entries.len(), t0.elapsed());
        report_job_outcomes(&batch.summary());
        return;
    }
    let exec = Executor::with_threads(threads).fail_fast().cancel_via(install_sigint());

    if let Some(shard) = shard_flag(args) {
        // Sharded run: execute only the owned slice of the grid and write
        // a partial report of raw curves (`merge` collates the shards
        // into exactly the single-process report).
        let path = shard_out(args);
        let all_jobs = grid_jobs(&entries, &factories, runs, opts.seed);
        let picked: Vec<usize> = (0..all_jobs.len()).filter(|&i| shard.owns(i)).collect();
        let shard_jobs: Vec<_> = picked.iter().map(|&i| all_jobs[i]).collect();
        eprintln!(
            "coordinating shard {}/{}: {} of {} jobs on {} workers",
            shard.index,
            shard.count,
            shard_jobs.len(),
            n_jobs,
            exec.threads()
        );
        let t0 = std::time::Instant::now();
        let progress = ProgressLine::new(Some(shard_jobs.len()));
        let batch = exec.run_jobs_observed(&shard_jobs, &|ev| progress.observe(ev));
        progress.finish();
        let summary = batch.summary();
        // Completed jobs only: an interrupted shard still writes an honest
        // partial report (`merge` refuses incomplete coverage, so nothing
        // downstream can mistake it for the full slice).
        let rows: Vec<ShardJob> = batch
            .handles
            .iter()
            .filter_map(|h| {
                h.outcome.curve().map(|curve| {
                    let i = picked[h.slot];
                    ShardJob { index: i, group: all_jobs[i].group, curve: curve.to_vec() }
                })
            })
            .collect();
        let ids: Vec<String> = entries.iter().map(|e| e.cache.id()).collect();
        let json = partial_coordinate_json(
            title, &ids, &labels, runs, opts.seed, &shard, n_jobs, &summary, &rows,
        );
        write_report(&path, json);
        eprintln!("partial report written to {}", path);
        eprintln!(
            "{} jobs (caches: {}) in {:?}",
            rows.len(),
            cache_tally(registry),
            t0.elapsed()
        );
        report_job_outcomes(&summary);
        return;
    }

    eprintln!(
        "coordinating {} jobs ({} optimizers x {} spaces x {} seeds) on {} workers",
        n_jobs,
        specs.len(),
        entries.len(),
        runs,
        exec.threads()
    );
    let t0 = std::time::Instant::now();
    // The grid streams into the executor's bounded queue; the progress
    // line consumes the event stream while the batch drains.
    let mut source = grid_source(&entries, &factories, runs, opts.seed);
    let progress = ProgressLine::new(Some(n_jobs));
    let batch = exec.run_observed(&mut source, &|ev| progress.observe(ev));
    progress.finish();
    // Completed-prefix collation (shared with the serve daemon): a fully
    // completed batch renders the historical report byte-for-byte; a
    // Ctrl-C'd one degrades to the scoreable subset, marked
    // `"interrupted": true`, instead of panicking away finished work.
    let results = coordinate_results(&labels, entries.len(), &batch);
    println!("{}", score_table(title, &results).to_text());
    if let Some(path) = flag_value(args, "--out") {
        let ids: Vec<String> = entries.iter().map(|e| e.cache.id()).collect();
        write_report(&path, coordinate_report(title, &ids, &labels, &batch));
        eprintln!("score table written to {}", path);
    }
    eprintln!(
        "{} jobs over {} spaces (caches: {}) in {:?}",
        n_jobs,
        entries.len(),
        cache_tally(registry),
        t0.elapsed()
    );
    report_job_outcomes(&batch.summary());
}

/// `race`: race an optimizer portfolio on each space through the bandit
/// rung ladder (`coordinator::race`). Every flag that shapes the outcome
/// (`--opts`, `--spaces`, `--eta`, `--rungs`, `--seed`) is deterministic
/// input; `--threads` only changes wall-clock. Ctrl-C cancels
/// cooperatively — the report keeps completed rungs and is marked
/// `"interrupted": true`.
fn cmd_race(args: &[String]) {
    let opts = options(args);
    let eta: usize =
        flag_value(args, "--eta").map(|v| v.parse().expect("--eta")).unwrap_or(2);
    let rungs: usize =
        flag_value(args, "--rungs").map(|v| v.parse().expect("--rungs")).unwrap_or(3);
    let registry = CacheRegistry::global();
    let entries = space_entries(args, "");
    let all_names: Vec<&str> = llamea_kt::optimizers::all_names().collect();
    let specs: Vec<OptimizerSpec> = opt_specs(args, &all_names);
    let cfg = RaceConfig {
        eta,
        rungs,
        seed: opts.seed,
        threads: opts.threads,
        cancel: Some(install_sigint()),
    };
    eprintln!(
        "racing {} arms over {} spaces ({} rungs, eta {})",
        specs.len(),
        entries.len(),
        rungs.max(1),
        eta.max(2)
    );
    let t0 = std::time::Instant::now();
    let mut outcomes = Vec::with_capacity(entries.len());
    for entry in &entries {
        let progress = ProgressLine::new(None);
        let outcome = run_race_observed(entry, &specs, &cfg, &|ev| progress.observe(ev));
        progress.finish();
        println!("{}", race_table(&outcome).to_text());
        let stop = outcome.interrupted;
        outcomes.push(outcome);
        if stop {
            break; // Ctrl-C: keep the completed spaces, skip the rest
        }
    }
    let mut jobs = llamea_kt::coordinator::JobsSummary::default();
    for o in &outcomes {
        jobs.absorb(o.jobs);
    }
    if let Some(path) = flag_value(args, "--out") {
        write_report(&path, race_report(&outcomes, &cfg));
        eprintln!("race report written to {}", path);
    }
    eprintln!(
        "{} jobs over {} spaces (caches: {}) in {:?}",
        jobs.total(),
        outcomes.len(),
        cache_tally(registry),
        t0.elapsed()
    );
    report_job_outcomes(&jobs);
}

/// The `--backend measured` arm of `coordinate`: one lazily-measured
/// variant space per kernel in the artifact manifest, tuned through the
/// same job graph. Each space shares one measurement store, so the whole
/// grid compiles/times every variant at most once.
fn coordinate_measured(
    args: &[String],
    opts: &ExpOptions,
    specs: &[OptimizerSpec],
    threads: Option<usize>,
    runs: usize,
) {
    if flag_value(args, "--spaces").is_some() {
        eprintln!(
            "--backend measured selects kernels from the artifact manifest; \
             use --kernel K instead of --spaces"
        );
        std::process::exit(2);
    }
    let dir = PathBuf::from(flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into()));
    let set = llamea_kt::runtime::ArtifactSet::load(&dir).expect("loading manifest");
    let runtime = pjrt_runtime_or_exit();
    let budget_s: f64 =
        flag_value(args, "--budget-s").map(|v| v.parse().expect("--budget-s")).unwrap_or(60.0);
    let kernels = match flag_value(args, "--kernel") {
        Some(k) => vec![k],
        None => set.kernels(),
    };
    let owned: Vec<MeasuredSource> = kernels
        .iter()
        .map(|k| {
            MeasuredSource::new(&runtime, &set, k, 2, 7, opts.seed)
                .unwrap_or_else(|e| panic!("variant space for '{}': {}", k, e))
        })
        .collect();
    let sources: Vec<(&dyn BackendSource, SpaceSetup)> = owned
        .iter()
        .map(|s| (s as &dyn BackendSource, SpaceSetup::uncalibrated(budget_s, NOMINAL_EVAL_COST_S)))
        .collect();
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
    let jobs = source_jobs(&sources, &factories, runs, opts.seed);
    let exec = Executor::with_threads(threads).cancel_via(install_sigint());
    eprintln!(
        "coordinating {} measured jobs ({} optimizers x {} kernels x {} seeds) on {} workers",
        jobs.len(),
        factories.len(),
        sources.len(),
        runs,
        exec.threads()
    );
    let t0 = std::time::Instant::now();
    let progress = ProgressLine::new(Some(jobs.len()));
    let batch = exec.run_jobs_observed(&jobs, &|ev| progress.observe(ev));
    progress.finish();
    report_job_outcomes(&batch.summary());
    // No methodology score table here: uncalibrated spaces have no
    // random-search reference, so curve-based scores would be
    // meaningless. The deliverables are the measured optima.
    for source in &owned {
        println!(
            "{}: measured {}/{} variants",
            source.space_id(),
            source.measured_count(),
            source.space().len()
        );
        for (name, ms, cost) in source.results().iter().take(3) {
            println!("  {:50} {:8.3} ms  (eval cost {:.2}s)", name, ms, cost);
        }
        for e in source.errors() {
            eprintln!("  measurement error: {}", e);
        }
    }
    eprintln!("{} jobs in {:?}", jobs.len(), t0.elapsed());
}

/// Meta-tune an optimizer's hyperparameters through the job graph: each
/// meta-configuration is scored by a grid of seeded tuning runs over the
/// selected spaces, searched by grid / random / successive-halving / any
/// registry optimizer. Output (leaderboard and `--out` JSON) is
/// byte-identical for any `--threads`/`--jobs` width.
fn cmd_sweep(args: &[String]) {
    let opts = options(args);
    let threads =
        flag_value(args, "--jobs").map(|v| v.parse().expect("--jobs")).or(opts.threads);
    Scheduler::set_default_width(threads);
    let opt = flag_value(args, "--opt").unwrap_or_else(|| "ga".into());
    let base = OptimizerSpec::parse(&opt)
        .unwrap_or_else(|| panic!("bad --opt spec '{}' (see `llamea-kt optimizers`)", opt));
    let runs: usize =
        flag_value(args, "--runs").map(|v| v.parse().expect("--runs")).unwrap_or(5);
    let evals: usize = flag_value(args, "--meta-evals")
        .map(|v| v.parse().expect("--meta-evals"))
        .unwrap_or(16);
    assert!(evals > 0, "--meta-evals must be at least 1");
    let meta = flag_value(args, "--meta").unwrap_or_else(|| "sha".into());
    let strategy = MetaStrategy::parse(&meta, evals)
        .unwrap_or_else(|| panic!("--meta must be grid|random|sha|<optimizer-spec>, got '{}'", meta));
    // The full 4×6 grid per meta-evaluation is rarely what an interactive
    // sweep wants; default to one cheap space and let --spaces widen it.
    let entries = space_entries(args, "convolution@A4000");
    // The sweep's inner job batches stream progress events to the live
    // line (total unknown up front: the fan-out depends on memo state).
    let progress = std::sync::Arc::new(ProgressLine::new(None));
    let line = std::sync::Arc::clone(&progress);
    // `--workers`: drain every inner batch through the remote fleet
    // instead of the sweep's own executor (scores and reports stay
    // byte-identical — the runner seam guarantees collation by slot).
    let remote = workers_flag(args)
        .map(|workers| std::sync::Arc::new(RemoteRunner::new(workers).cancel_via(install_sigint())));
    let mut mt = MetaTuning::new(base, entries, runs, opts.seed, threads)
        .unwrap_or_else(|e| panic!("sweep setup: {}", e))
        .with_cancel(install_sigint())
        .with_progress(Box::new(move |ev| line.observe(ev)));
    if let Some(runner) = &remote {
        mt = mt.with_runner(std::sync::Arc::clone(runner) as std::sync::Arc<dyn BatchRunner>);
    }
    let mt = mt;

    if let Some(shard) = shard_flag(args) {
        if remote.is_some() {
            eprintln!("--workers and --shard are mutually exclusive (the fleet partitions dynamically)");
            std::process::exit(2);
        }
        // Sharded sweep: only the grid strategy has an up-front job set
        // (adaptive strategies pick later evaluations from earlier
        // scores, so their work cannot be partitioned before running).
        if !matches!(strategy, MetaStrategy::Grid) {
            eprintln!(
                "--shard requires --meta grid (strategy '{}' decides its evaluations \
                 adaptively and cannot be partitioned up front)",
                strategy.label()
            );
            std::process::exit(2);
        }
        let path = shard_out(args);
        let cands: Vec<u32> =
            (0..mt.space().len() as u32).filter(|&o| shard.owns(o as usize)).collect();
        eprintln!(
            "sweeping shard {}/{}: {} of {} meta-configs of {} over {} ({} seeds each)",
            shard.index,
            shard.count,
            cands.len(),
            mt.space().len(),
            mt.base(),
            mt.space_ids().join(","),
            mt.runs(),
        );
        let t0 = std::time::Instant::now();
        mt.evaluate_all(&cands, mt.runs());
        progress.finish();
        let outcome = SweepOutcome {
            strategy: strategy.label(),
            leaderboard: mt.leaderboard(),
            rungs: Vec::new(),
        };
        write_report(&path, sweep_partial_json(&mt, &outcome, opts.seed, &shard));
        eprintln!("partial sweep report written to {}", path);
        eprintln!(
            "{} meta-evaluations / {} inner jobs (caches: {}) in {:?}",
            mt.evaluations(),
            mt.jobs_summary().total(),
            cache_tally(CacheRegistry::global()),
            t0.elapsed()
        );
        return;
    }

    eprintln!(
        "sweeping {} meta-configs of {} over {} ({} seeds each, strategy {}, ~{:.0}s simulated per meta-eval)",
        mt.space().len(),
        mt.base(),
        mt.space_ids().join(","),
        mt.runs(),
        strategy.label(),
        mt.meta_eval_cost_s(),
    );
    let t0 = std::time::Instant::now();
    let outcome = sweep(&mt, &strategy, opts.seed);
    progress.finish();
    println!(
        "{}",
        leaderboard_table("Hypertune: hyperparameter leaderboard", &outcome.leaderboard, 10)
            .to_text()
    );
    for rung in &outcome.rungs {
        eprintln!(
            "  rung @{} seeds: {} candidates -> {} survivors",
            rung.runs,
            rung.candidates.len(),
            rung.survivors.len()
        );
    }
    if let Some(best) = outcome.leaderboard.first() {
        println!("best: {} (score {:.3} over {} seeds)", best.spec, best.score, best.runs);
    }
    if let Some(path) = flag_value(args, "--out") {
        write_report(&path, sweep_json(&mt, &outcome, opts.seed));
        eprintln!("sweep report written to {}", path);
    }
    if let Some(runner) = &remote {
        report_worker_tallies(runner);
    }
    let jobs = mt.jobs_summary();
    eprintln!(
        "{} meta-evaluations / {} inner jobs over {} distinct configs ({} memo hits, caches: {}) in {:?}",
        mt.evaluations(),
        jobs.total(),
        outcome.leaderboard.len(),
        mt.memo_hits(),
        cache_tally(CacheRegistry::global()),
        t0.elapsed()
    );
}

/// Collate per-shard partial reports (`coordinate --shard` / `sweep
/// --shard` outputs) into the single-process report, byte for byte.
/// Inputs are the positional arguments; `--out` names the merged file.
fn cmd_merge(args: &[String]) {
    let out = flag_value(args, "--out").unwrap_or_else(|| {
        eprintln!("merge requires --out FILE");
        std::process::exit(2);
    });
    let mut inputs: Vec<&String> = Vec::new();
    let mut skip = false;
    for a in args {
        if skip {
            skip = false;
            continue;
        }
        if a == "--out" || a == "--cache-dir" || a == "--trace" {
            skip = true;
            continue;
        }
        if a == "--metrics" || a == "--no-progress" {
            continue;
        }
        if a.starts_with("--") {
            eprintln!("merge: unknown flag '{}' (usage: merge <partial.json>.. --out F)", a);
            std::process::exit(2);
        }
        inputs.push(a);
    }
    if inputs.is_empty() {
        eprintln!("merge: no partial reports given");
        std::process::exit(2);
    }
    let partials: Vec<llamea_kt::util::json::Json> = inputs
        .iter()
        .map(|p| {
            llamea_kt::util::json::read_file(Path::new(p)).unwrap_or_else(|e| {
                eprintln!("merge: {}", e);
                std::process::exit(2);
            })
        })
        .collect();
    let merged = merge_reports(&partials).unwrap_or_else(|e| {
        eprintln!("merge: {}", e);
        std::process::exit(2);
    });
    llamea_kt::util::json::write_file(Path::new(&out), &merged)
        .unwrap_or_else(|e| panic!("writing {}: {}", out, e));
    eprintln!("merged {} partial reports into {}", partials.len(), out);
}

/// Run the tuning daemon: one process-wide cache registry (honoring the
/// global `--cache-dir`) and one shared worker pool serving concurrent
/// sessions over newline-delimited JSON (see `llamea_kt::serve`). Ctrl-C
/// shuts down cooperatively: running sessions are cancelled, their
/// completed-prefix reports delivered, the pool joined.
fn cmd_serve(args: &[String]) {
    let opts = options(args);
    let listen = flag_value(args, "--listen").unwrap_or_else(|| "127.0.0.1:4517".into());
    let queue_cap: usize = flag_value(args, "--queue-cap")
        .map(|v| v.parse().expect("--queue-cap"))
        .unwrap_or(0);
    let max_sessions: usize = flag_value(args, "--max-sessions")
        .map(|v| v.parse().expect("--max-sessions"))
        .unwrap_or(0);
    let config = ServeConfig { threads: opts.threads, queue_cap, max_sessions };
    let server = Server::bind(&listen, config).unwrap_or_else(|e| {
        eprintln!("serve: cannot bind {}: {}", listen, e);
        std::process::exit(2);
    });
    let addr = server.local_addr();
    eprintln!(
        "llamea-kt serve: listening on {} ({} workers, queue cap {}, session cap {})",
        addr,
        server.threads(),
        if queue_cap == 0 { "none".to_string() } else { queue_cap.to_string() },
        if max_sessions == 0 { "none".to_string() } else { max_sessions.to_string() },
    );
    // Machine-readable bound address (scripts rely on it with port 0);
    // flushed explicitly because stdout is block-buffered under
    // redirection and the daemon does not exit.
    {
        use std::io::Write;
        let mut out = std::io::stdout();
        writeln!(out, "{}", addr).ok();
        out.flush().ok();
    }
    let handle = server.handle();
    let sigint = install_sigint();
    std::thread::spawn(move || {
        while !sigint.is_cancelled() {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        handle.shutdown();
    });
    server.run().unwrap_or_else(|e| {
        eprintln!("serve: {}", e);
        std::process::exit(1);
    });
    eprintln!("llamea-kt serve: shut down");
}

/// `--workers h:p,h:p,..` — remote fleet addresses for
/// `coordinate`/`sweep`.
fn workers_flag(args: &[String]) -> Option<Vec<String>> {
    let raw = flag_value(args, "--workers")?;
    let workers: Vec<String> =
        raw.split(',').map(str::trim).filter(|s| !s.is_empty()).map(str::to_string).collect();
    if workers.is_empty() {
        eprintln!("--workers needs at least one HOST:PORT address");
        std::process::exit(2);
    }
    Some(workers)
}

/// Per-worker fleet accounting on stderr: one tally line per worker plus
/// the absorbed fleet total. Observational — the report's `"jobs"` block
/// comes from the deduped batch, not from these.
fn report_worker_tallies(runner: &RemoteRunner) {
    let tallies = runner.tallies();
    let mut fleet = llamea_kt::coordinator::JobsSummary::default();
    for t in &tallies {
        fleet.absorb(t.jobs);
        eprintln!(
            "worker {}: dispatched {}, rows {}, duplicates {}, completed {}, cancelled {}, failed {}{}",
            t.addr,
            t.dispatched,
            t.rows,
            t.duplicates,
            t.jobs.completed,
            t.jobs.cancelled,
            t.jobs.failed,
            if t.lost { " (lost)" } else { "" }
        );
    }
    eprintln!(
        "fleet total: {} completed, {} cancelled, {} failed across {} workers",
        fleet.completed,
        fleet.cancelled,
        fleet.failed,
        tallies.len()
    );
}

/// Run a fleet worker daemon: accept batches dispatched by
/// `coordinate`/`sweep` `--workers` coordinators, execute them on a
/// local deterministic pool (honoring the global `--cache-dir` warm
/// start), and stream rows home. Ctrl-C shuts down cooperatively: a
/// running batch is cancelled and its coordinator re-dispatches the
/// unfinished indices to surviving workers.
fn cmd_worker(args: &[String]) {
    let opts = options(args);
    let listen = flag_value(args, "--listen").unwrap_or_else(|| "127.0.0.1:4518".into());
    let config = WorkerConfig { threads: opts.threads, ..WorkerConfig::default() };
    let worker = Worker::bind(&listen, config).unwrap_or_else(|e| {
        eprintln!("worker: cannot bind {}: {}", listen, e);
        std::process::exit(2);
    });
    let addr = worker.local_addr();
    eprintln!("llamea-kt worker: listening on {} ({} threads)", addr, worker.threads());
    // Machine-readable bound address (scripts rely on it with port 0);
    // flushed explicitly because stdout is block-buffered under
    // redirection and the daemon does not exit.
    {
        use std::io::Write;
        let mut out = std::io::stdout();
        writeln!(out, "{}", addr).ok();
        out.flush().ok();
    }
    let handle = worker.handle();
    let sigint = install_sigint();
    std::thread::spawn(move || {
        while !sigint.is_cancelled() {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        handle.shutdown();
    });
    worker.run().unwrap_or_else(|e| {
        eprintln!("worker: {}", e);
        std::process::exit(1);
    });
    eprintln!("llamea-kt worker: shut down");
}

/// Rehydrate a daemon progress event into the executor's [`Progress`] so
/// `client submit`/`tail` reuse the CLI's live counter line.
fn progress_from_event(ev: &Json) -> Option<Progress> {
    if ev.get("event").and_then(|v| v.as_str()) != Some("progress") {
        return None;
    }
    let slot = ev.get("slot").and_then(|v| v.as_usize())?;
    match ev.get("kind").and_then(|v| v.as_str())? {
        "started" => Some(Progress::Started { slot }),
        "finished" => Some(Progress::Finished {
            slot,
            completed: ev.get("completed").and_then(|v| v.as_usize()).unwrap_or(0),
            elapsed_us: ev.get("elapsed_us").and_then(|v| v.as_usize()).unwrap_or(0) as u64,
        }),
        "cancelled" => Some(Progress::Cancelled { slot }),
        "failed" => Some(Progress::Failed {
            slot,
            error: ev.get("error").and_then(|v| v.as_str()).unwrap_or("").to_string(),
        }),
        _ => None,
    }
}

/// Deliver a served report: `--out FILE` writes it through the same JSON
/// writer as direct-CLI reports (byte-identical files), otherwise it
/// pretty-prints to stdout. Interrupted sessions get a stderr warning.
fn client_deliver_report(args: &[String], session: u64, report: &Json) {
    if let Some(path) = flag_value(args, "--out") {
        llamea_kt::util::json::write_file(Path::new(&path), report)
            .unwrap_or_else(|e| panic!("writing {}: {}", path, e));
        eprintln!("served report for session {} written to {}", session, path);
    } else {
        println!("{}", report.to_pretty());
    }
    if report.get("interrupted").is_some() {
        eprintln!(
            "warning: session {} was interrupted; the report covers the completed prefix",
            session
        );
    }
}

fn client_err(what: &str, e: String) -> ! {
    eprintln!("client {}: {}", what, e);
    std::process::exit(1);
}

/// `--session N` (mandatory for cancel/tail).
fn client_session(args: &[String], sub: &str) -> u64 {
    flag_value(args, "--session").map(|v| v.parse().expect("--session")).unwrap_or_else(|| {
        eprintln!("client {} requires --session N", sub);
        std::process::exit(2);
    })
}

/// Command-line counterpart of the daemon (see `llamea_kt::serve::client`).
fn cmd_client(args: &[String]) {
    let sub = args.first().map(|s| s.as_str()).unwrap_or("");
    let rest = &args[args.len().min(1)..];
    let addr = flag_value(rest, "--addr").unwrap_or_else(|| "127.0.0.1:4517".into());
    match sub {
        "submit" => {
            let kind = flag_value(rest, "--kind").unwrap_or_else(|| "coordinate".into());
            let spaces: Vec<String> = flag_value(rest, "--spaces")
                .unwrap_or_else(|| "convolution@A4000".into())
                .split(',')
                .map(str::to_string)
                .collect();
            let runs: usize =
                flag_value(rest, "--runs").map(|v| v.parse().expect("--runs")).unwrap_or(3);
            let seed: u64 =
                flag_value(rest, "--seed").map(|v| v.parse().expect("--seed")).unwrap_or(1);
            let spec = match kind.as_str() {
                "coordinate" => SubmitSpec::Coordinate {
                    spaces,
                    opts: flag_value(rest, "--opts")
                        .unwrap_or_else(|| "sa,random".into())
                        .split(',')
                        .map(str::to_string)
                        .collect(),
                    runs,
                    seed,
                },
                "sweep" => SubmitSpec::Sweep {
                    spaces,
                    opt: flag_value(rest, "--opt").unwrap_or_else(|| "ga".into()),
                    runs,
                    seed,
                },
                other => {
                    eprintln!("client submit: --kind must be coordinate|sweep, got '{}'", other);
                    std::process::exit(2);
                }
            };
            let progress = ProgressLine::new(None);
            let mut on_event = |ev: &Json| {
                if ev.get("event").and_then(|v| v.as_str()) == Some("accepted") {
                    eprintln!(
                        "session {} accepted ({} jobs)",
                        ev.get("session").and_then(|v| v.as_usize()).unwrap_or(0),
                        ev.get("jobs").and_then(|v| v.as_usize()).unwrap_or(0)
                    );
                } else if let Some(p) = progress_from_event(ev) {
                    progress.observe(&p);
                }
            };
            let (session, report) = client::submit(&addr, &spec, &mut on_event)
                .unwrap_or_else(|e| client_err("submit", e));
            progress.finish();
            client_deliver_report(rest, session, &report);
        }
        "status" => {
            let status =
                client::status(&addr).unwrap_or_else(|e| client_err("status", e));
            println!("{}", status.to_pretty());
        }
        "cancel" => {
            let session = client_session(rest, "cancel");
            client::cancel(&addr, session).unwrap_or_else(|e| client_err("cancel", e));
            eprintln!("cancellation requested for session {}", session);
        }
        "tail" => {
            let session = client_session(rest, "tail");
            let progress = ProgressLine::new(None);
            let mut on_event = |ev: &Json| {
                if let Some(p) = progress_from_event(ev) {
                    progress.observe(&p);
                }
            };
            let report = client::tail(&addr, session, &mut on_event)
                .unwrap_or_else(|e| client_err("tail", e));
            progress.finish();
            client_deliver_report(rest, session, &report);
        }
        other => {
            eprintln!(
                "usage: llamea-kt client <submit|status|cancel|tail> [--addr HOST:PORT] \
                 (got '{}')",
                other
            );
            std::process::exit(2);
        }
    }
}

fn cmd_experiment(args: &[String]) {
    let id = args.first().map(|s| s.as_str()).unwrap_or("all");
    let rest = &args[args.len().min(1)..];
    let opts = options(rest);
    if opts.backend == BackendKind::Measured {
        eprintln!(
            "experiment grids replay the paper's simulated testbed; \
             --backend measured applies to `coordinate` and `real-tune`"
        );
        std::process::exit(2);
    }
    let out = out_dir(rest);
    std::fs::create_dir_all(&out).ok();
    let t0 = std::time::Instant::now();
    match id {
        "table1" => println!("{}", harness::table1(&out).to_text()),
        "fig8" | "fig9" => {
            let (f8, f9) = harness::fig8_fig9(&opts, &out);
            println!("{}", f8.to_text());
            println!("{}", f9.to_text());
        }
        "fig5" | "fig6" | "table2" | "fig7" | "table3" | "generated" => {
            eprintln!(
                "generation stage ({} runs x {} LLM calls per condition)...",
                opts.gen_runs, opts.llm_calls
            );
            let generated = harness::generate_all(&opts, true);
            harness::dump_genomes(&generated, &out);
            println!("{}", harness::fig5(&generated, &out).to_text());
            let (t2, f7, t3) = harness::evaluate_generated(&generated, &opts, &out);
            println!("{}", t2.to_text());
            println!("{}", f7.to_text());
            println!("{}", t3.to_text());
        }
        "all" => {
            println!("{}", harness::table1(&out).to_text());
            println!("{}", harness::testbed_summary().to_text());
            eprintln!("generation stage...");
            let generated = harness::generate_all(&opts, true);
            harness::dump_genomes(&generated, &out);
            println!("{}", harness::fig5(&generated, &out).to_text());
            let (t2, f7, t3) = harness::evaluate_generated(&generated, &opts, &out);
            println!("{}", t2.to_text());
            println!("{}", f7.to_text());
            println!("{}", t3.to_text());
            let (f8, f9) = harness::fig8_fig9(&opts, &out);
            println!("{}", f8.to_text());
            println!("{}", f9.to_text());
            println!("{}", harness::train_test_split(&generated, &opts, &out).to_text());
        }
        other => {
            eprintln!("unknown experiment '{}'", other);
            std::process::exit(2);
        }
    }
    eprintln!("experiment {} done in {:?}; results in {}", id, t0.elapsed(), out.display());
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // `--cache-dir DIR` works on every subcommand: all registry lookups
    // anywhere in the process warm-start from (and save back to) DIR.
    if let Some(dir) = flag_value(&args, "--cache-dir") {
        match llamea_kt::persist::prepare_cache_dir(Path::new(&dir)) {
            Ok(p) => CacheRegistry::global().set_cache_dir(Some(p)),
            Err(e) => {
                eprintln!("--cache-dir: {}", e);
                std::process::exit(2);
            }
        }
    }
    // Observability flags work on every subcommand and are strictly
    // out-of-band: `--trace FILE` records spans process-wide and writes
    // a Chrome trace at exit, `--metrics` dumps a Prometheus snapshot
    // to stderr. With neither flag the recorder never turns on and the
    // per-span cost is one relaxed atomic load.
    let trace_path = flag_value(&args, "--trace").map(PathBuf::from);
    let dump_metrics = has_flag(&args, "--metrics");
    if trace_path.is_some() || dump_metrics {
        obs::enable(trace_path.is_some(), dump_metrics);
        obs::export::configure(trace_path, dump_metrics);
    }
    if has_flag(&args, "--no-progress") {
        NO_PROGRESS.store(true, std::sync::atomic::Ordering::Relaxed);
    }
    match args.first().map(|s| s.as_str()) {
        Some("spaces") => cmd_spaces(),
        Some("testbed") => println!("{}", harness::testbed_summary().to_text()),
        Some("optimizers") => cmd_optimizers(),
        Some("tune") => cmd_tune(&args[1..]),
        Some("evolve") => cmd_evolve(&args[1..]),
        Some("real-tune") => cmd_real_tune(&args[1..]),
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("coordinate") => cmd_coordinate(&args[1..]),
        Some("race") => cmd_race(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("merge") => cmd_merge(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("client") => cmd_client(&args[1..]),
        _ => {
            eprintln!(
                "usage: llamea-kt <spaces|testbed|optimizers|tune|evolve|real-tune|experiment|coordinate|race|sweep|merge|serve|worker|client> [options]\n\
                 see rust/src/main.rs header for details"
            );
            std::process::exit(2);
        }
    }
    // The exit point every successful subcommand reaches; failed
    // batches finalize in `report_job_outcomes` before their exit(1).
    // Idempotent, so both paths can call it unconditionally.
    obs::export::finalize();
}
