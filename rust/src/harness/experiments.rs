//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§4). Each function writes CSV + markdown into `out_dir` and
//! returns the rendered table for the CLI to print. See DESIGN.md §5 for
//! the experiment index and EXPERIMENTS.md for recorded runs.

use std::collections::BTreeMap;
use std::path::Path;

use crate::coordinator::{
    collate_groups, grid_aggregates, grid_source, CacheKey, CacheRegistry, Executor,
};
use crate::kernels::gpu::{GpuSpec, ALL_GPUS, TEST_GPUS, TRAIN_GPUS};
use crate::llamea::{evolve_best_of_runs, EvolutionConfig, Genome, MockLlm, SpaceInfo};
use crate::methodology::{run_many, Aggregate, OptimizerFactory};
use crate::optimizers::OptimizerSpec;
use crate::searchspace::Application;
use crate::tuning::Cache;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::table::{delta, f, Table};

/// Which evaluation backend a grid runs against (the CLI's `--backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pre-explored simulated caches (the paper's replayed-cachefile mode).
    #[default]
    Cached,
    /// Lazily measured AOT variants over PJRT (`coordinate`/`real-tune`
    /// only — the paper's figures are defined over the simulated testbed).
    Measured,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "cached" => Some(BackendKind::Cached),
            "measured" => Some(BackendKind::Measured),
            _ => None,
        }
    }
}

/// Shared experiment options.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Tuning runs per (algorithm, space) in final evaluations (paper: 100).
    pub runs: usize,
    /// Independent LLaMEA runs per generation condition (paper: 5).
    pub gen_runs: usize,
    /// LLM calls per LLaMEA run (paper: 100).
    pub llm_calls: u64,
    pub seed: u64,
    /// Executor worker count; `None` sizes the pool to the machine.
    pub threads: Option<usize>,
    /// Evaluation backend the grid runs against.
    pub backend: BackendKind,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            runs: 100,
            gen_runs: 5,
            llm_calls: 100,
            seed: 2026,
            threads: None,
            backend: BackendKind::Cached,
        }
    }
}

fn write(out_dir: &Path, name: &str, content: &str) {
    std::fs::create_dir_all(out_dir).ok();
    std::fs::write(out_dir.join(name), content).expect("writing result file");
}

/// The paper's experiment grids are defined over the simulated testbed;
/// validate the option where it is consumed, so library callers cannot
/// silently run cached when they asked for measured.
fn require_cached_backend(opts: &ExpOptions) {
    assert!(
        opts.backend == BackendKind::Cached,
        "experiment grids replay the paper's simulated testbed; \
         --backend measured applies to `coordinate` and `real-tune`"
    );
}

// ---------------------------------------------------------------- Table 1

/// Table 1: search-space characteristics, paper vs ours.
pub fn table1(out_dir: &Path) -> Table {
    let mut t = Table::new(
        "Table 1: search-space characteristics (paper vs reproduction)",
        &[
            "Name",
            "Cartesian (paper)",
            "Cartesian (ours)",
            "Constrained (paper)",
            "Constrained (ours)",
            "Dims (paper)",
            "Dims (ours)",
        ],
    );
    for app in Application::ALL {
        let (pc, pcon, pd) = app.paper_table1();
        let space = app.build_space();
        t.row(vec![
            app.name().to_string(),
            pc.to_string(),
            space.cartesian_size().to_string(),
            pcon.to_string(),
            space.len().to_string(),
            pd.to_string(),
            space.dims().to_string(),
        ]);
    }
    write(out_dir, "table1.csv", &t.to_csv());
    write(out_dir, "table1.md", &t.to_markdown());
    t
}

// ------------------------------------------------ Generation (Figs 5-7, T2-3)

/// One generated optimizer: its condition and the evolved genome.
pub struct GeneratedAlgo {
    pub application: Application,
    pub with_info: bool,
    pub genome: Genome,
    pub train_fitness: f64,
    /// Token totals of the 5 independent runs (Fig. 5).
    pub run_tokens: Vec<u64>,
    pub failures: u64,
}

impl GeneratedAlgo {
    pub fn label(&self) -> String {
        format!(
            "{}-{}",
            self.application.name(),
            if self.with_info { "info" } else { "noinfo" }
        )
    }
}

/// Run the generation stage: 4 applications x {with, without info}
/// (paper §4.2), each the best of `gen_runs` independent LLaMEA runs
/// trained on the target application's three training-GPU spaces (shared
/// with the evaluation stages via the coordinator registry).
pub fn generate_all(opts: &ExpOptions, progress: bool) -> Vec<GeneratedAlgo> {
    require_cached_backend(opts);
    let registry = CacheRegistry::global();
    let mut out = Vec::new();
    for app in Application::ALL {
        let entries: Vec<_> = TRAIN_GPUS
            .iter()
            .map(|g| registry.entry(CacheKey::new(app, GpuSpec::by_name(g).unwrap())))
            .collect();
        let caches: Vec<&Cache> = entries.iter().map(|e| &e.cache).collect();
        for with_info in [false, true] {
            let info =
                with_info.then(|| SpaceInfo::from_cache(&entries[0].cache, &entries[0].setup));
            let mut config = EvolutionConfig::paper_defaults(app.name(), info);
            config.llm_call_budget = opts.llm_calls;
            let mut make = |seed: u64| -> Box<dyn crate::llamea::LlmClient> {
                Box::new(MockLlm::new(seed))
            };
            let (result, run_tokens) = evolve_best_of_runs(
                &config,
                &mut make,
                &caches,
                opts.gen_runs,
                opts.seed ^ crate::util::rng::fnv1a(app.name().as_bytes())
                    ^ (with_info as u64) << 32,
            );
            if progress {
                eprintln!(
                    "  generated {}-{}: fitness {:.3}, {} failures, {} tokens avg",
                    app.name(),
                    if with_info { "info" } else { "noinfo" },
                    result.best.fitness,
                    result.failures,
                    run_tokens.iter().sum::<u64>() / run_tokens.len() as u64
                );
            }
            out.push(GeneratedAlgo {
                application: app,
                with_info,
                genome: result.best.genome,
                train_fitness: result.best.fitness,
                run_tokens,
                failures: result.failures,
            });
        }
    }
    out
}

/// Fig. 5: total LLM tokens per generated optimizer (mean +- std over runs).
pub fn fig5(generated: &[GeneratedAlgo], out_dir: &Path) -> Table {
    let mut t = Table::new(
        "Fig 5: LLM tokens per generated optimizer (mean ± std over runs)",
        &["Optimizer", "Mean tokens", "Std"],
    );
    for g in generated {
        let toks: Vec<f64> = g.run_tokens.iter().map(|&x| x as f64).collect();
        t.row(vec![
            g.label(),
            format!("{:.0}", stats::mean(&toks)),
            format!("{:.0}", stats::std_dev(&toks)),
        ]);
    }
    write(out_dir, "fig5.csv", &t.to_csv());
    write(out_dir, "fig5.md", &t.to_markdown());
    t
}

/// Evaluation of a set of labeled optimizers over all 24 spaces, as one
/// flat job batch on the shared registry: the scheduler parallelizes
/// across optimizers × spaces × seeds at once, and repeated calls (fig6,
/// fig8, ...) reuse the same caches instead of rebuilding them.
/// Returns (label, per-space aggregate) plus writes curve CSVs.
pub fn evaluate_on_all_spaces(
    factories: &[(String, &dyn OptimizerFactory)],
    opts: &ExpOptions,
    seed: u64,
    out_dir: &Path,
    file_prefix: &str,
) -> Vec<(String, Aggregate, Vec<String>)> {
    require_cached_backend(opts);
    let entries = CacheRegistry::global().all_entries();
    let space_ids: Vec<String> = entries.iter().map(|e| e.cache.id()).collect();
    // The grid streams through the executor's bounded queue instead of
    // materializing optimizers × spaces × seeds jobs up front.
    let mut source = grid_source(&entries, factories, opts.runs, seed);
    let batch = Executor::with_threads(opts.threads).fail_fast().run(&mut source);
    let groups = batch.groups();
    let grouped =
        collate_groups(factories.len() * entries.len(), &groups, batch.expect_curves());
    let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();

    let mut curves_csv = String::from("algorithm,t_frac,mean,ci95\n");
    let mut out = Vec::new();
    for (label, agg) in grid_aggregates(&labels, entries.len(), grouped) {
        let n = agg.curve.len();
        for (j, (&m, &ci)) in agg.curve.iter().zip(&agg.ci95).enumerate() {
            curves_csv.push_str(&format!(
                "{},{:.4},{:.4},{:.4}\n",
                label,
                (j + 1) as f64 / n as f64,
                m,
                ci
            ));
        }
        out.push((label, agg, space_ids.clone()));
    }
    write(out_dir, &format!("{}_curves.csv", file_prefix), &curves_csv);
    out
}

/// Table 2 + Figs 6-7 + Table 3: evaluate the 8 generated algorithms on all
/// 24 spaces and derive every §4.2 artifact.
pub fn evaluate_generated(
    generated: &[GeneratedAlgo],
    opts: &ExpOptions,
    out_dir: &Path,
) -> (Table, Table, Table) {
    let factories: Vec<(String, OptimizerSpec)> = generated
        .iter()
        .map(|g| (g.label(), OptimizerSpec::genome(g.genome.clone())))
        .collect();
    let refs: Vec<(String, &dyn OptimizerFactory)> = factories
        .iter()
        .map(|(l, spec)| (l.clone(), spec as &dyn OptimizerFactory))
        .collect();
    let results = evaluate_on_all_spaces(&refs, opts, opts.seed, out_dir, "fig6");

    // ---- Table 2: per-application with/without info ----
    let mut t2 = Table::new(
        "Table 2: overall performance scores, without vs with extra info",
        &["Target application", "Without extra info", "With extra info", "Difference"],
    );
    let mut sums = (0.0, 0.0);
    for app in Application::ALL {
        let find = |with_info: bool| -> &Aggregate {
            let label = format!(
                "{}-{}",
                app.name(),
                if with_info { "info" } else { "noinfo" }
            );
            &results.iter().find(|(l, _, _)| *l == label).unwrap().1
        };
        let (wo, wi) = (find(false), find(true));
        sums.0 += wo.score;
        sums.1 += wi.score;
        t2.row(vec![
            app.name().to_string(),
            format!("{} ± {}", f(wo.score, 3), f(wo.score_std, 3)),
            format!("{} ± {}", f(wi.score, 3), f(wi.score_std, 3)),
            delta(wi.score - wo.score, 3),
        ]);
    }
    t2.row(vec![
        "Mean".into(),
        f(sums.0 / 4.0, 3),
        f(sums.1 / 4.0, 3),
        delta((sums.1 - sums.0) / 4.0, 3),
    ]);
    write(out_dir, "table2.csv", &t2.to_csv());
    write(out_dir, "table2.md", &t2.to_markdown());

    // ---- Fig 7: per-space score matrix ----
    let space_ids = &results[0].2;
    let mut f7 = Table::new(
        "Fig 7: per-search-space performance scores of the generated algorithms",
        &std::iter::once("space")
            .chain(results.iter().map(|(l, _, _)| l.as_str()))
            .collect::<Vec<_>>(),
    );
    for (si, sid) in space_ids.iter().enumerate() {
        let mut row = vec![sid.clone()];
        for (_, agg, _) in &results {
            row.push(f(agg.per_space_scores[si], 3));
        }
        f7.row(row);
    }
    write(out_dir, "fig7.csv", &f7.to_csv());
    write(out_dir, "fig7.md", &f7.to_markdown());

    // ---- Table 3: target vs non-target ----
    // Per-application score of each algorithm: mean over that app's spaces.
    let app_of_space = |sid: &str| -> Application {
        Application::from_name(sid.split('@').next().unwrap()).unwrap()
    };
    let mut t3 = Table::new(
        "Table 3: non-target vs target scores per application",
        &["Target application", "Non-target mean score", "Target score", "Difference"],
    );
    let mut mean_nt = 0.0;
    let mut mean_t = 0.0;
    let mut rows = 0;
    for app in Application::ALL {
        let space_idx: Vec<usize> = space_ids
            .iter()
            .enumerate()
            .filter(|(_, sid)| app_of_space(sid) == app)
            .map(|(i, _)| i)
            .collect();
        let app_score = |agg: &Aggregate| -> f64 {
            stats::mean(&space_idx.iter().map(|&i| agg.per_space_scores[i]).collect::<Vec<_>>())
        };
        for with_info in [false, true] {
            let label = format!(
                "{}-{}",
                app.name(),
                if with_info { "info" } else { "noinfo" }
            );
            let target = app_score(&results.iter().find(|(l, _, _)| *l == label).unwrap().1);
            // Non-target mean: algorithms targeted at other applications,
            // scored on this application's spaces.
            let nt: Vec<f64> = results
                .iter()
                .filter(|(l, _, _)| !l.starts_with(app.name()))
                .map(|(_, agg, _)| app_score(agg))
                .collect();
            let nt_mean = stats::mean(&nt);
            mean_nt += nt_mean;
            mean_t += target;
            rows += 1;
            t3.row(vec![
                format!(
                    "{} {} extra info",
                    app.name(),
                    if with_info { "with" } else { "without" }
                ),
                f(nt_mean, 3),
                f(target, 3),
                delta(target - nt_mean, 3),
            ]);
        }
    }
    t3.row(vec![
        "Mean".into(),
        f(mean_nt / rows as f64, 3),
        f(mean_t / rows as f64, 3),
        delta((mean_t - mean_nt) / rows as f64, 3),
    ]);
    write(out_dir, "table3.csv", &t3.to_csv());
    write(out_dir, "table3.md", &t3.to_markdown());

    (t2, f7, t3)
}

// ------------------------------------------------------- Figs 8-9

/// Figs 8-9: the two best generated algorithms (paper's HybridVNDX and
/// AdaptiveTabuGreyWolf, our faithful implementations) against the
/// human-designed baselines GA + SA (Kernel Tuner) and DE (pyATF).
pub fn fig8_fig9(opts: &ExpOptions, out_dir: &Path) -> (Table, Table) {
    let names = ["hybrid_vndx", "atgw", "ga", "sa", "de"];
    let factories: Vec<(String, OptimizerSpec)> = names
        .iter()
        .map(|n| (n.to_string(), OptimizerSpec::named(*n)))
        .collect();
    let refs: Vec<(String, &dyn OptimizerFactory)> = factories
        .iter()
        .map(|(l, spec)| (l.clone(), spec as &dyn OptimizerFactory))
        .collect();
    let results = evaluate_on_all_spaces(&refs, opts, opts.seed ^ 0x89, out_dir, "fig8");

    let mut f8 = Table::new(
        "Fig 8: aggregate performance, generated vs human-designed",
        &["Algorithm", "Score P", "± std", "Δ vs GA", "Δ vs SA", "Δ vs DE"],
    );
    let score_of = |n: &str| results.iter().find(|(l, _, _)| l == n).unwrap().1.score;
    let (ga, sa, de) = (score_of("ga"), score_of("sa"), score_of("de"));
    for (label, agg, _) in &results {
        f8.row(vec![
            label.clone(),
            f(agg.score, 3),
            f(agg.score_std, 3),
            delta(agg.score - ga, 3),
            delta(agg.score - sa, 3),
            delta(agg.score - de, 3),
        ]);
    }
    write(out_dir, "fig8.csv", &f8.to_csv());
    write(out_dir, "fig8.md", &f8.to_markdown());

    let space_ids = &results[0].2;
    let mut f9 = Table::new(
        "Fig 9: per-search-space performance, generated vs human-designed",
        &std::iter::once("space")
            .chain(results.iter().map(|(l, _, _)| l.as_str()))
            .collect::<Vec<_>>(),
    );
    for (si, sid) in space_ids.iter().enumerate() {
        let mut row = vec![sid.clone()];
        for (_, agg, _) in &results {
            row.push(f(agg.per_space_scores[si], 3));
        }
        f9.row(row);
    }
    write(out_dir, "fig9.csv", &f9.to_csv());
    write(out_dir, "fig9.md", &f9.to_markdown());

    // Summary JSON for EXPERIMENTS.md automation.
    let mut j = Json::obj();
    for (label, agg, _) in &results {
        let mut o = Json::obj();
        o.set("score", agg.score).set("std", agg.score_std);
        j.set(label, o);
    }
    let avg_gen = (score_of("hybrid_vndx") + score_of("atgw")) / 2.0;
    let avg_human = (ga + sa + de) / 3.0;
    j.set("avg_generated", avg_gen);
    j.set("avg_human", avg_human);
    j.set(
        "improvement_pct",
        if avg_human.abs() > 1e-12 { (avg_gen - avg_human) / avg_human.abs() * 100.0 } else { 0.0 },
    );
    write(out_dir, "fig8_summary.json", &j.to_pretty());

    (f8, f9)
}

// --------------------------------------------------- train/test split view

/// Supplementary: generated-algorithm scores split by train vs test GPUs
/// (the paper's generalization argument in §4.1.2).
pub fn train_test_split(
    generated: &[GeneratedAlgo],
    opts: &ExpOptions,
    out_dir: &Path,
) -> Table {
    require_cached_backend(opts);
    let mut t = Table::new(
        "Generalization: mean score on training GPUs vs held-out GPUs",
        &["Algorithm", "Train-GPU score", "Test-GPU score"],
    );
    let entries = CacheRegistry::global().all_entries();
    for g in generated {
        let spec = OptimizerSpec::genome(g.genome.clone());
        let mut train_scores = Vec::new();
        let mut test_scores = Vec::new();
        for e in entries.iter() {
            let curves = run_many(&e.cache, &e.setup, &spec, opts.runs.min(30), opts.seed ^ 0x77);
            let score = stats::mean(&stats::mean_curve(&curves));
            if TRAIN_GPUS.contains(&e.cache.gpu.name) {
                train_scores.push(score);
            } else if TEST_GPUS.contains(&e.cache.gpu.name) {
                test_scores.push(score);
            }
        }
        t.row(vec![
            g.label(),
            f(stats::mean(&train_scores), 3),
            f(stats::mean(&test_scores), 3),
        ]);
    }
    write(out_dir, "train_test.csv", &t.to_csv());
    write(out_dir, "train_test.md", &t.to_markdown());
    t
}

/// Ensure the GPU list covers the paper's six devices (sanity used by CLI).
pub fn testbed_summary() -> Table {
    let mut t = Table::new(
        "Testbed: the six GPUs (train: MI250X/A100/A4000, test: W6600/W7800/A6000)",
        &["GPU", "Vendor", "SMs", "BW GB/s", "fp32 TFLOPs", "role"],
    );
    for g in ALL_GPUS.iter() {
        let role = if TRAIN_GPUS.contains(&g.name) { "train" } else { "test" };
        t.row(vec![
            g.name.to_string(),
            format!("{:?}", g.vendor),
            g.sm_count.to_string(),
            format!("{}", g.mem_bandwidth_gbs),
            format!("{}", g.fp32_tflops),
            role.to_string(),
        ]);
    }
    t
}

/// Persist generated-genome summaries for reproducibility.
pub fn dump_genomes(generated: &[GeneratedAlgo], out_dir: &Path) {
    let mut s = String::new();
    let mut sorted: BTreeMap<String, &GeneratedAlgo> =
        generated.iter().map(|g| (g.label(), g)).collect();
    for (label, g) in sorted.iter_mut() {
        s.push_str(&format!(
            "## {}\ntrain fitness: {:.3}\nfailures: {}\n{}\n{:#?}\n\n",
            label, g.train_fitness, g.failures, g.genome.summary(), g.genome
        ));
    }
    write(out_dir, "generated_genomes.md", &s);
}
