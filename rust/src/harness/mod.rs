//! Experiment harness regenerating the paper's tables and figures.

pub mod experiments;

pub use experiments::{
    dump_genomes, evaluate_generated, fig5, fig8_fig9, generate_all, table1,
    testbed_summary, train_test_split, BackendKind, ExpOptions, GeneratedAlgo,
};
