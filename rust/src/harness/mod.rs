//! Experiment harness regenerating the paper's tables and figures.
//!
//! Every stage is a job batch against the global
//! [`CacheRegistry`](crate::coordinator::CacheRegistry): the evaluation
//! grids expand through `grid_jobs`, and the generation stage's candidate
//! fitness now batches each LLaMEA generation through the scheduler as
//! one flat job list across the training caches
//! ([`fitness_batch`](crate::llamea::evolution::fitness_batch)).
//! Hyperparameter sweeps over the same registry live in
//! `crate::hypertune` (the `sweep` subcommand).

pub mod experiments;

pub use experiments::{
    dump_genomes, evaluate_generated, fig5, fig8_fig9, generate_all, table1,
    testbed_summary, train_test_split, BackendKind, ExpOptions, GeneratedAlgo,
};
