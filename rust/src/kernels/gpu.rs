//! GPU hardware catalog — the six devices of the paper's testbed.
//!
//! Training set (LLaMEA feedback loop): AMD MI250X, Nvidia A100, Nvidia
//! A4000. Test set (held-out evaluation): AMD W6600, AMD W7800, Nvidia
//! A6000. Specifications are public datasheet values; they parameterize the
//! analytic performance models in this module's siblings, which stand in
//! for the paper's pre-exhaustively-explored cachefiles (DESIGN.md §3).

/// GPU vendor; some model effects are vendor-specific (e.g. the read-only
/// data cache path only exists on Nvidia, wave64 on CDNA).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Vendor {
    Nvidia,
    Amd,
}

/// Datasheet-level device description.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    pub vendor: Vendor,
    /// Streaming multiprocessors (Nvidia) / compute units (AMD).
    pub sm_count: u32,
    /// Hardware scheduling granularity (warp/wavefront).
    pub warp_size: u32,
    pub max_threads_per_block: u32,
    pub max_threads_per_sm: u32,
    /// Shared memory (LDS) capacity per SM, bytes.
    pub shared_mem_per_sm: u32,
    /// Register file per SM (32-bit registers).
    pub regs_per_sm: u32,
    /// Peak DRAM bandwidth, GB/s.
    pub mem_bandwidth_gbs: f64,
    /// Peak fp32 throughput, TFLOP/s.
    pub fp32_tflops: f64,
    /// L2 cache, MiB.
    pub l2_mib: f64,
    /// Fixed kernel-launch overhead, microseconds.
    pub launch_overhead_us: f64,
    /// Mean compile time for one configuration of a typical kernel, s.
    pub compile_time_s: f64,
}

impl GpuSpec {
    pub fn by_name(name: &str) -> Option<&'static GpuSpec> {
        ALL_GPUS.iter().find(|g| g.name.eq_ignore_ascii_case(name))
    }
}

/// The six GPUs of the paper's evaluation.
pub static ALL_GPUS: [GpuSpec; 6] = [
    // ---- training set ----
    GpuSpec {
        name: "MI250X",
        vendor: Vendor::Amd,
        sm_count: 110,
        warp_size: 64,
        max_threads_per_block: 1024,
        max_threads_per_sm: 2048,
        shared_mem_per_sm: 65_536,
        regs_per_sm: 65_536 * 4,
        mem_bandwidth_gbs: 1638.0,
        fp32_tflops: 23.9,
        l2_mib: 8.0,
        launch_overhead_us: 8.0,
        compile_time_s: 4.5,
    },
    GpuSpec {
        name: "A100",
        vendor: Vendor::Nvidia,
        sm_count: 108,
        warp_size: 32,
        max_threads_per_block: 1024,
        max_threads_per_sm: 2048,
        shared_mem_per_sm: 167_936,
        regs_per_sm: 65_536 * 4,
        mem_bandwidth_gbs: 1555.0,
        fp32_tflops: 19.5,
        l2_mib: 40.0,
        launch_overhead_us: 5.0,
        compile_time_s: 3.5,
    },
    GpuSpec {
        name: "A4000",
        vendor: Vendor::Nvidia,
        sm_count: 48,
        warp_size: 32,
        max_threads_per_block: 1024,
        max_threads_per_sm: 1536,
        shared_mem_per_sm: 102_400,
        regs_per_sm: 65_536 * 4,
        mem_bandwidth_gbs: 448.0,
        fp32_tflops: 19.2,
        l2_mib: 4.0,
        launch_overhead_us: 5.0,
        compile_time_s: 3.0,
    },
    // ---- test set ----
    GpuSpec {
        name: "W6600",
        vendor: Vendor::Amd,
        sm_count: 28,
        warp_size: 32,
        max_threads_per_block: 1024,
        max_threads_per_sm: 1024,
        shared_mem_per_sm: 65_536,
        regs_per_sm: 65_536 * 4,
        mem_bandwidth_gbs: 224.0,
        fp32_tflops: 10.4,
        l2_mib: 2.0,
        launch_overhead_us: 9.0,
        compile_time_s: 4.0,
    },
    GpuSpec {
        name: "W7800",
        vendor: Vendor::Amd,
        sm_count: 70,
        warp_size: 32,
        max_threads_per_block: 1024,
        max_threads_per_sm: 2048,
        shared_mem_per_sm: 65_536,
        regs_per_sm: 65_536 * 4,
        mem_bandwidth_gbs: 576.0,
        fp32_tflops: 45.2,
        l2_mib: 64.0,
        launch_overhead_us: 8.0,
        compile_time_s: 4.0,
    },
    GpuSpec {
        name: "A6000",
        vendor: Vendor::Nvidia,
        sm_count: 84,
        warp_size: 32,
        max_threads_per_block: 1024,
        max_threads_per_sm: 1536,
        shared_mem_per_sm: 102_400,
        regs_per_sm: 65_536 * 4,
        mem_bandwidth_gbs: 768.0,
        fp32_tflops: 38.7,
        l2_mib: 6.0,
        launch_overhead_us: 5.0,
        compile_time_s: 3.0,
    },
];

/// Pseudo-device for the *measured* PJRT-CPU tuning path: real wall-clock
/// measurements are attributed to this host instead of a modeled GPU.
pub static CPU_HOST: GpuSpec = GpuSpec {
    name: "CPU-PJRT",
    vendor: Vendor::Nvidia, // unused on the measured path
    sm_count: 1,
    warp_size: 1,
    max_threads_per_block: 1,
    max_threads_per_sm: 1,
    shared_mem_per_sm: 0,
    regs_per_sm: 0,
    mem_bandwidth_gbs: 0.0,
    fp32_tflops: 0.0,
    l2_mib: 0.0,
    launch_overhead_us: 0.0,
    compile_time_s: 0.3,
};

/// Training-set GPU names (generation-stage feedback loop).
pub const TRAIN_GPUS: [&str; 3] = ["MI250X", "A100", "A4000"];
/// Held-out test-set GPU names.
pub const TEST_GPUS: [&str; 3] = ["W6600", "W7800", "A6000"];

/// Occupancy calculation: how many blocks are concurrently resident per SM.
///
/// Limited by threads, shared memory, registers and an optional explicit
/// `blocks_per_sm` cap (the `__launch_bounds__`-style tunable; 0 = off).
pub fn active_blocks_per_sm(
    gpu: &GpuSpec,
    threads_per_block: u32,
    shmem_per_block: u32,
    regs_per_thread: u32,
    blocks_per_sm_cap: u32,
) -> u32 {
    if threads_per_block == 0 || threads_per_block > gpu.max_threads_per_block {
        return 0;
    }
    let by_threads = gpu.max_threads_per_sm / threads_per_block;
    let by_shmem = if shmem_per_block == 0 {
        u32::MAX
    } else if shmem_per_block > gpu.shared_mem_per_sm {
        0
    } else {
        gpu.shared_mem_per_sm / shmem_per_block
    };
    let by_regs = {
        let per_block = regs_per_thread.max(16) * threads_per_block;
        if per_block > gpu.regs_per_sm {
            0
        } else {
            gpu.regs_per_sm / per_block
        }
    };
    let mut blocks = by_threads.min(by_shmem).min(by_regs);
    if blocks_per_sm_cap > 0 {
        blocks = blocks.min(blocks_per_sm_cap);
    }
    blocks
}

/// Occupancy fraction in [0, 1]: resident threads / max threads.
pub fn occupancy_fraction(gpu: &GpuSpec, threads_per_block: u32, blocks: u32) -> f64 {
    ((blocks * threads_per_block) as f64 / gpu.max_threads_per_sm as f64).min(1.0)
}

/// Wave-quantization multiplier: executing `total_blocks` in waves of
/// `sm_count * blocks_per_sm` rounds the tail wave up.
pub fn wave_quantization(gpu: &GpuSpec, total_blocks: u64, blocks_per_sm: u32) -> f64 {
    if total_blocks == 0 || blocks_per_sm == 0 {
        return 1.0;
    }
    let per_wave = (gpu.sm_count as u64 * blocks_per_sm as u64).max(1);
    let waves_exact = total_blocks as f64 / per_wave as f64;
    let waves_ceil = waves_exact.ceil().max(1.0);
    waves_ceil / waves_exact.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a100() -> &'static GpuSpec {
        GpuSpec::by_name("A100").unwrap()
    }

    #[test]
    fn catalog_complete() {
        assert_eq!(ALL_GPUS.len(), 6);
        for n in TRAIN_GPUS.iter().chain(TEST_GPUS.iter()) {
            assert!(GpuSpec::by_name(n).is_some(), "{}", n);
        }
        assert!(GpuSpec::by_name("H100").is_none());
    }

    #[test]
    fn occupancy_limits() {
        let g = a100();
        // Thread-limited: 256-thread blocks, no other pressure.
        assert_eq!(active_blocks_per_sm(g, 256, 0, 32, 0), 8);
        // Shared-memory limited.
        assert_eq!(active_blocks_per_sm(g, 64, 84_000, 32, 0), 1);
        // Explicit cap wins.
        assert_eq!(active_blocks_per_sm(g, 64, 0, 16, 2), 2);
        // Oversized block -> zero.
        assert_eq!(active_blocks_per_sm(g, 2048, 0, 32, 0), 0);
        // Shared overflow -> zero.
        assert_eq!(active_blocks_per_sm(g, 64, 200_000, 32, 0), 0);
    }

    #[test]
    fn occupancy_fraction_bounds() {
        let g = a100();
        let f = occupancy_fraction(g, 256, 8);
        assert!((f - 1.0).abs() < 1e-9);
        assert!(occupancy_fraction(g, 32, 1) < 0.05);
    }

    #[test]
    fn wave_quantization_tail() {
        let g = a100(); // 108 SMs
        // Exactly one wave -> 1.0.
        assert!((wave_quantization(g, 108, 1) - 1.0).abs() < 1e-9);
        // One extra block costs a whole second wave.
        assert!(wave_quantization(g, 109, 1) > 1.9);
        // Large grids amortize.
        assert!(wave_quantization(g, 108 * 100 + 1, 1) < 1.02);
    }
}
