//! 2D convolution performance model (compute-bound, 15x15 filter).
//!
//! Workload: 4096x4096 image, 15x15 filter => 7.55 GFLOP. The dominant
//! effects are register-tiling ILP (tile_size_x/y), the shared-memory vs
//! cache path (use_shmem / use_padding), the Nvidia-only read-only data
//! cache (`read_only` is inert on AMD — a real cross-vendor effect the
//! generated optimizers must cope with), and vectorized loads.

use super::gpu::{self, GpuSpec, Vendor};
use super::KernelModel;
use crate::searchspace::{Application, ParamSet};

const W: f64 = 4096.0;
const H: f64 = 4096.0;
const FW: f64 = 15.0;
const FH: f64 = 15.0;

pub struct ConvolutionModel {
    d_bsx: usize,
    d_bsy: usize,
    d_tsx: usize,
    d_tsy: usize,
    d_pad: usize,
    d_ro: usize,
    d_shmem: usize,
    d_vec: usize,
}

impl ConvolutionModel {
    pub fn new(params: &ParamSet) -> Self {
        ConvolutionModel {
            d_bsx: super::dim(params, "block_size_x"),
            d_bsy: super::dim(params, "block_size_y"),
            d_tsx: super::dim(params, "tile_size_x"),
            d_tsy: super::dim(params, "tile_size_y"),
            d_pad: super::dim(params, "use_padding"),
            d_ro: super::dim(params, "read_only"),
            d_shmem: super::dim(params, "use_shmem"),
            d_vec: super::dim(params, "vector"),
        }
    }
}

impl KernelModel for ConvolutionModel {
    fn application(&self) -> Application {
        Application::Convolution
    }

    fn workload_flops(&self) -> f64 {
        2.0 * W * H * FW * FH
    }

    fn workload_bytes(&self) -> f64 {
        2.0 * W * H * 4.0 // read image once, write output once (ideal)
    }

    fn runtime_ms(&self, vals: &[f64], gpu: &GpuSpec, salt: u64) -> Option<f64> {
        let bsx = vals[self.d_bsx];
        let bsy = vals[self.d_bsy];
        let tsx = vals[self.d_tsx];
        let tsy = vals[self.d_tsy];
        let pad = vals[self.d_pad] > 0.5;
        let read_only = vals[self.d_ro] > 0.5;
        let shmem = vals[self.d_shmem] > 0.5;
        let vec = vals[self.d_vec];

        if super::hidden_failure(salt, vals, 0.02) {
            return None;
        }

        let threads = (bsx * bsy) as u32;
        let tile_w = bsx * tsx;
        let tile_h = bsy * tsy;
        let shmem_bytes = if shmem {
            let padded_w = tile_w + FW - 1.0 + if pad { 1.0 } else { 0.0 };
            ((padded_w * (tile_h + FH - 1.0)) * 4.0) as u32
        } else {
            0
        };
        let regs = (24.0 + 2.2 * tsx * tsy + vec) as u32;
        let blocks = gpu::active_blocks_per_sm(gpu, threads, shmem_bytes, regs, 0);
        if blocks == 0 {
            return None;
        }
        let occ = gpu::occupancy_fraction(gpu, threads, blocks);

        // --- Compute path (dominant) ---
        // Register tiling: ILP grows with the per-thread tile until register
        // pressure bites (sweet spot ~6 elements/thread).
        let ilp = super::unroll_efficiency(tsx * tsy, 6.0);
        let comp_eff = super::compute_utilization(occ) * ilp * 0.95;
        let comp_time_s = self.workload_flops() / (gpu.fp32_tflops * 1e12 * comp_eff);

        // --- Memory path ---
        // Without shared memory every thread pulls its halo through the
        // cache hierarchy; the read-only cache (Nvidia) and L2 absorb most
        // but not all of the 225x amplification.
        let cache_hit = if shmem {
            0.995
        } else {
            let ro_bonus = if read_only && gpu.vendor == Vendor::Nvidia {
                0.02
            } else {
                0.0
            };
            0.955 + ro_bonus + 0.015 * (gpu.l2_mib / 40.0).min(1.0)
        };
        let amplification = 1.0 + (FW * FH - 1.0) * (1.0 - cache_hit);
        // Halo overlap between adjacent tiles re-reads border pixels.
        let halo_factor = (tile_w + FW - 1.0) * (tile_h + FH - 1.0) / (tile_w * tile_h);
        let bytes = W * H * 4.0 * (amplification * halo_factor + 1.0);

        // Bank conflicts on the shared-memory path when the tile width hits
        // the 32-bank stride; padding removes them.
        let bank_penalty = if shmem && !pad && (bsx as i64) % 32 == 0 {
            1.22
        } else {
            1.0
        };
        let vec_eff = if vec > 1.5 {
            match gpu.vendor {
                Vendor::Amd => 1.12, // wide loads help GCN/RDNA more
                Vendor::Nvidia => 1.04,
            }
        } else {
            1.0
        };
        let bw = gpu.mem_bandwidth_gbs * 1e9 * super::bandwidth_utilization(occ) * vec_eff
            / bank_penalty;
        let mem_time_s = bytes / bw;

        let total_blocks = ((W / tile_w).ceil() * (H / tile_h).ceil()) as u64;
        let wave = gpu::wave_quantization(gpu, total_blocks, blocks);

        let t_s = comp_time_s.max(mem_time_s) * wave * super::rugged(salt, vals, 0.45)
            + gpu.launch_overhead_us * 1e-6;
        Some(t_s * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::space_salt;
    use crate::searchspace::builder::build_convolution;

    fn best_ms(gpu_name: &str) -> f64 {
        let space = build_convolution();
        let model = ConvolutionModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name(gpu_name).unwrap();
        let salt = space_salt(Application::Convolution, gpu);
        space
            .iter_indices()
            .filter_map(|i| model.runtime_ms(&space.values_f64(i), gpu, salt))
            .fold(f64::INFINITY, f64::min)
    }

    #[test]
    fn compute_bound_near_roofline() {
        let space = build_convolution();
        let model = ConvolutionModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name("A100").unwrap();
        // Pure-compute roofline: 7.55 GFLOP / 19.5 TFLOPs = 0.39 ms.
        let roofline_ms = model.workload_flops() / (gpu.fp32_tflops * 1e12) * 1e3;
        let best = best_ms("A100");
        assert!(best > roofline_ms, "cannot beat the roofline");
        assert!(best < roofline_ms * 3.0, "best {} vs roofline {}", best, roofline_ms);
    }

    #[test]
    fn faster_gpu_is_faster() {
        assert!(best_ms("A100") < best_ms("W6600"));
    }

    #[test]
    fn read_only_cache_matters_only_on_nvidia() {
        let space = build_convolution();
        let model = ConvolutionModel::new(&space.params);
        let nv = gpu::GpuSpec::by_name("A6000").unwrap();
        let amd = gpu::GpuSpec::by_name("W7800").unwrap();
        // Find a valid config pair differing only in read_only with shmem=0.
        let d_ro = space.params.index_of("read_only").unwrap();
        let d_sh = space.params.index_of("use_shmem").unwrap();
        let mut tested = 0;
        for i in space.iter_indices() {
            let cfg = space.config(i);
            if cfg[d_ro] == 1 && cfg[d_sh] == 0 {
                let mut other = cfg.to_vec();
                other[d_ro] = 0;
                if let Some(j) = space.index_of(&other) {
                    // Compare deterministic parts (strip rugged noise by
                    // comparing the ratio across vendors).
                    let vi = space.values_f64(i);
                    let vj = space.values_f64(j);
                    let salt = 0; // fixed salt isolates the effect
                    let (a, b) = (
                        model.runtime_ms(&vi, nv, salt),
                        model.runtime_ms(&vj, nv, salt),
                    );
                    let (c, d) = (
                        model.runtime_ms(&vi, amd, salt),
                        model.runtime_ms(&vj, amd, salt),
                    );
                    if let (Some(_a), Some(_b), Some(c), Some(d)) = (a, b, c, d) {
                        // On AMD the two configs differ only by the rugged
                        // term; the deterministic parts are equal because
                        // read_only is inert. Verify by ratio stability.
                        let amd_ratio = c / d;
                        assert!(
                            (amd_ratio - (super::super::rugged(salt, &vi, 0.35)
                                / super::super::rugged(salt, &vj, 0.35)))
                                .abs()
                                < 0.25,
                        );
                        tested += 1;
                        if tested > 5 {
                            break;
                        }
                    }
                }
            }
        }
        assert!(tested > 0);
    }

    #[test]
    fn bank_conflict_penalty_visible() {
        let space = build_convolution();
        let model = ConvolutionModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name("A4000").unwrap();
        // With shmem on and bsx % 32 == 0, padding should help (modulo the
        // rugged term, so compare model internals via a crafted case).
        let d_pad = space.params.index_of("use_padding").unwrap();
        let d_sh = space.params.index_of("use_shmem").unwrap();
        let d_bsx = space.params.index_of("block_size_x").unwrap();
        let mut wins = 0;
        let mut total = 0;
        for i in space.iter_indices() {
            let cfg = space.config(i);
            let bsx = space.params.value_f64(d_bsx, cfg[d_bsx]);
            if cfg[d_sh] == 1 && cfg[d_pad] == 0 && (bsx as i64) % 32 == 0 {
                let mut other = cfg.to_vec();
                other[d_pad] = 1;
                // use_padding requires bsx % 32 != 0 in the constraints, so
                // the padded twin is invalid here; instead verify the
                // penalty directly on the model output distribution.
                assert!(space.index_of(&other).is_none());
                total += 1;
                let t = model.runtime_ms(&space.values_f64(i), gpu, 0);
                if t.is_some() {
                    wins += 1;
                }
            }
            if total > 20 {
                break;
            }
        }
        assert!(wins > 0);
    }
}
