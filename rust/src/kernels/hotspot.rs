//! Hotspot performance model (thermal stencil with temporal tiling).
//!
//! Workload: 1024x1024 grid, 1000 simulation steps. The central trade-off
//! is temporal tiling: fusing `temporal_tiling_factor` steps per launch
//! divides the DRAM traffic and launch count by that factor, but the halo
//! grows with it, inflating redundant compute quadratically — so the
//! optimal factor depends on the device's bandwidth/compute ratio, which is
//! why different GPUs prefer different configurations.

use super::gpu::{self, GpuSpec, Vendor};
use super::KernelModel;
use crate::searchspace::{Application, ParamSet};

const GRID: f64 = 1024.0;
const STEPS: f64 = 1000.0;
const FLOPS_PER_CELL: f64 = 12.0;

pub struct HotspotModel {
    d_bsx: usize,
    d_bsy: usize,
    d_tsx: usize,
    d_tsy: usize,
    d_tt: usize,
    d_unroll_t: usize,
    d_shp: usize,
    d_bpsm: usize,
    d_vec: usize,
    d_reorder: usize,
    d_dbuf: usize,
}

impl HotspotModel {
    pub fn new(params: &ParamSet) -> Self {
        HotspotModel {
            d_bsx: super::dim(params, "block_size_x"),
            d_bsy: super::dim(params, "block_size_y"),
            d_tsx: super::dim(params, "tile_size_x"),
            d_tsy: super::dim(params, "tile_size_y"),
            d_tt: super::dim(params, "temporal_tiling_factor"),
            d_unroll_t: super::dim(params, "loop_unroll_factor_t"),
            d_shp: super::dim(params, "sh_power"),
            d_bpsm: super::dim(params, "blocks_per_sm"),
            d_vec: super::dim(params, "vector"),
            d_reorder: super::dim(params, "reorder"),
            d_dbuf: super::dim(params, "double_buffer"),
        }
    }
}

impl KernelModel for HotspotModel {
    fn application(&self) -> Application {
        Application::Hotspot
    }

    fn workload_flops(&self) -> f64 {
        GRID * GRID * STEPS * FLOPS_PER_CELL
    }

    fn workload_bytes(&self) -> f64 {
        // Per step: read temp+power, write temp (ideal temporal locality).
        3.0 * GRID * GRID * 4.0 * STEPS
    }

    fn runtime_ms(&self, vals: &[f64], gpu: &GpuSpec, salt: u64) -> Option<f64> {
        let bsx = vals[self.d_bsx];
        let bsy = vals[self.d_bsy];
        let tsx = vals[self.d_tsx];
        let tsy = vals[self.d_tsy];
        let tt = vals[self.d_tt];
        let unroll_t = vals[self.d_unroll_t];
        let sh_power = vals[self.d_shp] > 0.5;
        let bpsm_cap = vals[self.d_bpsm] as u32;
        let vec = vals[self.d_vec];
        let reorder = vals[self.d_reorder] > 0.5;
        let dbuf = vals[self.d_dbuf] > 0.5;

        if super::hidden_failure(salt, vals, 0.02) {
            return None;
        }

        let threads = (bsx * bsy) as u32;
        let tile_w = bsx * tsx;
        let tile_h = bsy * tsy;
        let halo = 2.0 * tt;
        // Shared tile: temperature (+ power when sh_power), double-buffered
        // temperature when requested.
        let shmem_cells = (tile_w + halo) * (tile_h + halo);
        let shmem_bytes = (shmem_cells
            * 4.0
            * (1.0 + sh_power as u8 as f64 + dbuf as u8 as f64)) as u32;
        let regs = (26.0 + 2.0 * tsx * tsy + 1.5 * unroll_t + vec) as u32;
        let blocks = gpu::active_blocks_per_sm(gpu, threads, shmem_bytes, regs, bpsm_cap);
        if blocks == 0 {
            return None;
        }
        let occ = gpu::occupancy_fraction(gpu, threads, blocks);

        let launches = (STEPS / tt).ceil();

        // --- Memory per launch ---
        // Read temp + power (with halo amplification), write temp; sh_power
        // avoids re-reading power every fused step.
        let halo_amp = (tile_w + halo) * (tile_h + halo) / (tile_w * tile_h);
        let power_reads = if sh_power { 1.0 } else { tt };
        let bytes_per_launch =
            GRID * GRID * 4.0 * (halo_amp * (1.0 + power_reads / tt.max(1.0)) + 1.0);
        let coalesce = super::coalescing_efficiency(tile_w, gpu.warp_size as f64);
        let reorder_eff = if reorder { 1.04 } else { 1.0 };
        let vec_eff = if vec > 1.5 {
            match gpu.vendor {
                Vendor::Amd => 1.08,
                Vendor::Nvidia => 1.03,
            }
        } else {
            1.0
        };
        let bw = gpu.mem_bandwidth_gbs * 1e9
            * super::bandwidth_utilization(occ)
            * coalesce
            * reorder_eff
            * vec_eff;
        let mem_time_s = bytes_per_launch / bw;

        // --- Compute per launch ---
        // Redundant halo compute: each fused step s computes the tile plus
        // a shrinking halo; approximation via the mean inflation factor.
        let inflation = {
            let grow = (tile_w + halo) * (tile_h + halo) / (tile_w * tile_h);
            1.0 + (grow - 1.0) * 0.5
        };
        let unroll_eff = super::unroll_efficiency(unroll_t, 2.0);
        let dbuf_eff = if dbuf { 1.05 } else { 1.0 };
        let comp_eff = super::compute_utilization(occ) * unroll_eff * dbuf_eff * 0.92;
        let flops_per_launch = GRID * GRID * tt * FLOPS_PER_CELL * inflation;
        let comp_time_s = flops_per_launch / (gpu.fp32_tflops * 1e12 * comp_eff);

        let total_blocks = ((GRID / tile_w).ceil() * (GRID / tile_h).ceil()) as u64;
        let wave = gpu::wave_quantization(gpu, total_blocks, blocks);

        let per_launch_s =
            mem_time_s.max(comp_time_s) * wave + gpu.launch_overhead_us * 1e-6;
        let t_s = launches * per_launch_s * super::rugged(salt, vals, 0.35);
        Some(t_s * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::space_salt;
    use crate::searchspace::builder::build_hotspot;

    #[test]
    fn sampled_configs_sane() {
        let space = build_hotspot();
        let model = HotspotModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name("A100").unwrap();
        let salt = space_salt(Application::Hotspot, gpu);
        let mut ok = 0;
        let mut n = 0;
        for i in space.iter_indices().step_by(97) {
            n += 1;
            if let Some(t) = model.runtime_ms(&space.values_f64(i), gpu, salt) {
                assert!(t > 0.5 && t < 1e6, "t={}", t);
                ok += 1;
            }
        }
        assert!(ok as f64 > 0.85 * n as f64);
    }

    #[test]
    fn temporal_tiling_has_an_interior_optimum_somewhere() {
        // On a bandwidth-starved device (W6600) larger temporal tiling must
        // help relative to tt=1 for at least some configurations.
        let space = build_hotspot();
        let model = HotspotModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name("W6600").unwrap();
        let d_tt = space.params.index_of("temporal_tiling_factor").unwrap();
        let mut best_by_tt: std::collections::HashMap<u16, f64> = Default::default();
        for i in space.iter_indices().step_by(31) {
            if let Some(t) = model.runtime_ms(&space.values_f64(i), gpu, 0) {
                let tt = space.config(i)[d_tt];
                let e = best_by_tt.entry(tt).or_insert(f64::INFINITY);
                *e = e.min(t);
            }
        }
        let t1 = best_by_tt[&0]; // tt = 1
        let better = best_by_tt.iter().any(|(&tt, &t)| tt > 0 && t < t1);
        assert!(better, "temporal tiling never helps: {:?}", best_by_tt);
    }

    #[test]
    fn launch_overhead_visible_at_high_launch_count() {
        // tt=1 => 1000 launches; overhead must be a visible fraction.
        let gpu = gpu::GpuSpec::by_name("MI250X").unwrap();
        let overhead_ms = 1000.0 * gpu.launch_overhead_us * 1e-3;
        assert!(overhead_ms > 5.0);
    }
}
