//! Analytic GPU performance models for the four benchmark kernels.
//!
//! These stand in for the paper's pre-exhaustively-explored cachefiles
//! (DESIGN.md §3): for every valid configuration they produce a plausible
//! mean runtime on a given [`gpu::GpuSpec`], built from first-principles
//! components (occupancy, roofline bandwidth/compute balance, tiling reuse,
//! vectorization and unrolling efficiencies, wave quantization) plus a
//! deterministic hash-keyed rugged term that reproduces the irregular,
//! multi-modal structure real auto-tuning spaces exhibit (Willemsen et al.
//! 2025a). Bandwidth-bound (dedispersion, hotspot) vs compute-bound
//! (convolution, GEMM) character follows the paper's §4.1.1.

pub mod convolution;
pub mod dedispersion;
pub mod gemm;
pub mod gpu;
pub mod hotspot;

use crate::searchspace::{Application, ParamSet};
use crate::util::rng::{hash_config, hash_normal};
use gpu::GpuSpec;

/// A kernel performance model bound to a parameter set (dims resolved).
pub trait KernelModel: Send + Sync {
    fn application(&self) -> Application;

    /// Mean runtime in milliseconds of one configuration on `gpu`.
    ///
    /// `vals` are the configuration's numeric parameter values (by
    /// dimension); `salt` keys the deterministic rugged term (unique per
    /// (kernel, GPU) pair). Returns `None` for *hidden-constraint* failures
    /// — configurations that pass the static constraints but fail at
    /// compile/run time (BaCO-style), which the paper's methodology treats
    /// as wasted evaluations.
    fn runtime_ms(&self, vals: &[f64], gpu: &GpuSpec, salt: u64) -> Option<f64>;

    /// Total useful FLOPs of the workload (for roofline reporting).
    fn workload_flops(&self) -> f64;
    /// Minimal DRAM traffic of the workload in bytes (roofline).
    fn workload_bytes(&self) -> f64;
}

/// Construct the model for an application, resolving dims against `params`.
pub fn model_for(app: Application, params: &ParamSet) -> Box<dyn KernelModel> {
    match app {
        Application::Dedispersion => Box::new(dedispersion::DedispersionModel::new(params)),
        Application::Convolution => Box::new(convolution::ConvolutionModel::new(params)),
        Application::Hotspot => Box::new(hotspot::HotspotModel::new(params)),
        Application::Gemm => Box::new(gemm::GemmModel::new(params)),
    }
}

/// Salt for the rugged/noise terms of a (kernel, GPU) pair.
pub fn space_salt(app: Application, gpu: &GpuSpec) -> u64 {
    crate::util::rng::fnv1a(format!("{}::{}", app.name(), gpu.name).as_bytes())
}

/// Revision counter of the performance-model family. Bump whenever any
/// model formula, shared component, GPU spec constant, or noise stream
/// changes the values a [`KernelModel`] (or the simulated compile times)
/// can produce — the persistent cache store (`crate::persist`) folds this
/// into its build fingerprint, so bumping it invalidates every stored
/// cache instead of silently replaying outputs of the old models.
pub const MODEL_REVISION: u32 = 1;

// ----------------------------------------------------------------------
// Shared model components
// ----------------------------------------------------------------------

/// Resolve a parameter name to its dimension, panicking with context —
/// models are always paired with the space builder that defines the names.
pub(crate) fn dim(params: &ParamSet, name: &str) -> usize {
    params
        .index_of(name)
        .unwrap_or_else(|| panic!("model expects parameter '{}'", name))
}

/// Deterministic multiplicative rugged-terrain term, a half-normal penalty
/// in [1, inf).
///
/// Keyed by (salt, quantized values) so the same configuration always maps
/// to the same multiplier — this is what makes the simulated spaces
/// *irregular* rather than smooth, without breaking reproducibility. It is
/// one-sided (a slowdown) so no configuration can beat the analytic
/// roofline of its own formula; the tuned optimum stays physical.
pub(crate) fn rugged(salt: u64, vals: &[f64], sigma: f64) -> f64 {
    // Separable per-dimension penalties: each (dimension, value) pair draws
    // a fixed half-normal penalty, so configurations combining the good
    // value in *every* dimension are exponentially rare under random
    // sampling, yet coordinate moves (the neighbor operations) can descend
    // to them — matching how real tuning spaces reward local search.
    let mut acc = 0.0;
    for (d, &v) in vals.iter().enumerate() {
        let h = hash_config(
            salt ^ (d as u64 + 1).wrapping_mul(0xA24BAED4963EE407),
            &[(v as i64 & 0xffff) as u16],
        );
        acc += hash_normal(h).abs();
    }
    let separable = acc / vals.len() as f64;
    // Non-separable residual: interactions / irregularity.
    let q: Vec<u16> = vals.iter().map(|&v| (v as i64 & 0xffff) as u16).collect();
    let residual = hash_normal(hash_config(salt, &q)).abs();
    (sigma * (1.4 * separable + 0.5 * residual)).exp()
}

/// Deterministic hidden-failure test: ~`rate` of configurations crash at
/// run time even though they satisfy all static constraints.
pub(crate) fn hidden_failure(salt: u64, vals: &[f64], rate: f64) -> bool {
    let q: Vec<u16> = vals.iter().map(|&v| (v as i64 & 0xffff) as u16).collect();
    let h = hash_config(salt ^ 0xDEAD_BEEF, &q);
    ((h >> 16) as f64 / (1u64 << 48) as f64) < rate
}

/// Loop-unroll efficiency: log-space Gaussian around a hardware-dependent
/// sweet spot; `unroll == 0` (compiler-chosen) gets a solid default.
pub(crate) fn unroll_efficiency(unroll: f64, optimal: f64) -> f64 {
    if unroll <= 0.0 {
        return 0.88;
    }
    let d = (unroll.ln() - optimal.ln()) / 0.8;
    0.55 + 0.45 * (-0.5 * d * d).exp()
}

/// Memory-coalescing efficiency of a block whose fastest-moving extent is
/// `width` lanes on a device with `warp` scheduling granularity.
pub(crate) fn coalescing_efficiency(width: f64, warp: f64) -> f64 {
    if width >= warp {
        0.97
    } else {
        // Partially-filled transactions.
        0.35 + 0.62 * (width / warp)
    }
}

/// Occupancy-to-achieved-bandwidth curve: DRAM saturates around 40%
/// occupancy; below that, latency hiding fails roughly linearly.
pub(crate) fn bandwidth_utilization(occupancy: f64) -> f64 {
    (occupancy / 0.40).min(1.0) * 0.92 + 0.03
}

/// Occupancy-to-achieved-compute curve: ALUs saturate around 50%.
pub(crate) fn compute_utilization(occupancy: f64) -> f64 {
    (occupancy / 0.50).min(1.0) * 0.90 + 0.05
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rugged_is_deterministic_one_sided_penalty() {
        let vals = [4.0, 8.0, 1.0];
        assert_eq!(rugged(1, &vals, 0.1), rugged(1, &vals, 0.1));
        assert_ne!(rugged(1, &vals, 0.1), rugged(2, &vals, 0.1));
        // Always a slowdown; mean log-penalty follows the half-normal
        // composition: sigma * (1.4 + 0.5) * E[|z|], E[|z|] ~ 0.798.
        let mut sum = 0.0;
        for i in 0..10_000 {
            let r = rugged(7, &[i as f64, (i * 3) as f64], 0.15);
            assert!(r >= 1.0);
            sum += r.ln();
        }
        let mean_ln = sum / 10_000.0;
        assert!((mean_ln - 0.15 * 1.9 * 0.798).abs() < 0.03, "{}", mean_ln);
    }

    #[test]
    fn hidden_failure_rate_close_to_target() {
        let mut fails = 0;
        let n = 50_000;
        for i in 0..n {
            if hidden_failure(3, &[i as f64, (i * 7 + 1) as f64], 0.02) {
                fails += 1;
            }
        }
        let rate = fails as f64 / n as f64;
        assert!((rate - 0.02).abs() < 0.005, "rate {}", rate);
    }

    #[test]
    fn unroll_sweet_spot() {
        let at_opt = unroll_efficiency(8.0, 8.0);
        assert!(at_opt > unroll_efficiency(1.0, 8.0));
        assert!(at_opt > unroll_efficiency(32.0, 8.0));
        assert!(unroll_efficiency(0.0, 8.0) > 0.85);
    }

    #[test]
    fn utilization_curves_monotone() {
        assert!(bandwidth_utilization(0.1) < bandwidth_utilization(0.4));
        assert!((bandwidth_utilization(0.4) - bandwidth_utilization(1.0)).abs() < 1e-9);
        assert!(compute_utilization(0.2) < compute_utilization(0.5));
        assert!(coalescing_efficiency(8.0, 32.0) < coalescing_efficiency(32.0, 32.0));
    }
}
