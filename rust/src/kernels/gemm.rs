//! GEMM performance model (compute-bound, CLBlast xgemm, 4096^3).
//!
//! The classic GPU GEMM trade-offs: workgroup tile (MWG x NWG) sets the
//! DRAM reuse factor; per-thread register tile ((MWG/MDIMC) x (NWG/NDIMC))
//! sets ILP vs register pressure; staging A/B through shared memory (SA/SB)
//! trades LDS capacity for cache pressure; vector widths must match the
//! device's load granularity; and the reshaping between compute and load
//! thread layouts (MDIMA/NDIMB vs MDIMC/NDIMC) costs shuffles.

use super::gpu::{self, GpuSpec, Vendor};
use super::KernelModel;
use crate::searchspace::{Application, ParamSet};

const M: f64 = 4096.0;
const N: f64 = 4096.0;
const K: f64 = 4096.0;

pub struct GemmModel {
    d_mwg: usize,
    d_nwg: usize,
    d_kwg: usize,
    d_mdimc: usize,
    d_ndimc: usize,
    d_mdima: usize,
    d_ndimb: usize,
    d_kwi: usize,
    d_vwm: usize,
    d_vwn: usize,
    d_strm: usize,
    d_strn: usize,
    d_sa: usize,
    d_sb: usize,
}

impl GemmModel {
    pub fn new(params: &ParamSet) -> Self {
        GemmModel {
            d_mwg: super::dim(params, "MWG"),
            d_nwg: super::dim(params, "NWG"),
            d_kwg: super::dim(params, "KWG"),
            d_mdimc: super::dim(params, "MDIMC"),
            d_ndimc: super::dim(params, "NDIMC"),
            d_mdima: super::dim(params, "MDIMA"),
            d_ndimb: super::dim(params, "NDIMB"),
            d_kwi: super::dim(params, "KWI"),
            d_vwm: super::dim(params, "VWM"),
            d_vwn: super::dim(params, "VWN"),
            d_strm: super::dim(params, "STRM"),
            d_strn: super::dim(params, "STRN"),
            d_sa: super::dim(params, "SA"),
            d_sb: super::dim(params, "SB"),
        }
    }
}

impl KernelModel for GemmModel {
    fn application(&self) -> Application {
        Application::Gemm
    }

    fn workload_flops(&self) -> f64 {
        2.0 * M * N * K
    }

    fn workload_bytes(&self) -> f64 {
        (M * K + K * N + 2.0 * M * N) * 4.0
    }

    fn runtime_ms(&self, vals: &[f64], gpu: &GpuSpec, salt: u64) -> Option<f64> {
        let mwg = vals[self.d_mwg];
        let nwg = vals[self.d_nwg];
        let kwg = vals[self.d_kwg];
        let mdimc = vals[self.d_mdimc];
        let ndimc = vals[self.d_ndimc];
        let mdima = vals[self.d_mdima];
        let ndimb = vals[self.d_ndimb];
        let kwi = vals[self.d_kwi];
        let vwm = vals[self.d_vwm];
        let vwn = vals[self.d_vwn];
        let strm = vals[self.d_strm] > 0.5;
        let strn = vals[self.d_strn] > 0.5;
        let sa = vals[self.d_sa] > 0.5;
        let sb = vals[self.d_sb] > 0.5;

        if super::hidden_failure(salt, vals, 0.025) {
            return None;
        }

        let threads = (mdimc * ndimc) as u32;
        let shmem_bytes = (((if sa { mwg * kwg } else { 0.0 })
            + (if sb { kwg * nwg } else { 0.0 }))
            * 4.0) as u32;
        // Register tile per thread.
        let rt_m = mwg / mdimc;
        let rt_n = nwg / ndimc;
        let regs = (20.0 + 1.6 * rt_m * rt_n + 2.0 * (vwm + vwn) + 2.0 * kwi) as u32;
        let blocks = gpu::active_blocks_per_sm(gpu, threads, shmem_bytes, regs, 0);
        if blocks == 0 {
            return None;
        }
        let occ = gpu::occupancy_fraction(gpu, threads, blocks);

        // --- Compute efficiency ---
        // Per-thread register tile: ILP sweet spot near 8x8 = 64 MACs.
        let ilp = super::unroll_efficiency(rt_m * rt_n, 48.0);
        // KWI unroll: deeper k-unroll helps ILP slightly.
        let kwi_eff = if kwi >= 8.0 { 1.03 } else { 1.0 };
        // Layout remap shuffle cost when the load layout differs from the
        // compute layout.
        let remap = 1.0
            - 0.02 * ((mdima != mdimc) as u8 as f64)
            - 0.02 * ((ndimb != ndimc) as u8 as f64);
        // Vector width match: the device load granularity is 16 B.
        let vec_target: f64 = 4.0;
        let vec_eff = |v: f64| -> f64 {
            let d = (v.ln() - vec_target.ln()).abs() / std::f64::consts::LN_2;
            0.94 + 0.06 * (-0.5 * d * d).exp()
        };
        // Strided access helps coalescing of vector loads on Nvidia.
        let stride_eff = match gpu.vendor {
            Vendor::Nvidia => 1.0 + 0.01 * (strm as u8 as f64) + 0.01 * (strn as u8 as f64),
            Vendor::Amd => 1.0 - 0.005 * (strm as u8 as f64) - 0.005 * (strn as u8 as f64),
        };
        let comp_eff = super::compute_utilization(occ)
            * ilp
            * kwi_eff
            * remap
            * vec_eff(vwm)
            * vec_eff(vwn)
            * stride_eff
            * 0.93;
        let comp_time_s = self.workload_flops() / (gpu.fp32_tflops * 1e12 * comp_eff);

        // --- Memory traffic ---
        // A is read N/NWG times, B is read M/MWG times; shared-memory
        // staging (SA/SB) makes the reuse perfect within a tile, otherwise
        // the cache path leaks a fraction of the reuse.
        let a_reuse_leak = if sa { 1.0 } else { 1.8 };
        let b_reuse_leak = if sb { 1.0 } else { 1.8 };
        let bytes = (M * K * (N / nwg) * a_reuse_leak + K * N * (M / mwg) * b_reuse_leak
            + 2.0 * M * N)
            * 4.0;
        let bw = gpu.mem_bandwidth_gbs * 1e9 * super::bandwidth_utilization(occ);
        let mem_time_s = bytes / bw;

        let total_blocks = ((M / mwg).ceil() * (N / nwg).ceil()) as u64;
        let wave = gpu::wave_quantization(gpu, total_blocks, blocks);

        let t_s = comp_time_s.max(mem_time_s) * wave * super::rugged(salt, vals, 0.40)
            + gpu.launch_overhead_us * 1e-6;
        Some(t_s * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::space_salt;
    use crate::searchspace::builder::build_gemm;

    #[test]
    fn best_hits_reasonable_mxu_fraction() {
        let space = build_gemm();
        let model = GemmModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name("A100").unwrap();
        let salt = space_salt(Application::Gemm, gpu);
        let best = space
            .iter_indices()
            .filter_map(|i| model.runtime_ms(&space.values_f64(i), gpu, salt))
            .fold(f64::INFINITY, f64::min);
        let roofline_ms = model.workload_flops() / (gpu.fp32_tflops * 1e12) * 1e3;
        let efficiency = roofline_ms / best;
        // Tuned GEMM reaches 50-90% of peak.
        assert!(efficiency > 0.5 && efficiency < 0.95, "eff {}", efficiency);
    }

    #[test]
    fn shared_memory_staging_generally_helps() {
        let space = build_gemm();
        let model = GemmModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name("A4000").unwrap();
        let d_sa = space.params.index_of("SA").unwrap();
        let (mut with, mut without) = (Vec::new(), Vec::new());
        for i in space.iter_indices().step_by(17) {
            if let Some(t) = model.runtime_ms(&space.values_f64(i), gpu, 0) {
                if space.config(i)[d_sa] == 1 {
                    with.push(t);
                } else {
                    without.push(t);
                }
            }
        }
        let m_with = crate::util::stats::median(&with);
        let m_without = crate::util::stats::median(&without);
        assert!(m_with < m_without, "{} vs {}", m_with, m_without);
    }

    #[test]
    fn occupancy_zero_configs_fail() {
        // A config that requests more shared memory than any device has
        // should be rejected by the occupancy calculation. MWG=NWG=128 with
        // SA=SB=1, KWG=32 -> (128*32 + 32*128)*4 = 32 KiB ok; our spaces
        // never overflow, so instead verify the plumbing directly.
        let gpu = gpu::GpuSpec::by_name("W6600").unwrap();
        assert_eq!(gpu::active_blocks_per_sm(gpu, 64, 100_000, 32, 0), 0);
    }

    #[test]
    fn compute_bound_everywhere_sensible() {
        let space = build_gemm();
        let model = GemmModel::new(&space.params);
        for name in ["A100", "A6000", "MI250X"] {
            let gpu = gpu::GpuSpec::by_name(name).unwrap();
            let salt = space_salt(Application::Gemm, gpu);
            let mut times: Vec<f64> = space
                .iter_indices()
                .step_by(7)
                .filter_map(|i| model.runtime_ms(&space.values_f64(i), gpu, salt))
                .collect();
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let spread = times[times.len() / 2] / times[0];
            assert!(spread > 1.4, "{}: spread {}", name, spread);
        }
    }
}
