//! Dedispersion performance model (bandwidth-bound, AMBER/ARTS workload).
//!
//! Workload: 1536 frequency channels, 2048 dispersion measures, 12,288 time
//! samples (ARTS-like scale, reduced 2x to keep cache building instant).
//! Each thread block covers a (time x DM) tile; channel data loaded for a
//! tile is reused across the DMs in that tile, so DM-tiling directly reduces
//! DRAM traffic — the dominant performance effect, as in the real kernel.

use super::gpu::{self, GpuSpec};
use super::KernelModel;
use crate::searchspace::{Application, ParamSet};

const N_CHANNELS: f64 = 1536.0;
const N_DMS: f64 = 2048.0;
const N_TIME: f64 = 12_288.0;
const AVG_DELAY_SPAN: f64 = 512.0; // mean extra samples read per tile row

pub struct DedispersionModel {
    d_bsx: usize,
    d_bsy: usize,
    d_tsx: usize,
    d_tsy: usize,
    d_stride_x: usize,
    d_stride_y: usize,
    d_unroll: usize,
    d_bpsm: usize,
}

impl DedispersionModel {
    pub fn new(params: &ParamSet) -> Self {
        DedispersionModel {
            d_bsx: super::dim(params, "block_size_x"),
            d_bsy: super::dim(params, "block_size_y"),
            d_tsx: super::dim(params, "tile_size_x"),
            d_tsy: super::dim(params, "tile_size_y"),
            d_stride_x: super::dim(params, "tile_stride_x"),
            d_stride_y: super::dim(params, "tile_stride_y"),
            d_unroll: super::dim(params, "loop_unroll_factor_channel"),
            d_bpsm: super::dim(params, "blocks_per_sm"),
        }
    }
}

impl KernelModel for DedispersionModel {
    fn application(&self) -> Application {
        Application::Dedispersion
    }

    fn workload_flops(&self) -> f64 {
        // One accumulate per (dm, time, channel): dedispersion is additions
        // over gathered samples, not FMAs.
        N_DMS * N_TIME * N_CHANNELS
    }

    fn workload_bytes(&self) -> f64 {
        // One pass over the input + one output write (ideal reuse).
        (N_CHANNELS * (N_TIME + AVG_DELAY_SPAN) + N_DMS * N_TIME) * 4.0
    }

    fn runtime_ms(&self, vals: &[f64], gpu: &GpuSpec, salt: u64) -> Option<f64> {
        let bsx = vals[self.d_bsx];
        let bsy = vals[self.d_bsy];
        let tsx = vals[self.d_tsx];
        let tsy = vals[self.d_tsy];
        let stride_x = vals[self.d_stride_x];
        let stride_y = vals[self.d_stride_y];
        let unroll = vals[self.d_unroll];
        let bpsm_cap = vals[self.d_bpsm] as u32;

        if super::hidden_failure(salt, vals, 0.02) {
            return None;
        }

        let threads = (bsx * bsy) as u32;
        let tile_time = bsx * tsx; // time samples per block
        let tile_dms = bsy * tsy; // DMs per block
        let regs_per_thread = (28.0 + 2.0 * tsx * tsy + 0.25 * unroll) as u32;
        let blocks = gpu::active_blocks_per_sm(gpu, threads, 0, regs_per_thread, bpsm_cap);
        if blocks == 0 {
            return None; // occupancy-zero: launch failure (hidden constraint)
        }
        let occ = gpu::occupancy_fraction(gpu, threads, blocks);

        // --- DRAM traffic ---
        // Input: each (time, DM) tile reads all channels over its time span
        // (+ delay spread); reused across the DMs of the tile. The halo
        // amplification is capped (the L1/texture path absorbs extreme
        // re-reads for tiny tiles) and DM-tile reuse saturates sub-linearly
        // through L2.
        let n_tiles_time = (N_TIME / tile_time).ceil();
        let n_tiles_dm = (N_DMS / tile_dms).ceil();
        let halo_amp =
            ((tile_time + AVG_DELAY_SPAN / tsy.max(1.0)) / tile_time).min(16.0);
        // Register-level reuse covers the DMs inside a tile; every DM tile
        // re-streams the input (linear in the number of DM tiles), which is
        // what keeps the kernel bandwidth-bound on high-FLOP devices.
        let input_bytes = n_tiles_dm * n_tiles_time * N_CHANNELS * tile_time * halo_amp * 4.0;
        // L2 captures part of the inter-block reuse.
        let l2_factor = 1.0 - 0.25 * (gpu.l2_mib / 40.0).min(1.0);
        let output_bytes = N_DMS * N_TIME * 4.0;
        let bytes = input_bytes * l2_factor + output_bytes;

        // Striding changes the access pattern: strided (1) keeps warps on
        // consecutive samples (coalesced); contiguous-per-thread (0) splits
        // transactions unless tiles are tiny.
        let coalesce = if stride_x > 0.5 {
            super::coalescing_efficiency(bsx, gpu.warp_size as f64)
        } else {
            super::coalescing_efficiency(bsx / tsx.max(1.0), gpu.warp_size as f64) * 0.92
        };
        let stride_y_eff = if stride_y > 0.5 { 0.98 } else { 0.94 };

        let bw = gpu.mem_bandwidth_gbs * 1e9
            * super::bandwidth_utilization(occ)
            * coalesce
            * stride_y_eff;
        let mem_time_s = bytes / bw;

        // --- Compute ---
        // Sweet-spot unrolling of the channel loop (wider on Nvidia).
        let opt_unroll = match gpu.vendor {
            super::gpu::Vendor::Nvidia => 8.0,
            super::gpu::Vendor::Amd => 4.0,
        };
        let comp_eff = super::compute_utilization(occ) * super::unroll_efficiency(unroll, opt_unroll);
        let comp_time_s = self.workload_flops() / (gpu.fp32_tflops * 1e12 * comp_eff);

        let total_blocks = (n_tiles_time * n_tiles_dm) as u64;
        let wave = gpu::wave_quantization(gpu, total_blocks, blocks);

        let t_s = mem_time_s.max(comp_time_s) * wave * super::rugged(salt, vals, 0.50)
            + gpu.launch_overhead_us * 1e-6;
        Some(t_s * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::space_salt;
    use crate::searchspace::builder::build_dedispersion;

    #[test]
    fn all_valid_configs_have_sane_times() {
        let space = build_dedispersion();
        let model = DedispersionModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name("A100").unwrap();
        let salt = space_salt(Application::Dedispersion, gpu);
        let mut ok = 0;
        for i in space.iter_indices() {
            if let Some(t) = model.runtime_ms(&space.values_f64(i), gpu, salt) {
                // Terrible configurations are allowed to be terrible (tiny
                // tiles blow up redundant traffic), but stay finite.
                assert!(t > 0.01 && t < 1e6, "t={} cfg={}", t, i);
                ok += 1;
            }
        }
        // A handful of hidden failures, but the vast majority run.
        assert!(ok as f64 > 0.9 * space.len() as f64);
    }

    #[test]
    fn bandwidth_bound_on_a100() {
        // The best configuration should be memory-bound: its time should be
        // within 20x of the pure-bandwidth roofline.
        let space = build_dedispersion();
        let model = DedispersionModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name("A100").unwrap();
        let salt = space_salt(Application::Dedispersion, gpu);
        let best = space
            .iter_indices()
            .filter_map(|i| model.runtime_ms(&space.values_f64(i), gpu, salt))
            .fold(f64::INFINITY, f64::min);
        // The ideal roofline assumes perfect channel reuse; the real kernel
        // (and the model) re-reads input once per DM tile, so the best
        // achievable sits well above the ideal but within ~100x.
        let roofline_ms = model.workload_bytes() / (gpu.mem_bandwidth_gbs * 1e9) * 1e3;
        assert!(best < roofline_ms * 100.0, "best {} roofline {}", best, roofline_ms);
        assert!(best > roofline_ms, "faster than roofline?");
    }

    #[test]
    fn tuning_matters() {
        // Spread between best and median must be substantial (>1.5x) or the
        // space would be trivial to tune.
        let space = build_dedispersion();
        let model = DedispersionModel::new(&space.params);
        let gpu = gpu::GpuSpec::by_name("A4000").unwrap();
        let salt = space_salt(Application::Dedispersion, gpu);
        let mut times: Vec<f64> = space
            .iter_indices()
            .filter_map(|i| model.runtime_ms(&space.values_f64(i), gpu, salt))
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let best = times[0];
        let median = times[times.len() / 2];
        assert!(median / best > 1.5, "median/best = {}", median / best);
    }

    #[test]
    fn gpus_have_different_optima() {
        let space = build_dedispersion();
        let model = DedispersionModel::new(&space.params);
        let mut best_cfgs = Vec::new();
        for name in ["A100", "W6600", "MI250X"] {
            let gpu = gpu::GpuSpec::by_name(name).unwrap();
            let salt = space_salt(Application::Dedispersion, gpu);
            let best = space
                .iter_indices()
                .filter_map(|i| model.runtime_ms(&space.values_f64(i), gpu, salt).map(|t| (i, t)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
                .0;
            best_cfgs.push(best);
        }
        // At least two of the three devices disagree on the optimum.
        assert!(best_cfgs[0] != best_cfgs[1] || best_cfgs[1] != best_cfgs[2]);
    }
}
