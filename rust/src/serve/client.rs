//! Client side of the serve protocol: blocking helpers, one TCP
//! connection each, behind the `llamea-kt client` subcommands and the
//! serve integration tests.
//!
//! [`submit`] and [`tail`] hold their connection open and forward every
//! intermediate event (`accepted`, `progress`, `cancelling`) to the
//! caller's sink until the final `report` event, whose payload they
//! return. The report `Json` re-serializes to exactly the bytes the
//! daemon computed (the parser round-trips every `f64` bit-exactly), so
//! `client submit --out` files diff byte-for-byte against direct CLI
//! runs. Server-side `error` events surface as `Err` with the daemon's
//! diagnostic.
//!
//! Every read is bounded: a daemon that dies mid-stream (killed process,
//! dropped network) turns into a structured timeout error instead of a
//! client blocked forever. Control round-trips ([`status`]/[`cancel`])
//! use the short [`CONTROL_TIMEOUT`]; [`submit`]/[`tail`] streams use the
//! generous [`STREAM_TIMEOUT`] because a busy session is legitimately
//! silent between progress events.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::protocol::{submit_request, SubmitSpec};
use crate::util::json::Json;

/// Read timeout for one-request/one-response control round-trips.
pub const CONTROL_TIMEOUT: Duration = Duration::from_secs(10);

/// Read timeout between events on a `submit`/`tail` stream. Generous:
/// a large admitted grid can be event-silent while earlier sessions
/// drain, but a daemon silent this long is gone, not busy.
pub const STREAM_TIMEOUT: Duration = Duration::from_secs(120);

fn connect(addr: &str, timeout: Duration) -> Result<TcpStream, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect {}: {}", addr, e))?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| format!("connect {}: set read timeout: {}", addr, e))?;
    Ok(stream)
}

fn send_line(stream: &TcpStream, line: &Json) -> Result<(), String> {
    let mut w = stream;
    w.write_all(format!("{}\n", line.to_string()).as_bytes())
        .map_err(|e| format!("send request: {}", e))
}

/// Read one event line; `None` on a clean close. A read timeout means
/// the daemon died (or stalled) mid-stream — surfaced as a structured
/// error naming the bound, never an indefinite block.
fn read_event(reader: &mut BufReader<TcpStream>, timeout: Duration) -> Result<Option<Json>, String> {
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
        {
            Err(format!(
                "timed out after {}s waiting for the daemon (it may have died mid-stream)",
                timeout.as_secs()
            ))
        }
        Err(e) => Err(format!("read response: {}", e)),
        Ok(0) => Ok(None),
        Ok(_) => Json::parse(line.trim_end()).map(Some).map_err(|e| format!("bad response line: {}", e)),
    }
}

/// Drive a response stream to its `report` event, forwarding everything
/// before it to `on_event`. Returns `(session, report)`.
fn await_report(
    reader: &mut BufReader<TcpStream>,
    on_event: &mut dyn FnMut(&Json),
) -> Result<(u64, Json), String> {
    loop {
        let Some(mut ev) = read_event(reader, STREAM_TIMEOUT)? else {
            return Err("connection closed before a report arrived".into());
        };
        match ev.get("event").and_then(|v| v.as_str()) {
            Some("report") => {
                let session = ev.get("session").and_then(|v| v.as_usize()).unwrap_or(0) as u64;
                let report = ev
                    .remove("report")
                    .ok_or_else(|| "report event without a report payload".to_string())?;
                return Ok((session, report));
            }
            Some("error") => {
                return Err(ev
                    .get("message")
                    .and_then(|v| v.as_str())
                    .unwrap_or("unspecified server error")
                    .to_string());
            }
            _ => on_event(&ev),
        }
    }
}

/// Submit a tuning session and block until its served report. Returns
/// `(session id, report)`.
pub fn submit(
    addr: &str,
    spec: &SubmitSpec,
    on_event: &mut dyn FnMut(&Json),
) -> Result<(u64, Json), String> {
    let stream = connect(addr, STREAM_TIMEOUT)?;
    send_line(&stream, &submit_request(spec))?;
    let mut reader = BufReader::new(stream);
    await_report(&mut reader, on_event)
}

/// Re-attach to a session (running or finished) and block until its
/// report.
pub fn tail(addr: &str, session: u64, on_event: &mut dyn FnMut(&Json)) -> Result<Json, String> {
    let stream = connect(addr, STREAM_TIMEOUT)?;
    let mut req = Json::obj();
    req.set("cmd", "tail");
    req.set("session", session);
    send_line(&stream, &req)?;
    let mut reader = BufReader::new(stream);
    await_report(&mut reader, on_event).map(|(_, report)| report)
}

/// One request line, one response event.
fn control(addr: &str, req: &Json) -> Result<Json, String> {
    let stream = connect(addr, CONTROL_TIMEOUT)?;
    send_line(&stream, req)?;
    let mut reader = BufReader::new(stream);
    let ev = read_event(&mut reader, CONTROL_TIMEOUT)?
        .ok_or_else(|| "connection closed without a response".to_string())?;
    if ev.get("event").and_then(|v| v.as_str()) == Some("error") {
        return Err(ev
            .get("message")
            .and_then(|v| v.as_str())
            .unwrap_or("unspecified server error")
            .to_string());
    }
    Ok(ev)
}

/// The daemon's `status` event: pool width, outstanding jobs, per-session
/// accounting rows, daemon-wide `"jobs"` totals, cache-registry events.
pub fn status(addr: &str) -> Result<Json, String> {
    let mut req = Json::obj();
    req.set("cmd", "status");
    control(addr, &req)
}

/// Fire a session's cancel token; completed work stays (completed-prefix
/// report).
pub fn cancel(addr: &str, session: u64) -> Result<Json, String> {
    let mut req = Json::obj();
    req.set("cmd", "cancel");
    req.set("session", session);
    control(addr, &req)
}
