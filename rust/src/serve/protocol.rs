//! The daemon's wire protocol: newline-delimited JSON over TCP,
//! dependency-free on both ends (the [`crate::util::json`] writer/parser
//! round-trips every `f64` bit-exactly, so a report that crosses the
//! wire re-serializes to the same bytes the daemon computed).
//!
//! ## Requests (one JSON object per line)
//!
//! ```text
//! {"cmd":"submit","kind":"coordinate","spaces":["convolution@A4000"],
//!  "opts":["sa","random"],"runs":3,"seed":7}
//! {"cmd":"submit","kind":"sweep","spaces":["convolution@A4000"],
//!  "opt":"ga","runs":2,"seed":7}
//! {"cmd":"status"}
//! {"cmd":"cancel","session":2}
//! {"cmd":"tail","session":2}
//! ```
//!
//! Served sweeps are grid-shaped (`--meta grid`): the full meta-space is
//! known up front, which is what makes admission control and the
//! byte-identity contract checkable at submit time. Adaptive strategies
//! stay a direct-CLI feature.
//!
//! ## Responses (events, one JSON object per line)
//!
//! `{"event":"accepted","session":N,"jobs":N}` — submission admitted;
//! `{"event":"progress","session":N,"kind":"started|finished|cancelled|failed",...}`;
//! `{"event":"report","session":N,"report":{...}}` — the finished report
//! (for coordinate sessions, byte-identical to the direct CLI's `--out`
//! file modulo the non-deterministic `"caches"` block);
//! `{"event":"cancelling","session":N}`, `{"event":"status",...}`, and
//! `{"event":"error","message":"..."}`. Malformed or oversized request
//! lines are answered with an `error` event — never a panic or a hang.
//!
//! Seeds ride as JSON numbers, so they are exact up to 2^53 — the same
//! range every report field already lives in.

use std::io::BufRead;
use std::net::TcpStream;

use crate::coordinator::Progress;
use crate::util::json::Json;

/// Hard cap on one request line (defends the daemon's memory against a
/// client that never sends a newline).
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// One request line, bounded by [`MAX_LINE_BYTES`]. Shared by every
/// newline-delimited-JSON server in the crate (the serve daemon and the
/// remote fleet worker), so the robustness rules — bounded buffering,
/// structured answers for oversized / non-UTF-8 / truncated lines — stay
/// identical across protocols.
pub enum Line {
    /// A complete (or final unterminated) line; the bool is whether a
    /// newline terminated it — an unterminated line is the connection's
    /// last.
    Data(String, bool),
    TooLong,
    Eof,
    NotUtf8(bool),
}

/// Read one bounded request line from a connection reader (wrap the
/// stream as `BufReader::new(stream.take((MAX_LINE_BYTES + 1) as u64))`;
/// the limit is re-armed per call so the cap applies per line, not per
/// connection).
pub fn read_line(reader: &mut std::io::BufReader<std::io::Take<TcpStream>>) -> Line {
    reader.get_mut().set_limit((MAX_LINE_BYTES + 1) as u64);
    let mut buf = Vec::new();
    match reader.read_until(b'\n', &mut buf) {
        Err(_) | Ok(0) => return Line::Eof,
        Ok(_) => {}
    }
    let terminated = buf.last() == Some(&b'\n');
    if terminated {
        buf.pop();
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
    } else if buf.len() > MAX_LINE_BYTES {
        return Line::TooLong;
    }
    match String::from_utf8(buf) {
        Ok(s) => Line::Data(s, terminated),
        Err(_) => Line::NotUtf8(terminated),
    }
}

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Submit(SubmitSpec),
    Status,
    Cancel { session: u64 },
    Tail { session: u64 },
}

/// A tuning-session specification: the same (spaces × optimizers × seeds)
/// grid the `coordinate` subcommand runs, or a grid-strategy `sweep`.
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitSpec {
    Coordinate { spaces: Vec<String>, opts: Vec<String>, runs: usize, seed: u64 },
    Sweep { spaces: Vec<String>, opt: String, runs: usize, seed: u64 },
}

impl SubmitSpec {
    /// One-line description for `status` listings.
    pub fn describe(&self) -> String {
        match self {
            SubmitSpec::Coordinate { spaces, opts, runs, seed } => format!(
                "coordinate spaces={} opts={} runs={} seed={}",
                spaces.join(","),
                opts.join(","),
                runs,
                seed
            ),
            SubmitSpec::Sweep { spaces, opt, runs, seed } => {
                format!("sweep opt={} spaces={} runs={} seed={}", opt, spaces.join(","), runs, seed)
            }
        }
    }
}

fn str_list(j: &Json, key: &str) -> Result<Vec<String>, String> {
    let arr = j
        .get(key)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format!("'{}' must be an array of strings", key))?;
    let out: Vec<String> = arr.iter().filter_map(|v| v.as_str().map(str::to_string)).collect();
    if out.len() != arr.len() || out.is_empty() {
        return Err(format!("'{}' must be a non-empty array of strings", key));
    }
    Ok(out)
}

fn usize_field(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| format!("'{}' must be a non-negative integer", key))
}

/// Parse one request line. Every failure is a client-visible message —
/// the daemon wraps it in an `error` event and keeps the connection.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = Json::parse(line).map_err(|e| format!("bad request line: {}", e))?;
    let cmd = j
        .get("cmd")
        .and_then(|v| v.as_str())
        .ok_or_else(|| "request must carry a string 'cmd'".to_string())?;
    match cmd {
        "submit" => {
            let kind = j
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| "submit needs 'kind': 'coordinate' or 'sweep'".to_string())?;
            let spaces = str_list(&j, "spaces")?;
            let runs = usize_field(&j, "runs")?;
            if runs == 0 {
                return Err("'runs' must be at least 1".into());
            }
            let seed = usize_field(&j, "seed")? as u64;
            match kind {
                "coordinate" => {
                    let opts = str_list(&j, "opts")?;
                    Ok(Request::Submit(SubmitSpec::Coordinate { spaces, opts, runs, seed }))
                }
                "sweep" => {
                    let opt = j
                        .get("opt")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| "sweep submit needs a string 'opt'".to_string())?
                        .to_string();
                    Ok(Request::Submit(SubmitSpec::Sweep { spaces, opt, runs, seed }))
                }
                other => Err(format!("unknown submit kind '{}'", other)),
            }
        }
        "status" => Ok(Request::Status),
        "cancel" => Ok(Request::Cancel { session: usize_field(&j, "session")? as u64 }),
        "tail" => Ok(Request::Tail { session: usize_field(&j, "session")? as u64 }),
        other => Err(format!("unknown cmd '{}'", other)),
    }
}

/// Build the request line for a [`SubmitSpec`] (the client side of
/// [`parse_request`]; round-trips exactly).
pub fn submit_request(spec: &SubmitSpec) -> Json {
    let mut j = Json::obj();
    j.set("cmd", "submit");
    match spec {
        SubmitSpec::Coordinate { spaces, opts, runs, seed } => {
            j.set("kind", "coordinate");
            j.set("spaces", Json::Arr(spaces.iter().map(|s| Json::from(s.as_str())).collect()));
            j.set("opts", Json::Arr(opts.iter().map(|s| Json::from(s.as_str())).collect()));
            j.set("runs", *runs);
            j.set("seed", *seed);
        }
        SubmitSpec::Sweep { spaces, opt, runs, seed } => {
            j.set("kind", "sweep");
            j.set("spaces", Json::Arr(spaces.iter().map(|s| Json::from(s.as_str())).collect()));
            j.set("opt", opt.as_str());
            j.set("runs", *runs);
            j.set("seed", *seed);
        }
    }
    j
}

pub fn accepted_event(session: u64, jobs: usize) -> Json {
    let mut j = Json::obj();
    j.set("event", "accepted");
    j.set("session", session);
    j.set("jobs", jobs);
    j
}

pub fn progress_event(session: u64, ev: &Progress) -> Json {
    let mut j = Json::obj();
    j.set("event", "progress");
    j.set("session", session);
    match ev {
        Progress::Started { slot } => {
            j.set("kind", "started");
            j.set("slot", *slot);
        }
        Progress::Finished { slot, completed, elapsed_us } => {
            j.set("kind", "finished");
            j.set("slot", *slot);
            j.set("completed", *completed);
            j.set("elapsed_us", *elapsed_us);
        }
        Progress::Cancelled { slot } => {
            j.set("kind", "cancelled");
            j.set("slot", *slot);
        }
        Progress::Failed { slot, error } => {
            j.set("kind", "failed");
            j.set("slot", *slot);
            j.set("error", error.as_str());
        }
    }
    j
}

pub fn report_event(session: u64, report: Json) -> Json {
    let mut j = Json::obj();
    j.set("event", "report");
    j.set("session", session);
    j.set("report", report);
    j
}

pub fn cancelling_event(session: u64) -> Json {
    let mut j = Json::obj();
    j.set("event", "cancelling");
    j.set("session", session);
    j
}

pub fn error_event(message: &str) -> Json {
    let mut j = Json::obj();
    j.set("event", "error");
    j.set("message", message);
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_round_trips_through_the_parser() {
        let specs = [
            SubmitSpec::Coordinate {
                spaces: vec!["convolution@A4000".into(), "gemm@A100".into()],
                opts: vec!["sa".into(), "random".into()],
                runs: 3,
                seed: 7,
            },
            SubmitSpec::Sweep {
                spaces: vec!["convolution@A4000".into()],
                opt: "ga".into(),
                runs: 2,
                seed: 123,
            },
        ];
        for spec in specs {
            let line = submit_request(&spec).to_string();
            assert_eq!(parse_request(&line), Ok(Request::Submit(spec)));
        }
    }

    #[test]
    fn control_requests_parse() {
        assert_eq!(parse_request(r#"{"cmd":"status"}"#), Ok(Request::Status));
        assert_eq!(
            parse_request(r#"{"cmd":"cancel","session":4}"#),
            Ok(Request::Cancel { session: 4 })
        );
        assert_eq!(parse_request(r#"{"cmd":"tail","session":1}"#), Ok(Request::Tail { session: 1 }));
    }

    #[test]
    fn malformed_lines_yield_messages_not_panics() {
        for bad in [
            "{not json",
            "[]",
            r#"{"cmd":"warp"}"#,
            r#"{"cmd":"submit","kind":"coordinate"}"#,
            r#"{"cmd":"submit","kind":"coordinate","spaces":[],"opts":["sa"],"runs":1,"seed":0}"#,
            r#"{"cmd":"submit","kind":"coordinate","spaces":["a@b"],"opts":[3],"runs":1,"seed":0}"#,
            r#"{"cmd":"submit","kind":"coordinate","spaces":["a@b"],"opts":["sa"],"runs":0,"seed":0}"#,
            r#"{"cmd":"cancel"}"#,
        ] {
            assert!(parse_request(bad).is_err(), "{} must be rejected", bad);
        }
    }
}
