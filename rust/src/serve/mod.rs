//! Tuning-as-a-service: the `llamea-kt serve` daemon and its client.
//!
//! One long-lived process owns the expensive state — the process-wide
//! [`CacheRegistry`](crate::coordinator::CacheRegistry) of built search
//! spaces and one persistent [`pool::SharedPool`] of worker threads —
//! and serves many tuning sessions over TCP, so repeated experiments pay
//! cache construction once instead of once per CLI invocation.
//!
//! ## Protocol
//!
//! Newline-delimited JSON over `std::net::TcpListener`, dependency-free
//! on both ends (see [`protocol`] for the full request/response grammar).
//! A client submits a `coordinate`- or grid-`sweep`-shaped session,
//! receives an `accepted` event with its session id and admitted job
//! count, then a stream of per-job `progress` events, and finally a
//! `report` event carrying the finished report. `status`, `cancel`, and
//! `tail` control requests address sessions by id from any connection.
//! Malformed, oversized (> 1 MiB), or truncated request lines are
//! answered with a structured `error` event — never a panic or a hang.
//!
//! ## Invariants
//!
//! - **Byte identity.** A served coordinate report is byte-identical to
//!   the direct CLI's (`llamea-kt coordinate --out`) for the same spec —
//!   modulo the non-deterministic `"caches"` block — for any pool width,
//!   any number of concurrent sessions, and any cancellation timing of
//!   *other* sessions. This holds because job seeds are grid-derived,
//!   results are slot-indexed, and the daemon assembles reports through
//!   the CLI's own paths
//!   ([`coordinate_report`](crate::coordinator::coordinate_report),
//!   [`sweep_json`](crate::hypertune::sweep_json)).
//! - **Completed-prefix truth.** Cancelling a session keeps every
//!   completed job's curve bit-identical to its drain-all counterpart;
//!   the report degrades to the scoreable subset and is marked
//!   `"interrupted": true` with honest `"jobs"` counters — never a
//!   truncated or approximated curve.
//! - **Isolation.** A session's [`CancelToken`](crate::util::cancel)
//!   fires only its own batch; admission control
//!   (`--queue-cap`, `--max-sessions`) rejects with a diagnostic event
//!   rather than degrading running sessions.
//!
//! ## Fair share
//!
//! The pool interleaves sessions by least-started-first: each free
//! worker picks the batch with the fewest jobs started (ties to the
//! earlier arrival) and runs that batch's highest-priority pending job.
//! [`Priority`](crate::coordinator::Priority) bands therefore order work
//! *within* the owning session only — a flood of high-priority jobs from
//! one tenant cannot starve another, and a newly admitted session starts
//! drawing workers immediately.
//!
//! Module map: [`pool`] (the persistent executor), [`session`]
//! (per-tenant state + accounting), [`protocol`] (wire format),
//! [`daemon`] (listener + dispatch), [`client`] (blocking client
//! helpers).

pub mod client;
pub mod daemon;
pub mod pool;
pub mod protocol;
pub mod session;

pub use daemon::{ServeConfig, Server, ServerHandle};
pub use pool::{SessionRunner, SharedPool};
pub use protocol::{parse_request, submit_request, Request, SubmitSpec, MAX_LINE_BYTES};
pub use session::{Phase, SessionState, Sessions};
