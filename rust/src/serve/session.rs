//! Per-tenant session state and the daemon's session table.
//!
//! A session is one admitted `submit`: it owns a [`CancelToken`] (the
//! per-tenant cancellation seam), a set of subscribed event writers (the
//! submitting connection plus any `tail`ers), per-tenant job accounting
//! (the completed/cancelled/failed counters and summed evaluation cost
//! that also land in the report's `"jobs"` block), and — once finished —
//! the retained report, so late `tail`s and `status` queries answer from
//! memory instead of re-running anything.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::JobsSummary;
use crate::util::cancel::CancelToken;
use crate::util::json::Json;

/// Lifecycle of a session. `Cancelled` and `Failed` still retain a
/// report when one could be assembled (completed-prefix semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Running,
    Done,
    Cancelled,
    Failed,
}

impl Phase {
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Running => "running",
            Phase::Done => "done",
            Phase::Cancelled => "cancelled",
            Phase::Failed => "failed",
        }
    }
}

struct Inner {
    phase: Phase,
    summary: JobsSummary,
    report: Option<Json>,
    writers: Vec<TcpStream>,
}

/// One admitted tuning session (see the module docs).
pub struct SessionState {
    pub id: u64,
    /// Human-readable spec (`status` listings).
    pub desc: String,
    /// Total jobs admitted against the queue cap (exact for coordinate
    /// grids; the full-meta-space bound for grid sweeps).
    pub jobs_total: usize,
    pub cancel: CancelToken,
    inner: Mutex<Inner>,
    /// Notified on phase changes, so `tail` handlers can block until the
    /// session finishes without polling.
    finished: Condvar,
}

impl SessionState {
    /// Serialize one event and write it to every subscribed stream,
    /// dropping writers whose client hung up. One `write_all` per
    /// writer per event keeps lines atomic (all session writes go
    /// through this one lock).
    pub fn broadcast(&self, event: &Json) {
        let line = format!("{}\n", event.to_string());
        let mut inner = self.inner.lock().unwrap();
        inner.writers.retain_mut(|w| w.write_all(line.as_bytes()).is_ok());
    }

    /// Fold one batch's counters into the per-tenant account.
    pub fn absorb(&self, summary: JobsSummary) {
        self.inner.lock().unwrap().summary.absorb(summary);
    }

    pub fn summary(&self) -> JobsSummary {
        self.inner.lock().unwrap().summary
    }

    pub fn phase(&self) -> Phase {
        self.inner.lock().unwrap().phase
    }

    /// Retain the finished report and mark the session's terminal phase.
    pub fn finish(&self, phase: Phase, report: Option<Json>) {
        let mut inner = self.inner.lock().unwrap();
        inner.phase = phase;
        inner.report = report;
        self.finished.notify_all();
    }

    pub fn report(&self) -> Option<Json> {
        self.inner.lock().unwrap().report.clone()
    }

    /// Subscribe `stream` to this session's event broadcasts. For a
    /// still-running session the stream is attached and `true` is
    /// returned — the caller should then [`Self::wait_finished`]. For a
    /// finished session nothing is attached (`false`): the caller
    /// answers from the retained report instead.
    pub fn attach(&self, stream: TcpStream) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.phase != Phase::Running {
            return false;
        }
        inner.writers.push(stream);
        true
    }

    /// Block until the session leaves `Running`.
    pub fn wait_finished(&self) {
        let mut inner = self.inner.lock().unwrap();
        while inner.phase == Phase::Running {
            inner = self.finished.wait(inner).unwrap();
        }
    }

    /// The per-tenant accounting row of the daemon's `status` report.
    pub fn status_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut j = Json::obj();
        j.set("session", self.id);
        j.set("spec", self.desc.as_str());
        j.set("state", inner.phase.label());
        j.set("jobs_total", self.jobs_total);
        j.set("jobs", inner.summary.to_json());
        j
    }
}

/// The daemon's session table: monotonic ids, all sessions retained for
/// the process lifetime (`status`/`tail` answer about finished work; the
/// daemon is an interactive tool, not an unbounded archive).
#[derive(Default)]
pub struct Sessions {
    next_id: AtomicU64,
    all: Mutex<Vec<Arc<SessionState>>>,
}

impl Sessions {
    pub fn new() -> Sessions {
        Sessions { next_id: AtomicU64::new(1), all: Mutex::new(Vec::new()) }
    }

    /// Admit a session: assign the next id, register it, hand it out.
    pub fn register(&self, desc: String, jobs_total: usize) -> Arc<SessionState> {
        self.try_register(desc, jobs_total, 0).expect("a cap of 0 never rejects")
    }

    /// [`Self::register`] under a session cap: the active-count check and
    /// the registration happen under one lock, so two racing submissions
    /// cannot both slip past `--max-sessions` (`0` = uncapped). `None`
    /// means rejected.
    pub fn try_register(
        &self,
        desc: String,
        jobs_total: usize,
        max_sessions: usize,
    ) -> Option<Arc<SessionState>> {
        let mut all = self.all.lock().unwrap();
        if max_sessions > 0
            && all.iter().filter(|s| s.phase() == Phase::Running).count() >= max_sessions
        {
            return None;
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let session = Arc::new(SessionState {
            id,
            desc,
            jobs_total,
            cancel: CancelToken::new(),
            inner: Mutex::new(Inner {
                phase: Phase::Running,
                summary: JobsSummary::default(),
                report: None,
                writers: Vec::new(),
            }),
            finished: Condvar::new(),
        });
        all.push(Arc::clone(&session));
        Some(session)
    }

    pub fn get(&self, id: u64) -> Option<Arc<SessionState>> {
        self.all.lock().unwrap().iter().find(|s| s.id == id).cloned()
    }

    /// Sessions still running (the `--max-sessions` admission input).
    pub fn active(&self) -> usize {
        self.all.lock().unwrap().iter().filter(|s| s.phase() == Phase::Running).count()
    }

    /// Fire every running session's token (daemon shutdown).
    pub fn cancel_all(&self) {
        for s in self.all.lock().unwrap().iter() {
            s.cancel.cancel();
        }
    }

    /// Per-session accounting rows plus daemon-wide totals.
    pub fn status_json(&self) -> (Json, JobsSummary) {
        let all = self.all.lock().unwrap();
        let mut rows = Vec::with_capacity(all.len());
        let mut totals = JobsSummary::default();
        for s in all.iter() {
            rows.push(s.status_json());
            totals.absorb(s.summary());
        }
        (Json::Arr(rows), totals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sessions_account_per_tenant_and_in_total() {
        let sessions = Sessions::new();
        let a = sessions.register("coordinate ...".into(), 6);
        let b = sessions.register("sweep ...".into(), 12);
        assert_eq!((a.id, b.id), (1, 2));
        assert_eq!(sessions.active(), 2);
        a.absorb(JobsSummary { completed: 4, cancelled: 2, failed: 0, cost_us: 400 });
        b.absorb(JobsSummary { completed: 3, cancelled: 0, failed: 1, cost_us: 300 });
        b.absorb(JobsSummary { completed: 2, cancelled: 0, failed: 0, cost_us: 200 });
        a.finish(Phase::Cancelled, None);
        assert_eq!(sessions.active(), 1);
        let (rows, totals) = sessions.status_json();
        assert_eq!(
            totals,
            JobsSummary { completed: 9, cancelled: 2, failed: 1, cost_us: 900 }
        );
        let rows = rows.as_arr().unwrap();
        assert_eq!(rows[0].get("state").and_then(|v| v.as_str()), Some("cancelled"));
        assert_eq!(
            rows[1].get("jobs").unwrap().to_string(),
            r#"{"completed":5,"cancelled":0,"failed":1,"cost_us":500}"#
        );
        // Finished sessions answer tail from the retained report.
        b.finish(Phase::Done, Some(Json::obj()));
        assert_eq!(b.report(), Some(Json::obj()));
        b.wait_finished(); // returns immediately once terminal
    }

    #[test]
    fn try_register_enforces_the_session_cap_atomically() {
        let sessions = Sessions::new();
        let a = sessions.try_register("a".into(), 1, 1).unwrap();
        assert!(sessions.try_register("b".into(), 1, 1).is_none(), "cap of 1 rejects a second");
        a.finish(Phase::Done, None);
        let c = sessions.try_register("c".into(), 1, 1).unwrap();
        assert_eq!(c.id, 2, "rejected submissions must not burn ids");
    }
}
