//! The `llamea-kt serve` daemon: a TCP accept loop over the process-wide
//! [`CacheRegistry`] and one [`SharedPool`].
//!
//! One thread per connection; a connection serves one request at a time
//! (a `submit` occupies it until the report event, which is what keeps
//! every write to a stream whole-line atomic). Sessions are admitted
//! against `--max-sessions` (atomically, under the session-table lock)
//! and `--queue-cap` (pool-wide outstanding jobs); rejected submissions
//! get an `error` event with a diagnostic naming the limit, never a
//! dropped connection. Spaces resolve through the **global** registry,
//! so every session of the daemon's lifetime shares one set of built
//! caches (and one `--cache-dir`, when main wired it).
//!
//! Served reports reuse the CLI's exact assembly paths
//! ([`coordinate_report`], [`sweep_json`]) and append the registry's
//! `"caches"` block the same way `--out` files do — byte-identity modulo
//! that one block is pinned in `rust/tests/integration_serve.rs` and the
//! CI serve-smoke stage.

use std::io::{BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use super::pool::{SessionRunner, SharedPool};
use super::protocol::{
    accepted_event, cancelling_event, error_event, parse_request, progress_event, read_line,
    report_event, Line, Request, SubmitSpec, MAX_LINE_BYTES,
};
use super::session::{Phase, SessionState, Sessions};
use crate::coordinator::{
    coordinate_report, BatchRunner, CacheKey, CacheRegistry, OwnedJob, SpaceEntry,
    COORDINATE_TITLE,
};
use crate::hypertune::{sweep, sweep_json, MetaStrategy, MetaTuning};
use crate::obs;
use crate::optimizers::OptimizerSpec;
use crate::util::cancel::CancelToken;
use crate::util::error::panic_message;
use crate::util::json::Json;

/// Daemon limits. Zeros mean uncapped.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeConfig {
    /// Worker width of the shared pool (`None` = process default).
    pub threads: Option<usize>,
    /// Pool-wide outstanding-job bound for admission control.
    pub queue_cap: usize,
    /// Concurrent running-session bound.
    pub max_sessions: usize,
}

struct Shared {
    pool: Arc<SharedPool>,
    sessions: Sessions,
    config: ServeConfig,
    shutdown: CancelToken,
}

/// A bound, not-yet-running daemon. `bind` → inspect
/// [`Server::local_addr`] (supports `--listen 127.0.0.1:0`) → [`Server::run`].
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    shared: Arc<Shared>,
}

/// Clonable remote control for a running [`Server`]: fires the shutdown
/// token and pokes the accept loop awake.
#[derive(Clone)]
pub struct ServerHandle {
    token: CancelToken,
    addr: SocketAddr,
}

impl ServerHandle {
    pub fn shutdown(&self) {
        self.token.cancel();
        // The accept loop blocks in `accept`; a throwaway connection
        // makes it re-check the token.
        let _ = TcpStream::connect(self.addr);
    }
}

impl Server {
    pub fn bind(addr: &str, config: ServeConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        // A daemon always aggregates metrics: they feed the `status`
        // response's "metrics" block. Aggregation is in-place (bounded
        // memory), so this is safe for arbitrarily long uptimes.
        obs::enable_metrics();
        Ok(Server {
            listener,
            addr,
            shared: Arc::new(Shared {
                pool: SharedPool::new(config.threads),
                sessions: Sessions::new(),
                config,
                shutdown: CancelToken::new(),
            }),
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn threads(&self) -> usize {
        self.shared.pool.threads()
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { token: self.shared.shutdown.clone(), addr: self.addr }
    }

    /// Accept connections until the shutdown token fires, then cancel
    /// every running session and wind the pool down.
    pub fn run(self) -> std::io::Result<()> {
        for stream in self.listener.incoming() {
            if self.shared.shutdown.is_cancelled() {
                break;
            }
            let Ok(stream) = stream else { continue };
            let shared = Arc::clone(&self.shared);
            std::thread::spawn(move || handle_conn(shared, stream));
        }
        self.shared.sessions.cancel_all();
        self.shared.pool.shutdown();
        Ok(())
    }
}

/// Write one event line (best effort — a hung-up client just ends its
/// own connection).
fn send(stream: &TcpStream, event: &Json) {
    let mut w = stream;
    let _ = w.write_all(format!("{}\n", event.to_string()).as_bytes());
}

fn handle_conn(shared: Arc<Shared>, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half.take((MAX_LINE_BYTES + 1) as u64));
    loop {
        let (line, terminated) = match read_line(&mut reader) {
            Line::Eof => return,
            Line::TooLong => {
                // Cannot resync inside an unbounded line; answer and drop.
                send(&stream, &error_event("request line exceeds 1 MiB"));
                return;
            }
            Line::NotUtf8(t) => {
                send(&stream, &error_event("request line is not UTF-8"));
                if t {
                    continue;
                }
                return;
            }
            Line::Data(s, t) => (s, t),
        };
        if !line.trim().is_empty() {
            match parse_request(&line) {
                Err(msg) => send(&stream, &error_event(&msg)),
                Ok(Request::Status) => send(&stream, &status_event(&shared)),
                Ok(Request::Cancel { session }) => match shared.sessions.get(session) {
                    Some(s) => {
                        s.cancel.cancel();
                        send(&stream, &cancelling_event(session));
                    }
                    None => send(&stream, &error_event(&format!("unknown session {}", session))),
                },
                Ok(Request::Tail { session }) => handle_tail(&shared, &stream, session),
                Ok(Request::Submit(spec)) => handle_submit(&shared, &stream, spec),
            }
        }
        if !terminated {
            return;
        }
    }
}

fn status_event(shared: &Shared) -> Json {
    let (rows, totals) = shared.sessions.status_json();
    let mut j = Json::obj();
    j.set("event", "status");
    j.set("threads", shared.pool.threads());
    j.set("outstanding_jobs", shared.pool.outstanding());
    j.set("active_sessions", shared.sessions.active());
    j.set("sessions", rows);
    j.set("jobs", totals.to_json());
    j.set("metrics", obs::export::metrics_json());
    j.set("caches", CacheRegistry::global().caches_json());
    j
}

fn handle_tail(shared: &Shared, stream: &TcpStream, session: u64) {
    let Some(s) = shared.sessions.get(session) else {
        return send(stream, &error_event(&format!("unknown session {}", session)));
    };
    let Ok(writer) = stream.try_clone() else { return };
    if s.attach(writer) {
        // Attached mid-run: events (and the final report) stream through
        // the broadcast path; hold the request slot until then.
        s.wait_finished();
        return;
    }
    // Already finished: answer from the retained report.
    match s.report() {
        Some(r) => send(stream, &report_event(s.id, r)),
        None => send(
            stream,
            &error_event(&format!("session {} failed before a report was assembled", s.id)),
        ),
    }
}

/// A resolved, sized submission: everything admission control needs,
/// with the expensive world (registry entries, meta space) built exactly
/// once.
enum Prepared {
    Coordinate {
        entries: Vec<Arc<SpaceEntry>>,
        specs: Vec<Arc<OptimizerSpec>>,
        runs: usize,
        seed: u64,
    },
    Sweep {
        mt: MetaTuning,
        seed: u64,
    },
}

fn resolve_spaces(spaces: &[String]) -> Result<Vec<Arc<SpaceEntry>>, String> {
    spaces
        .iter()
        .map(|s| {
            CacheKey::parse(s)
                .map(|k| CacheRegistry::global().entry(k))
                .ok_or_else(|| format!("unknown space '{}' (use app@gpu)", s))
        })
        .collect()
}

fn prepare(spec: &SubmitSpec) -> Result<(Prepared, usize), String> {
    match spec {
        SubmitSpec::Coordinate { spaces, opts, runs, seed } => {
            let entries = resolve_spaces(spaces)?;
            let specs: Vec<Arc<OptimizerSpec>> = opts
                .iter()
                .map(|o| {
                    OptimizerSpec::parse(o).map(Arc::new).ok_or_else(|| {
                        format!("bad optimizer spec '{}' (see `llamea-kt optimizers`)", o)
                    })
                })
                .collect::<Result<_, _>>()?;
            let total = entries.len() * specs.len() * runs;
            Ok((Prepared::Coordinate { entries, specs, runs: *runs, seed: *seed }, total))
        }
        SubmitSpec::Sweep { spaces, opt, runs, seed } => {
            let entries = resolve_spaces(spaces)?;
            let base = OptimizerSpec::parse(opt)
                .ok_or_else(|| format!("bad optimizer spec '{}' (see `llamea-kt optimizers`)", opt))?;
            let n_spaces = entries.len();
            let mt = MetaTuning::new(base, entries, *runs, *seed, None)
                .map_err(|e| format!("sweep setup: {}", e))?;
            let total = mt.space().len() * n_spaces * runs;
            Ok((Prepared::Sweep { mt, seed: *seed }, total))
        }
    }
}

fn handle_submit(shared: &Arc<Shared>, stream: &TcpStream, spec: SubmitSpec) {
    let (prepared, jobs_total) = match prepare(&spec) {
        Err(msg) => return send(stream, &error_event(&msg)),
        Ok(p) => p,
    };
    if shared.config.queue_cap > 0 {
        let used = shared.pool.outstanding();
        if used + jobs_total > shared.config.queue_cap {
            obs::counter("serve.rejected_queue_cap", 1);
            return send(
                stream,
                &error_event(&format!(
                    "queue capacity exceeded: submission needs {} job(s) with {} already \
                     outstanding against --queue-cap {}; retry after running sessions drain",
                    jobs_total, used, shared.config.queue_cap
                )),
            );
        }
    }
    let Some(session) =
        shared.sessions.try_register(spec.describe(), jobs_total, shared.config.max_sessions)
    else {
        obs::counter("serve.rejected_sessions", 1);
        return send(
            stream,
            &error_event(&format!(
                "session limit reached: {} session(s) running at --max-sessions {}; \
                 retry after one finishes",
                shared.sessions.active(),
                shared.config.max_sessions
            )),
        );
    };
    if shared.shutdown.is_cancelled() {
        session.cancel.cancel();
    }
    let sid = session.id;
    send(stream, &accepted_event(sid, jobs_total));
    if let Ok(writer) = stream.try_clone() {
        session.attach(writer);
    }
    let mut session_span = obs::span("serve.session").kv("session", sid).kv("jobs", jobs_total);
    let outcome = catch_unwind(AssertUnwindSafe(|| run_session(shared, &session, prepared)));
    match outcome {
        Ok((mut report, phase)) => {
            session_span.note("outcome", phase.label());
            // Run metadata, outside the byte-identity contract — exactly
            // like the CLI's `write_report`.
            report.set("caches", CacheRegistry::global().caches_json());
            session.finish(phase, Some(report.clone()));
            session.broadcast(&report_event(sid, report));
        }
        Err(payload) => {
            session_span.note("outcome", Phase::Failed.label());
            session.finish(Phase::Failed, None);
            session.broadcast(&error_event(&format!(
                "session {} failed: {}",
                sid,
                panic_message(payload.as_ref())
            )));
        }
    }
}

/// Execute an admitted session on the shared pool and assemble its
/// report through the CLI's own paths.
fn run_session(
    shared: &Arc<Shared>,
    session: &Arc<SessionState>,
    prepared: Prepared,
) -> (Json, Phase) {
    let sid = session.id;
    match prepared {
        Prepared::Coordinate { entries, specs, runs, seed } => {
            let jobs = OwnedJob::grid(&entries, &specs, runs, seed);
            let runner = SessionRunner::new(Arc::clone(&shared.pool), session.cancel.clone());
            let observer = Arc::clone(session);
            let sink = move |ev: &crate::coordinator::Progress| {
                observer.broadcast(&progress_event(sid, ev));
            };
            let batch = runner.run_batch(&jobs, &sink);
            let summary = batch.summary();
            session.absorb(summary);
            let ids: Vec<String> = entries.iter().map(|e| e.cache.id()).collect();
            let labels: Vec<String> = specs.iter().map(|s| s.label()).collect();
            let report = coordinate_report(COORDINATE_TITLE, &ids, &labels, &batch);
            let phase = if summary.failed > 0 {
                Phase::Failed
            } else if !summary.all_completed() {
                Phase::Cancelled
            } else {
                Phase::Done
            };
            (report, phase)
        }
        Prepared::Sweep { mt, seed } => {
            let runner = Arc::new(SessionRunner::new(
                Arc::clone(&shared.pool),
                session.cancel.clone(),
            ));
            let observer = Arc::clone(session);
            let mt = mt
                .with_runner(runner)
                .with_progress(Box::new(move |ev| observer.broadcast(&progress_event(sid, ev))));
            let outcome = sweep(&mt, &MetaStrategy::Grid, seed);
            let summary = mt.jobs_summary();
            session.absorb(summary);
            let report = sweep_json(&mt, &outcome, seed);
            let phase = if summary.failed > 0 {
                Phase::Failed
            } else if mt.interrupted() {
                Phase::Cancelled
            } else {
                Phase::Done
            };
            (report, phase)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_run_and_shutdown_complete_without_sessions() {
        let server =
            Server::bind("127.0.0.1:0", ServeConfig { threads: Some(1), ..Default::default() })
                .expect("bind on an ephemeral port");
        let addr = server.local_addr();
        assert_ne!(addr.port(), 0, "port 0 must resolve to the bound port");
        let handle = server.handle();
        let runner = std::thread::spawn(move || server.run());
        handle.shutdown();
        runner.join().unwrap().expect("accept loop exits cleanly");
    }
}
