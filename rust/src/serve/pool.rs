//! The daemon's persistent execution engine: one long-lived worker pool
//! multiplexing job batches from many concurrent sessions.
//!
//! The [`Executor`](crate::coordinator::Executor) cannot serve a daemon
//! directly: its jobs borrow caches and setups, so every batch pins a
//! caller stack frame and its workers are scoped to one `run` call.
//! [`SharedPool`] decouples executor lifetime from batch lifetime by
//! executing [`OwnedJob`]s — `Arc`-owned worlds — on `'static` worker
//! threads that outlive every session.
//!
//! ## Scheduling and fair share
//!
//! Each submitted batch keeps its own pending max-heap with the
//! executor's exact order (higher [`Priority`] first, then lower slot).
//! Across batches, a free worker picks from the *least-started* batch
//! (ties to the earlier submission), so sessions interleave round-robin
//! at job granularity: a tenant with a thousand queued jobs cannot
//! starve one with ten. Priorities only reorder work **within** the
//! owning session's batch — one tenant's priority band can never outrank
//! another tenant's jobs, which is what makes the bands fair-share
//! rather than global.
//!
//! ## Determinism
//!
//! A job's curve is a pure function of its `(source, setup, factory,
//! seed)` and results land in slot-indexed handles, so completed results
//! are byte-identical to the same batch on a direct [`Executor`] — for
//! any worker count, any number of concurrent sessions, and any
//! cancellation timing of *other* sessions (pinned in
//! `rust/tests/integration_serve.rs`). Outcome edge semantics
//! (pre-checked cancellation, discarded partial curves, panic payloads)
//! go through the executor's own `execute_isolated`, so the two engines
//! cannot diverge.
//!
//! ## Cancellation
//!
//! Every batch carries the submitting session's
//! [`CancelToken`]. A fired token drains the batch's still-pending jobs
//! to [`JobOutcome::Cancelled`] and winds running ones down at their
//! next budget check; other batches are untouched. [`SharedPool::shutdown`]
//! fires every active batch's token, drains all pending work, and joins
//! the workers.

use std::collections::{BinaryHeap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::coordinator::executor::{execute_isolated, ProgressSink};
use crate::coordinator::{
    BatchResult, BatchRunner, JobHandle, JobOutcome, OwnedJob, Priority, Progress,
};
use crate::obs;
use crate::util::cancel::CancelToken;
use crate::util::parallel;

/// Pending-queue entry; identical max-heap order to the executor's
/// internal queue — higher priority first, then lower slot.
struct Pend {
    priority: Priority,
    slot: usize,
}

impl PartialEq for Pend {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.slot == other.slot
    }
}
impl Eq for Pend {}
impl PartialOrd for Pend {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pend {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority.cmp(&other.priority).then(other.slot.cmp(&self.slot))
    }
}

/// One in-flight batch. Lives in the pool state from submission until
/// the submitting thread collects the finished result (its `events`
/// queue buffers progress for that thread to forward — workers never
/// call a session's sink directly, so sinks may borrow submitter stack
/// state and a slow consumer can never stall the pool).
struct Batch {
    seq: u64,
    jobs: Vec<OwnedJob>,
    pending: BinaryHeap<Pend>,
    outcomes: Vec<Option<JobOutcome>>,
    cancel: CancelToken,
    fail_fast: bool,
    started: usize,
    finished: usize,
    completed: usize,
    events: VecDeque<Progress>,
    done: bool,
    /// Submission time, the origin for `Progress::Finished::elapsed_us`.
    t0: Instant,
}

/// Drain a batch's pending jobs to `Cancelled` (session cancel,
/// fail-fast abort, pool shutdown). Returns how many jobs were drained
/// so the caller can settle the pool-wide outstanding counter.
fn drain_pending(b: &mut Batch) -> usize {
    let mut n = 0;
    while let Some(p) = b.pending.pop() {
        b.outcomes[p.slot] = Some(JobOutcome::Cancelled);
        b.finished += 1;
        b.events.push_back(Progress::Cancelled { slot: p.slot });
        n += 1;
    }
    if b.finished == b.jobs.len() {
        b.done = true;
    }
    n
}

struct PoolState {
    batches: Vec<Batch>,
    next_seq: u64,
    /// Queued-or-running jobs across all batches (the admission-control
    /// input for the daemon's `--queue-cap`).
    outstanding: usize,
    shutdown: bool,
}

/// The long-lived, multi-tenant worker pool (see the module docs). One
/// per daemon process; sessions submit through [`SessionRunner`] handles.
pub struct SharedPool {
    state: Mutex<PoolState>,
    /// One condvar for both directions: workers wait for work, submitters
    /// wait for events/completion. Every state change `notify_all`s.
    cond: Condvar,
    threads: usize,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl SharedPool {
    /// Spawn a pool with `threads` workers (`None` = the process default
    /// width, like [`Executor::auto`](crate::coordinator::Executor::auto)).
    pub fn new(threads: Option<usize>) -> Arc<SharedPool> {
        let threads = threads.unwrap_or_else(parallel::default_width).max(1);
        let pool = Arc::new(SharedPool {
            state: Mutex::new(PoolState {
                batches: Vec::new(),
                next_seq: 0,
                outstanding: 0,
                shutdown: false,
            }),
            cond: Condvar::new(),
            threads,
            workers: Mutex::new(Vec::new()),
        });
        let mut handles = pool.workers.lock().unwrap();
        for _ in 0..threads {
            let p = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || p.worker()));
        }
        drop(handles);
        pool
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Queued-or-running jobs across all sessions right now.
    pub fn outstanding(&self) -> usize {
        self.state.lock().unwrap().outstanding
    }

    /// Execute one batch to completion under `cancel`, forwarding its
    /// [`Progress`] events to `sink` **on the calling thread**, and
    /// return the slot-indexed result. Every job gets a handle (a
    /// cancelled batch reports `Cancelled` outcomes, never missing
    /// slots), so the result is always fully drained. After
    /// [`Self::shutdown`], batches complete immediately as all-cancelled.
    pub fn run_batch_with(
        &self,
        jobs: &[OwnedJob],
        cancel: &CancelToken,
        fail_fast: bool,
        sink: &ProgressSink,
    ) -> BatchResult {
        let seq = {
            let mut st = self.state.lock().unwrap();
            let seq = st.next_seq;
            st.next_seq += 1;
            let mut pending = BinaryHeap::with_capacity(jobs.len());
            for (slot, j) in jobs.iter().enumerate() {
                pending.push(Pend { priority: j.priority, slot });
            }
            st.outstanding += jobs.len();
            let mut batch = Batch {
                seq,
                jobs: jobs.to_vec(),
                pending,
                outcomes: vec![None; jobs.len()],
                cancel: cancel.clone(),
                fail_fast,
                started: 0,
                finished: 0,
                completed: 0,
                events: VecDeque::new(),
                done: jobs.is_empty(),
                t0: Instant::now(),
            };
            if st.shutdown {
                st.outstanding -= drain_pending(&mut batch);
            }
            st.batches.push(batch);
            seq
        };
        self.cond.notify_all();
        let mut st = self.state.lock().unwrap();
        loop {
            let bi = st
                .batches
                .iter()
                .position(|b| b.seq == seq)
                .expect("a batch is removed only by its own submitter");
            let events: Vec<Progress> = st.batches[bi].events.drain(..).collect();
            if !events.is_empty() {
                // Forward outside the lock: a slow sink (a TCP client)
                // must never stall workers or other submitters.
                drop(st);
                for e in &events {
                    sink(e);
                }
                st = self.state.lock().unwrap();
                continue;
            }
            if st.batches[bi].done {
                let b = st.batches.remove(bi);
                drop(st);
                let handles = b
                    .jobs
                    .iter()
                    .zip(b.outcomes)
                    .enumerate()
                    .map(|(slot, (job, outcome))| JobHandle {
                        slot,
                        group: job.group,
                        priority: job.priority,
                        seed: job.seed,
                        cost_us: job.cost_us(),
                        outcome: outcome.expect("a done batch has every outcome recorded"),
                    })
                    .collect();
                // Every job of the materialized batch has a handle, so
                // the stream is drained by construction even when some
                // outcomes are Cancelled.
                return BatchResult::from_handles(handles, true);
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Fire every active batch's cancel token, drain pending work, and
    /// join the workers. In-flight jobs wind down at their next budget
    /// check; blocked submitters then collect their (partial) results as
    /// usual. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut st = self.state.lock().unwrap();
            st.shutdown = true;
            let mut drained = 0;
            for b in st.batches.iter_mut() {
                b.cancel.cancel();
                drained += drain_pending(b);
            }
            st.outstanding -= drained;
        }
        self.cond.notify_all();
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            h.join().expect("pool workers exit cleanly on shutdown");
        }
    }

    fn worker(&self) {
        loop {
            let (seq, slot, job, cancel) = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    // Short-circuit batches whose session token fired:
                    // drain them in bulk rather than cycling each job
                    // through a worker dispatch.
                    let mut drained = 0;
                    for b in st.batches.iter_mut() {
                        if !b.done && !b.pending.is_empty() && b.cancel.is_cancelled() {
                            drained += drain_pending(b);
                        }
                    }
                    if drained > 0 {
                        st.outstanding -= drained;
                        self.cond.notify_all();
                        continue;
                    }
                    // Fair share: least-started batch first, ties to the
                    // earlier submission; executor order within a batch.
                    let pick = st
                        .batches
                        .iter()
                        .enumerate()
                        .filter(|(_, b)| !b.done && !b.pending.is_empty())
                        .min_by_key(|(_, b)| (b.started, b.seq))
                        .map(|(i, _)| i);
                    if let Some(bi) = pick {
                        obs::counter("serve.pool.picks", 1);
                        let b = &mut st.batches[bi];
                        let p = b.pending.pop().expect("picked batch has pending work");
                        b.started += 1;
                        b.events.push_back(Progress::Started { slot: p.slot });
                        break (b.seq, p.slot, b.jobs[p.slot].clone(), b.cancel.clone());
                    }
                    st = self.cond.wait(st).unwrap();
                }
            };
            // Deliver the Started event before the (long) execution.
            self.cond.notify_all();
            let mut job_span = obs::span("serve.pool.job")
                .kv("slot", slot)
                .kv("priority", job.priority);
            let outcome = execute_isolated(&job.as_job(), &cancel);
            job_span.note("outcome", outcome.label());
            drop(job_span);
            {
                let mut st = self.state.lock().unwrap();
                let b = st
                    .batches
                    .iter_mut()
                    .find(|b| b.seq == seq)
                    .expect("a batch with an in-flight job is never removed");
                let event = match &outcome {
                    JobOutcome::Completed(_) => {
                        b.completed += 1;
                        Progress::Finished {
                            slot,
                            completed: b.completed,
                            elapsed_us: b.t0.elapsed().as_micros() as u64,
                        }
                    }
                    JobOutcome::Cancelled => Progress::Cancelled { slot },
                    JobOutcome::Failed(e) => Progress::Failed { slot, error: e.clone() },
                };
                let failed = matches!(outcome, JobOutcome::Failed(_));
                b.outcomes[slot] = Some(outcome);
                b.finished += 1;
                b.events.push_back(event);
                let mut settled = 1;
                if failed && b.fail_fast {
                    settled += drain_pending(b);
                }
                if b.finished == b.jobs.len() {
                    b.done = true;
                }
                st.outstanding -= settled;
            }
            self.cond.notify_all();
        }
    }
}

/// One session's view of the [`SharedPool`]: a [`BatchRunner`] carrying
/// the session's [`CancelToken`], so
/// [`MetaTuning::with_runner`](crate::hypertune::MetaTuning::with_runner)
/// and the served coordinate path drain through the shared pool while
/// cancellation stays per-tenant. Batches run fail-fast, matching the
/// direct CLI's `coordinate` executor.
pub struct SessionRunner {
    pool: Arc<SharedPool>,
    cancel: CancelToken,
}

impl SessionRunner {
    pub fn new(pool: Arc<SharedPool>, cancel: CancelToken) -> SessionRunner {
        SessionRunner { pool, cancel }
    }
}

impl BatchRunner for SessionRunner {
    fn run_batch(&self, jobs: &[OwnedJob], sink: &ProgressSink) -> BatchResult {
        self.pool.run_batch_with(jobs, &self.cancel, true, sink)
    }

    fn batch_cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::{CacheKey, CacheRegistry};
    use crate::coordinator::Executor;
    use crate::optimizers::OptimizerSpec;

    fn grid(registry: &CacheRegistry, opts: &[&str], runs: usize, seed: u64) -> Vec<OwnedJob> {
        let entries = vec![
            registry.entry(CacheKey::parse("convolution@A4000").unwrap()),
            registry.entry(CacheKey::parse("convolution@W6600").unwrap()),
        ];
        let specs: Vec<Arc<OptimizerSpec>> =
            opts.iter().map(|n| Arc::new(OptimizerSpec::parse(n).unwrap())).collect();
        OwnedJob::grid(&entries, &specs, runs, seed)
    }

    fn curves(batch: BatchResult) -> Vec<Vec<f64>> {
        batch.expect_curves()
    }

    #[test]
    fn pool_results_match_the_executor_bit_for_bit() {
        let registry = CacheRegistry::new();
        let jobs = grid(&registry, &["sa", "random"], 2, 11);
        let reference = curves(Executor::new(2).run_batch(&jobs, &|_| {}));
        for width in [1, 4] {
            let pool = SharedPool::new(Some(width));
            let token = CancelToken::new();
            let batch = pool.run_batch_with(&jobs, &token, true, &|_| {});
            assert!(batch.fully_drained());
            assert_eq!(batch.summary().completed, jobs.len());
            assert_eq!(curves(batch), reference, "width {}", width);
            pool.shutdown();
        }
    }

    #[test]
    fn concurrent_sessions_are_isolated_and_deterministic() {
        let registry = CacheRegistry::new();
        let a = grid(&registry, &["sa"], 3, 5);
        let b = grid(&registry, &["random"], 3, 9);
        let ref_a = curves(Executor::new(2).run_batch(&a, &|_| {}));
        let ref_b = curves(Executor::new(2).run_batch(&b, &|_| {}));
        let pool = SharedPool::new(Some(3));
        std::thread::scope(|scope| {
            let pa = &pool;
            let (ja, jb) = (&a, &b);
            let ta = scope.spawn(move || {
                pa.run_batch_with(ja, &CancelToken::new(), true, &|_| {})
            });
            let got_b = pool.run_batch_with(jb, &CancelToken::new(), true, &|_| {});
            assert_eq!(curves(got_b), ref_b);
            assert_eq!(curves(ta.join().unwrap()), ref_a);
        });
        pool.shutdown();
    }

    #[test]
    fn cancelling_one_session_leaves_the_other_byte_identical() {
        let registry = CacheRegistry::new();
        let victim = grid(&registry, &["sa", "random"], 8, 3);
        let bystander = grid(&registry, &["greedy_ils"], 3, 21);
        let ref_bystander = curves(Executor::new(2).run_batch(&bystander, &|_| {}));
        let ref_victim = curves(Executor::new(2).run_batch(&victim, &|_| {}));
        let pool = SharedPool::new(Some(2));
        let token = CancelToken::new();
        std::thread::scope(|scope| {
            let (p, t, jv) = (&pool, &token, &victim);
            let handle = scope.spawn(move || {
                // Cancel the victim session after its second completion.
                p.run_batch_with(jv, t, true, &|ev| {
                    if matches!(ev, Progress::Finished { completed: 2, .. }) {
                        t.cancel();
                    }
                })
            });
            let got = pool.run_batch_with(&bystander, &CancelToken::new(), true, &|_| {});
            assert_eq!(curves(got), ref_bystander, "bystander unaffected by foreign cancel");
            let partial = handle.join().unwrap();
            assert!(partial.fully_drained(), "every slot gets a handle");
            let s = partial.summary();
            assert_eq!(s.total(), victim.len());
            assert!(s.cancelled > 0, "the fired token must cancel pending jobs");
            // Completed-prefix invariant: whatever did complete is
            // bit-identical to the drain-all run's same slot.
            for h in &partial.handles {
                if let JobOutcome::Completed(curve) = &h.outcome {
                    assert_eq!(curve, &ref_victim[h.slot], "slot {}", h.slot);
                }
            }
        });
        pool.shutdown();
    }

    #[test]
    fn shutdown_cancels_everything_and_accepts_no_new_work() {
        let registry = CacheRegistry::new();
        let jobs = grid(&registry, &["sa"], 2, 2);
        let pool = SharedPool::new(Some(2));
        pool.shutdown();
        let batch = pool.run_batch_with(&jobs, &CancelToken::new(), true, &|_| {});
        let s = batch.summary();
        assert_eq!((s.completed, s.cancelled), (0, jobs.len()));
        assert_eq!(pool.outstanding(), 0);
        // Idempotent.
        pool.shutdown();
    }
}
