//! Prompt construction (paper Figs. 3–4).
//!
//! The task prompt carries the problem framing ("design novel metaheuristic
//! algorithms to solve kernel tuner problems (integer, variable dimension,
//! constraint)"), the code-format specification, an *optional* search-space
//! specification (the with/without-information experimental contrast of
//! §4.2), a minimum working example, and the output format spec. Mutation
//! prompts are the three natural-language operators of Fig. 4.

use super::genome::Genome;
use crate::methodology::SpaceSetup;
use crate::tuning::Cache;

/// The three LLaMEA mutation prompts (Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MutationPrompt {
    /// "Refine the strategy of the selected solution to improve it."
    Refine,
    /// "Generate a new algorithm that is different from the algorithms you
    /// have tried before."
    NewDifferent,
    /// "Refine and simplify the selected algorithm to improve it."
    Simplify,
}

impl MutationPrompt {
    pub const ALL: [MutationPrompt; 3] = [
        MutationPrompt::Refine,
        MutationPrompt::NewDifferent,
        MutationPrompt::Simplify,
    ];

    pub fn text(&self) -> &'static str {
        match self {
            MutationPrompt::Refine => {
                "Refine the strategy of the selected solution to improve it."
            }
            MutationPrompt::NewDifferent => {
                "Generate a new algorithm that is different from the algorithms you have tried before."
            }
            MutationPrompt::Simplify => {
                "Refine and simplify the selected algorithm to improve it."
            }
        }
    }
}

/// The search-space specification optionally inserted into the prompt
/// ("with extra info" condition): everything a generator could exploit.
#[derive(Debug, Clone, PartialEq)]
pub struct SpaceInfo {
    pub dims: usize,
    pub cartesian_size: u64,
    pub constrained_size: u64,
    /// constrained / cartesian.
    pub constraint_tightness: f64,
    /// Cardinality per tunable parameter.
    pub cardinalities: Vec<usize>,
    /// Budget divided by mean evaluation cost — how many evaluations an
    /// algorithm can afford on this space.
    pub expected_evals: f64,
}

impl SpaceInfo {
    /// Extract from a cache + its methodology setup.
    pub fn from_cache(cache: &Cache, setup: &SpaceSetup) -> SpaceInfo {
        let space = &cache.space;
        SpaceInfo {
            dims: space.dims(),
            cartesian_size: space.cartesian_size(),
            constrained_size: space.len() as u64,
            constraint_tightness: space.len() as f64 / space.cartesian_size() as f64,
            cardinalities: space
                .params
                .params
                .iter()
                .map(|p| p.cardinality())
                .collect(),
            expected_evals: setup.budget_s / cache.mean_eval_cost_s,
        }
    }
}

/// A full generation prompt.
#[derive(Debug, Clone)]
pub struct Prompt {
    /// Target application name (task framing).
    pub application: String,
    /// Present in the "with search space information" condition.
    pub space_info: Option<SpaceInfo>,
    /// Parent code for mutation calls.
    pub parent: Option<Genome>,
    pub mutation: Option<MutationPrompt>,
    /// Stack trace fed back for self-repair.
    pub repair_trace: Option<String>,
}

impl Prompt {
    /// Initial-population task prompt (Fig. 3).
    pub fn task(application: &str) -> Prompt {
        Prompt {
            application: application.to_string(),
            space_info: None,
            parent: None,
            mutation: None,
            repair_trace: None,
        }
    }

    pub fn with_info(mut self, info: SpaceInfo) -> Prompt {
        self.space_info = Some(info);
        self
    }

    pub fn mutate(mut self, parent: Genome, op: MutationPrompt) -> Prompt {
        self.parent = Some(parent);
        self.mutation = Some(op);
        self
    }

    /// Render the prompt text (what would be sent to a real LLM endpoint).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(
            "Your task is to design novel metaheuristic algorithms to solve \
             kernel tuner problems (integer, variable dimension, constraint).\n\n",
        );
        s.push_str(
            "<code format specification: subclass OptAlg; use the SearchSpace \
             object to generate an initial population, retrieve neighbors of a \
             configuration, and repair invalid configurations>\n\n",
        );
        if let Some(info) = &self.space_info {
            s.push_str(&format!(
                "Search space specification (json): {{\"application\": \"{}\", \
                 \"dimensions\": {}, \"cartesian_size\": {}, \"constrained_size\": {}, \
                 \"constraint_tightness\": {:.3}, \"cardinalities\": {:?}, \
                 \"expected_evaluations_within_budget\": {:.0}}}\n\n",
                self.application,
                info.dims,
                info.cartesian_size,
                info.constrained_size,
                info.constraint_tightness,
                info.cardinalities,
                info.expected_evals,
            ));
        }
        s.push_str("<minimum working code example>\n\n");
        if let (Some(parent), Some(op)) = (&self.parent, self.mutation) {
            s.push_str(&format!("Selected solution:\n{}\n\n", parent.summary()));
            s.push_str(op.text());
            s.push('\n');
        } else {
            s.push_str(
                "Give an excellent and novel heuristic algorithm to solve this \
                 task and also give it a one-line description, describing the \
                 main idea.\n",
            );
        }
        if let Some(trace) = &self.repair_trace {
            s.push_str(&format!(
                "\nThe previous candidate failed with:\n{}\nPlease repair the \
                 implementation.\n",
                trace
            ));
        }
        s.push_str("<output format specification>\n");
        s
    }

    /// Token estimate of the rendered prompt (~4 chars/token heuristic).
    pub fn token_estimate(&self) -> u64 {
        (self.render().len() as u64) / 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutation_prompt_texts_match_paper() {
        assert!(MutationPrompt::Refine.text().starts_with("Refine the strategy"));
        assert!(MutationPrompt::NewDifferent.text().contains("different from the algorithms"));
        assert!(MutationPrompt::Simplify.text().contains("simplify"));
    }

    #[test]
    fn rendered_prompt_contains_sections() {
        let p = Prompt::task("gemm");
        let r = p.render();
        assert!(r.contains("kernel tuner problems"));
        assert!(r.contains("minimum working code example"));
        assert!(r.contains("one-line description"));
        assert!(!r.contains("Search space specification"));
    }

    #[test]
    fn info_increases_prompt_tokens() {
        // (with-info prompts must be strictly longer)
        let without = Prompt::task("gemm");
        let with = Prompt::task("gemm").with_info(SpaceInfo {
            dims: 17,
            cartesian_size: 663_552,
            constrained_size: 112_912,
            constraint_tightness: 0.17,
            cardinalities: vec![4; 17],
            expected_evals: 3000.0,
        });
        assert!(with.token_estimate() > without.token_estimate());
        assert!(with.render().contains("Search space specification"));
    }

    #[test]
    fn mutation_prompt_replaces_initial_ask() {
        let p = Prompt::task("gemm").mutate(Genome::atgw_like(), MutationPrompt::Refine);
        let r = p.render();
        assert!(r.contains("Selected solution"));
        assert!(!r.contains("excellent and novel"));
    }

    #[test]
    fn repair_trace_rendered() {
        let mut p = Prompt::task("x");
        p.repair_trace = Some("TimeoutError".into());
        assert!(p.render().contains("Please repair"));
    }
}
