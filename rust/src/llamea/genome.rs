//! The algorithm genome: a structured representation of a metaheuristic.
//!
//! The paper's LLM emits Python classes; selection pressure, not the LLM,
//! guarantees quality. Our `MockLlm` emits *genomes* over the same design
//! space those classes span — initialization, neighborhood structures with
//! adaptive weights, surrogate pre-screening, tabu, SA-style acceptance,
//! elite recombination, restarts, population mixing — which the interpreter
//! (`super::interpreter`) turns into runnable [`Optimizer`]s. Both of the
//! paper's published winners are expressible: HybridVNDX is a
//! `SingleSolution` genome with surrogate+tabu+elites, AdaptiveTabuGreyWolf
//! a `Population` genome with leader mixing and budget-decayed acceptance.
//!
//! [`Optimizer`]: crate::optimizers::Optimizer

use crate::searchspace::NeighborKind;

/// Top-level control-flow skeleton.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Skeleton {
    /// One incumbent, candidate pools, VND-style neighborhood switching.
    SingleSolution,
    /// A small population with leader-based mixing (grey-wolf style).
    Population,
}

/// Initialization strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Init {
    /// Single random valid configuration / population of them.
    Random,
    /// Evaluate `k` random configs, start from the best.
    BestOfSample(usize),
}

/// Acceptance criterion for candidate moves.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Acceptance {
    /// Accept only improvements.
    Greedy,
    /// Metropolis with geometric cooling per step.
    Metropolis { t0: f64, cooling: f64 },
    /// Metropolis with budget-coupled temperature (ATGW style).
    BudgetMetropolis { t0: f64, lambda: f64, t_min: f64 },
}

/// Surrogate pre-screening of candidate pools.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SurrogateGene {
    pub k: usize,
    pub window: usize,
}

/// Restart / partial-reinit policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RestartGene {
    pub stagnation: u32,
    /// Fraction of the population reinitialized (1.0 for single-solution).
    pub reinit_ratio: f64,
}

/// Elite archive + recombination.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EliteGene {
    pub size: usize,
    /// Probability a pool slot is filled by an elite-crossover child.
    pub crossover_prob: f64,
}

/// Population-skeleton specifics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PopulationGene {
    pub size: usize,
    /// Shaking probability (post-mixing perturbation).
    pub shake_rate: f64,
    /// Probability a shake is a fresh-sample coordinate jump.
    pub jump_rate: f64,
}

/// A complete algorithm genome.
#[derive(Debug, Clone, PartialEq)]
pub struct Genome {
    pub name: String,
    pub description: String,
    pub skeleton: Skeleton,
    pub init: Init,
    /// Neighborhood set sampled (roulette if `adaptive_weights`).
    pub neighborhoods: Vec<NeighborKind>,
    pub adaptive_weights: bool,
    /// Candidate pool size per step (single-solution skeleton).
    pub pool_size: usize,
    pub surrogate: Option<SurrogateGene>,
    pub tabu_size: Option<usize>,
    pub acceptance: Acceptance,
    pub restart: Option<RestartGene>,
    pub elites: Option<EliteGene>,
    pub population: PopulationGene,
}

impl Genome {
    /// Rough structural complexity — drives the synthetic output-token count
    /// (Fig. 5) and the "simplify" mutation's pressure.
    pub fn complexity(&self) -> u32 {
        let mut c = 6; // skeleton + init + acceptance + loop scaffolding
        c += 2 * self.neighborhoods.len() as u32;
        if self.adaptive_weights {
            c += 3;
        }
        if self.surrogate.is_some() {
            c += 6;
        }
        if self.tabu_size.is_some() {
            c += 3;
        }
        if self.restart.is_some() {
            c += 3;
        }
        if self.elites.is_some() {
            c += 5;
        }
        if self.skeleton == Skeleton::Population {
            c += 6;
        }
        c
    }

    /// Structural validity: the interpreter can run anything that passes
    /// this; the mock LLM's "broken code" failures are modeled separately.
    pub fn is_valid(&self) -> bool {
        !self.neighborhoods.is_empty()
            && self.pool_size >= 1
            && self.pool_size <= 64
            && self.population.size >= 4
            && self.population.size <= 64
            && (0.0..=1.0).contains(&self.population.shake_rate)
            && (0.0..=1.0).contains(&self.population.jump_rate)
            && self.surrogate.map(|s| s.k >= 1 && s.window >= s.k).unwrap_or(true)
            && self.tabu_size.map(|t| t >= 1).unwrap_or(true)
            && self.elites.map(|e| e.size >= 1).unwrap_or(true)
            && match self.acceptance {
                Acceptance::Greedy => true,
                Acceptance::Metropolis { t0, cooling } => {
                    t0 > 0.0 && (0.5..1.0).contains(&cooling)
                }
                Acceptance::BudgetMetropolis { t0, lambda, t_min } => {
                    t0 > 0.0 && lambda > 0.0 && t_min > 0.0
                }
            }
    }

    /// A compact single-line summary (the "one-line description" of the
    /// paper's output format specification).
    pub fn summary(&self) -> String {
        let mut parts: Vec<&str> = Vec::new();
        match self.skeleton {
            Skeleton::SingleSolution => parts.push("VND-style single-solution search"),
            Skeleton::Population => parts.push("leader-mixed population search"),
        }
        if self.adaptive_weights {
            parts.push("adaptive neighborhood weights");
        }
        if self.surrogate.is_some() {
            parts.push("k-NN surrogate pre-screening");
        }
        if self.tabu_size.is_some() {
            parts.push("tabu");
        }
        if self.elites.is_some() {
            parts.push("elite recombination");
        }
        match self.acceptance {
            Acceptance::Greedy => parts.push("greedy acceptance"),
            Acceptance::Metropolis { .. } => parts.push("SA acceptance"),
            Acceptance::BudgetMetropolis { .. } => parts.push("budget-decayed SA acceptance"),
        }
        if self.restart.is_some() {
            parts.push("stagnation restarts");
        }
        format!("{}: {}", self.name, parts.join(", "))
    }

    /// The HybridVNDX genome (paper Algorithm 1) — used as a regression
    /// anchor in tests: interpreting this genome must behave like the
    /// hand-written implementation.
    pub fn hybrid_vndx_like() -> Genome {
        Genome {
            name: "HybridVNDX".into(),
            description: "VND with dynamic weights, kNN prescreen, elites, tabu+SA".into(),
            skeleton: Skeleton::SingleSolution,
            init: Init::Random,
            neighborhoods: vec![
                NeighborKind::Adjacent,
                NeighborKind::StrictlyAdjacent,
                NeighborKind::Hamming,
            ],
            adaptive_weights: true,
            pool_size: 8,
            surrogate: Some(SurrogateGene { k: 5, window: 512 }),
            tabu_size: Some(300),
            acceptance: Acceptance::Metropolis { t0: 1.0, cooling: 0.995 },
            restart: Some(RestartGene { stagnation: 100, reinit_ratio: 1.0 }),
            elites: Some(EliteGene { size: 5, crossover_prob: 0.15 }),
            population: PopulationGene { size: 8, shake_rate: 0.0, jump_rate: 0.0 },
        }
    }

    /// The AdaptiveTabuGreyWolf genome (paper Algorithm 2).
    pub fn atgw_like() -> Genome {
        Genome {
            name: "AdaptiveTabuGreyWolf".into(),
            description: "leader-mixed population, shaking, tabu, budget-decayed SA".into(),
            skeleton: Skeleton::Population,
            init: Init::Random,
            neighborhoods: vec![NeighborKind::Hamming, NeighborKind::Adjacent],
            adaptive_weights: false,
            pool_size: 8,
            surrogate: None,
            tabu_size: Some(24),
            acceptance: Acceptance::BudgetMetropolis { t0: 1.0, lambda: 5.0, t_min: 1e-4 },
            restart: Some(RestartGene { stagnation: 80, reinit_ratio: 0.3 }),
            elites: None,
            population: PopulationGene { size: 8, shake_rate: 0.2, jump_rate: 0.15 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_are_valid() {
        assert!(Genome::hybrid_vndx_like().is_valid());
        assert!(Genome::atgw_like().is_valid());
    }

    #[test]
    fn complexity_orders_sensibly() {
        let rich = Genome::hybrid_vndx_like();
        let mut lean = rich.clone();
        lean.surrogate = None;
        lean.elites = None;
        lean.adaptive_weights = false;
        assert!(rich.complexity() > lean.complexity());
    }

    #[test]
    fn invalid_genomes_detected() {
        let mut g = Genome::hybrid_vndx_like();
        g.neighborhoods.clear();
        assert!(!g.is_valid());
        let mut g2 = Genome::atgw_like();
        g2.population.size = 1;
        assert!(!g2.is_valid());
        let mut g3 = Genome::hybrid_vndx_like();
        g3.acceptance = Acceptance::Metropolis { t0: 1.0, cooling: 1.5 };
        assert!(!g3.is_valid());
    }

    #[test]
    fn summary_mentions_components() {
        let s = Genome::hybrid_vndx_like().summary();
        assert!(s.contains("surrogate"));
        assert!(s.contains("tabu"));
    }
}
