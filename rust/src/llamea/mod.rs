//! LLaMEA — LLM-driven evolutionary synthesis of optimization algorithms
//! (van Stein & Bäck 2025), integrated with the tuning substrate exactly as
//! the paper describes: the LLM proposes algorithms, the (4+12) elitist ES
//! selects on the methodology's performance score, broken candidates are
//! discarded, and stack traces feed self-repair.

pub mod evolution;
pub mod genome;
pub mod interpreter;
pub mod llm;
pub mod prompt;

pub use evolution::{
    evolve, evolve_best_of_runs, fitness_batch, fitness_of, Candidate, EvolutionConfig,
    EvolutionResult,
};
pub use genome::Genome;
pub use interpreter::GenomeOptimizer;
pub use llm::{Generation, LlmClient, MockLlm, TokenUsage};
pub use prompt::{MutationPrompt, Prompt, SpaceInfo};
