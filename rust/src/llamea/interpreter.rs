//! Genome interpreter: turns a [`Genome`] into a runnable optimizer.
//!
//! This is the executable stand-in for "the LLM's generated code": a
//! universal metaheuristic whose control flow is dictated entirely by the
//! genome's components. Both skeletons share the building blocks of
//! `crate::optimizers::components`.

use super::genome::{Acceptance, Genome, Init, Skeleton};
use crate::optimizers::components::{
    metropolis_accept, Cooling, EliteArchive, History, KnnSurrogate, TabuList,
};
use crate::optimizers::Optimizer;
use crate::tuning::TuningContext;

/// An optimizer executing a genome.
pub struct GenomeOptimizer {
    pub genome: Genome,
}

impl GenomeOptimizer {
    pub fn new(genome: Genome) -> GenomeOptimizer {
        GenomeOptimizer { genome }
    }

    fn accept(
        &self,
        acceptance: &Acceptance,
        cooling: &mut Cooling,
        current: f64,
        cand: f64,
        b: f64,
        rng: &mut crate::util::rng::Rng,
    ) -> bool {
        match *acceptance {
            Acceptance::Greedy => cand <= current,
            Acceptance::Metropolis { .. } => {
                let ok = metropolis_accept(current, cand, cooling.temperature(), rng);
                cooling.step();
                ok
            }
            Acceptance::BudgetMetropolis { t0, lambda, t_min } => {
                let t = Cooling::at_budget(t0, lambda, t_min, b);
                metropolis_accept(current, cand, t, rng)
            }
        }
    }

    fn initial(
        &self,
        ctx: &mut TuningContext,
        space: &crate::searchspace::SearchSpace,
    ) -> Option<(u32, f64)> {
        match self.genome.init {
            Init::Random => {
                // Sequential by necessity: how many draws happen depends
                // on each evaluation's outcome (retry on failures).
                for _ in 0..16 {
                    if ctx.budget_exhausted() {
                        return None;
                    }
                    let i = space.random_valid(&mut ctx.rng);
                    if let Some(v) = ctx.evaluate(i) {
                        return Some((i, v));
                    }
                }
                None
            }
            Init::BestOfSample(k) => {
                // The sample is drawn up front, so the whole probe goes to
                // the backend as one batch (bit-identical to the
                // sequential loop; skipped entries come back as None).
                let sample = space.random_sample(&mut ctx.rng, k);
                let mut best: Option<(u32, f64)> = None;
                for (&i, v) in sample.iter().zip(ctx.evaluate_batch(&sample)) {
                    if let Some(v) = v {
                        if best.map(|(_, bv)| v < bv).unwrap_or(true) {
                            best = Some((i, v));
                        }
                    }
                }
                best
            }
        }
    }

    fn run_single(&self, ctx: &mut TuningContext) {
        let g = &self.genome;
        let space = ctx.space_handle();
        let mut history = History::default();
        let mut elites = g.elites.map(|e| EliteArchive::new(e.size));
        let mut tabu = g.tabu_size.map(TabuList::new);
        let surrogate = g.surrogate.map(|s| KnnSurrogate::new(s.k, s.window));
        let mut weights = vec![1.0f64; g.neighborhoods.len()];
        let (t0, cooling_rate) = match g.acceptance {
            Acceptance::Metropolis { t0, cooling } => (t0, cooling),
            _ => (1.0, 1.0),
        };
        let mut cooling = Cooling::new(t0, cooling_rate, 1e-6);

        let Some((mut x, mut f_x)) = self.initial(ctx, &space) else { return };
        history.push(x, space.config(x), f_x);
        if let Some(e) = elites.as_mut() {
            e.push(x, f_x);
        }
        let mut stagnation = 0u32;
        // Convergence guard: steps that discover no new configuration only
        // pay bookkeeping time; a genome without restarts that has fully
        // converged would otherwise spin to the budget end. Kernel Tuner
        // strategies likewise terminate when converged.
        let mut idle_steps = 0u32;
        let mut last_unique = ctx.unique_evals();

        while !ctx.budget_exhausted() {
            if ctx.unique_evals() == last_unique {
                idle_steps += 1;
                if idle_steps > 300 {
                    if g.restart.is_some() {
                        if let Some((nx, nf)) = self.initial(ctx, &space) {
                            x = nx;
                            f_x = nf;
                        }
                        idle_steps = 0;
                    } else {
                        return; // converged
                    }
                }
            } else {
                last_unique = ctx.unique_evals();
                idle_steps = 0;
            }
            let n_idx = if g.adaptive_weights {
                ctx.rng.roulette(&weights)
            } else {
                ctx.rng.below(g.neighborhoods.len())
            };
            let kind = g.neighborhoods[n_idx];

            // Candidate pool over the precomputed CSR row (§Perf): the
            // per-(x, kind) memo this loop used to carry is obsolete —
            // every lookup is already a borrowed slice.
            let neigh = space.neighbors_of(x, kind);
            let mut pool: Vec<u32> = Vec::with_capacity(g.pool_size);
            let reserve = usize::from(elites.is_some());
            let take = g.pool_size.saturating_sub(1 + reserve).min(neigh.len());
            for &p in &ctx.rng.sample_indices(neigh.len(), take) {
                pool.push(neigh[p]);
            }
            if let Some(e) = elites.as_ref() {
                if ctx.rng.chance(g.elites.unwrap().crossover_prob.max(0.05)) {
                    if let Some(child) = e.crossover_child(&space, &mut ctx.rng) {
                        let idx = match space.index_of(&child) {
                            Some(i) => i,
                            None => space.repair(&child, &mut ctx.rng),
                        };
                        pool.push(idx);
                    }
                }
            }
            while pool.len() < g.pool_size {
                pool.push(space.random_valid(&mut ctx.rng));
            }

            // Pre-screen.
            let chosen = if let Some(s) = surrogate.as_ref() {
                let mut best_c = pool[0];
                let mut best_score = f64::INFINITY;
                for &c in &pool {
                    let mut score =
                        s.predict(&history, space.config(c)).unwrap_or(f_x);
                    if tabu.as_ref().map(|t| t.contains(c)).unwrap_or(false) {
                        score += 0.25 * f_x.abs().max(score.abs());
                    }
                    if score < best_score {
                        best_score = score;
                        best_c = c;
                    }
                }
                best_c
            } else {
                // No surrogate: pick a non-tabu pool member at random.
                *pool
                    .iter()
                    .find(|&&c| !tabu.as_ref().map(|t| t.contains(c)).unwrap_or(false))
                    .unwrap_or(&pool[0])
            };

            let Some(f_c) = ctx.evaluate(chosen) else {
                stagnation += 1;
                continue;
            };
            history.push(chosen, space.config(chosen), f_c);
            if let Some(e) = elites.as_mut() {
                e.push(chosen, f_c);
            }

            let b = ctx.budget_spent_fraction();
            if self.accept(&g.acceptance, &mut cooling, f_x, f_c, b, &mut ctx.rng) {
                if f_c < f_x {
                    stagnation = 0;
                } else {
                    stagnation += 1;
                }
                x = chosen;
                f_x = f_c;
                if let Some(t) = tabu.as_mut() {
                    t.push(x);
                }
                if g.adaptive_weights {
                    weights[n_idx] = (weights[n_idx] * 1.1).min(1e3);
                }
            } else {
                stagnation += 1;
                if g.adaptive_weights {
                    weights[n_idx] = (weights[n_idx] * 0.9).max(1e-3);
                }
            }

            if let Some(r) = g.restart {
                if stagnation > r.stagnation {
                    if let Some((nx, nf)) = self.initial(ctx, &space) {
                        x = nx;
                        f_x = nf;
                        history.push(x, space.config(x), f_x);
                    }
                    cooling.reset();
                    stagnation = 0;
                }
            }
        }
    }

    fn run_population(&self, ctx: &mut TuningContext) {
        let g = &self.genome;
        let space = ctx.space_handle();
        let p = g.population.size.max(4);
        let mut tabu = g.tabu_size.map(TabuList::new);
        let mut cooling = match g.acceptance {
            Acceptance::Metropolis { t0, cooling } => Cooling::new(t0, cooling, 1e-6),
            _ => Cooling::new(1.0, 1.0, 1e-6),
        };

        // Initial population as one backend batch (stream-preservation
        // argument: see TuningContext::evaluate_random_sample). The
        // steady-state generation loop below stays sequential —
        // Metropolis acceptance draws RNG per member between evaluations,
        // so batching it would change the stream.
        let mut pop: Vec<u32> = Vec::with_capacity(p);
        let mut fit: Vec<f64> = Vec::with_capacity(p);
        for (i, f) in ctx.evaluate_random_sample(p) {
            pop.push(i);
            fit.push(f.unwrap_or(f64::INFINITY));
            if let Some(t) = tabu.as_mut() {
                t.push(i);
            }
        }
        let mut best_seen = fit.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut stagnation = 0u32;
        let dims = space.dims();
        let mut idle_loops = 0u32;
        let mut last_unique = ctx.unique_evals();

        while !ctx.budget_exhausted() {
            // Convergence guard (see run_single).
            if ctx.unique_evals() == last_unique {
                idle_loops += 1;
                if idle_loops > 100 && g.restart.is_none() {
                    return; // converged
                }
            } else {
                last_unique = ctx.unique_evals();
                idle_loops = 0;
            }
            let b = ctx.budget_spent_fraction();
            let mut order: Vec<usize> = (0..pop.len()).collect();
            order.sort_by(|&a, &c| fit[a].partial_cmp(&fit[c]).unwrap());
            let leaders = [pop[order[0]], pop[order[1]], pop[order[2]]];

            for &t_idx in order.iter().skip(3) {
                if ctx.budget_exhausted() {
                    return;
                }
                let x = pop[t_idx];
                let (xa, xb, xd) = (
                    space.config(leaders[0]).to_vec(),
                    space.config(leaders[1]).to_vec(),
                    space.config(leaders[2]).to_vec(),
                );
                let xx = space.config(x).to_vec();
                let mut y: Vec<u16> = (0..dims)
                    .map(|d| match ctx.rng.below(4) {
                        0 => xa[d],
                        1 => xb[d],
                        2 => xd[d],
                        _ => xx[d],
                    })
                    .collect();
                if ctx.rng.chance(g.population.shake_rate) {
                    let d = ctx.rng.below(dims);
                    if ctx.rng.chance(g.population.jump_rate) {
                        let fresh = space.random_valid(&mut ctx.rng);
                        y[d] = space.config(fresh)[d];
                    } else {
                        let card = space.params.params[d].cardinality() as i32;
                        let step = if ctx.rng.chance(0.5) { 1 } else { -1 };
                        y[d] = (y[d] as i32 + step).clamp(0, card - 1) as u16;
                    }
                }
                let mut idx = match space.index_of(&y) {
                    Some(i) => i,
                    None => space.repair(&y, &mut ctx.rng),
                };
                if tabu.as_ref().map(|t| t.contains(idx)).unwrap_or(false) {
                    idx = space
                        .random_neighbor(idx, &mut ctx.rng, g.neighborhoods[0])
                        .unwrap_or_else(|| space.random_valid(&mut ctx.rng));
                }
                let Some(f_y) = ctx.evaluate(idx) else { continue };
                if self.accept(&g.acceptance, &mut cooling, fit[t_idx], f_y, b, &mut ctx.rng) {
                    pop[t_idx] = idx;
                    fit[t_idx] = f_y;
                    if let Some(t) = tabu.as_mut() {
                        t.push(idx);
                    }
                }
                if f_y < best_seen {
                    best_seen = f_y;
                    stagnation = 0;
                } else {
                    stagnation += 1;
                }
            }

            if let Some(r) = g.restart {
                if stagnation > r.stagnation {
                    let k = ((r.reinit_ratio * p as f64).ceil() as usize).clamp(1, p);
                    let mut order: Vec<usize> = (0..pop.len()).collect();
                    order.sort_by(|&a, &c| fit[c].partial_cmp(&fit[a]).unwrap());
                    // Reinit the worst k as one batch (stream-preservation
                    // argument: see TuningContext::evaluate_random_draws).
                    let targets: Vec<usize> = order.iter().take(k).copied().collect();
                    for (&w, (f_idx, f)) in
                        targets.iter().zip(ctx.evaluate_random_draws(targets.len()))
                    {
                        pop[w] = f_idx;
                        fit[w] = f.unwrap_or(f64::INFINITY);
                    }
                    stagnation = 0;
                }
            }
        }
    }
}

impl Optimizer for GenomeOptimizer {
    fn name(&self) -> &str {
        &self.genome.name
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        match self.genome.skeleton {
            Skeleton::SingleSolution => self.run_single(ctx),
            Skeleton::Population => self.run_population(ctx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llamea::genome::Genome;
    use crate::optimizers::testutil;

    #[test]
    fn interpreted_vndx_performs_like_handwritten() {
        let cache = testutil::conv_cache();
        let mut interp = GenomeOptimizer::new(Genome::hybrid_vndx_like());
        let mut hand = crate::optimizers::generated::HybridVndx::default();
        let (bi, _) = testutil::run_on(&mut interp, &cache, 500.0, 3);
        let (bh, _) = testutil::run_on(&mut hand, &cache, 500.0, 3);
        // Not bit-identical (independent streams) but the same class of
        // result: both in the top quintile.
        let sorted = cache.sorted_times();
        let p20 = sorted[sorted.len() / 5];
        assert!(bi < p20, "interpreted {} p20 {}", bi, p20);
        assert!(bh < p20);
    }

    #[test]
    fn interpreted_atgw_runs() {
        let cache = testutil::conv_cache();
        let mut interp = GenomeOptimizer::new(Genome::atgw_like());
        let (best, evals) = testutil::run_on(&mut interp, &cache, 400.0, 4);
        assert!(best.is_finite());
        assert!(evals > 10);
    }

    #[test]
    fn greedy_minimal_genome_runs() {
        let mut g = Genome::hybrid_vndx_like();
        g.surrogate = None;
        g.tabu_size = None;
        g.elites = None;
        g.adaptive_weights = false;
        g.acceptance = crate::llamea::genome::Acceptance::Greedy;
        g.restart = None;
        let cache = testutil::conv_cache();
        let (best, _) = testutil::run_on(&mut GenomeOptimizer::new(g), &cache, 300.0, 5);
        assert!(best.is_finite());
    }
}
