//! LLM clients for algorithm generation.
//!
//! `LlmClient` is the narrow interface LLaMEA needs: given a prompt,
//! return generated algorithm "code" (a [`Genome`]) plus token usage.
//! `MockLlm` is the offline stand-in (DESIGN.md §3): a grammar-based
//! sampler over the genome space with
//!   * ~25% failure injection (invalid code / runtime errors / timeouts —
//!     the paper's observed rate),
//!   * prompt conditioning: the *with search-space information* condition
//!     biases structural and hyperparameter choices using the space
//!     statistics embedded in the prompt (dimensionality, cardinalities,
//!     constraint tightness, expected budget),
//!   * stack-trace repair: a repair prompt greatly reduces the failure
//!     rate (the paper reports this is "consistently effective"),
//!   * token accounting for Fig. 5.

use super::genome::{
    Acceptance, EliteGene, Genome, Init, PopulationGene, RestartGene, SurrogateGene,
};
use super::prompt::{MutationPrompt, Prompt};
use crate::searchspace::NeighborKind;
use crate::util::rng::Rng;

/// Outcome of one LLM call.
#[derive(Debug, Clone)]
pub enum Generation {
    /// Parsable, runnable algorithm code.
    Code(Genome),
    /// Broken output: does not run (syntax/runtime/timeout). Carries the
    /// "stack trace" fed back on repair attempts.
    Broken { stack_trace: String },
}

/// Token usage of one call (prompt + completion), for Fig. 5.
#[derive(Debug, Clone, Copy, Default)]
pub struct TokenUsage {
    pub prompt_tokens: u64,
    pub completion_tokens: u64,
}

impl TokenUsage {
    pub fn total(&self) -> u64 {
        self.prompt_tokens + self.completion_tokens
    }
}

/// The narrow LLM interface LLaMEA consumes.
pub trait LlmClient {
    fn generate(&mut self, prompt: &Prompt) -> (Generation, TokenUsage);
}

/// Grammar-based mock LLM (see module docs).
pub struct MockLlm {
    rng: Rng,
    /// Failure probability of a fresh generation (paper: ~25%).
    pub failure_rate: f64,
    /// Failure probability when repairing with a stack trace.
    pub repair_failure_rate: f64,
    counter: u64,
}

impl MockLlm {
    pub fn new(seed: u64) -> MockLlm {
        MockLlm {
            rng: Rng::new(seed),
            failure_rate: 0.25,
            repair_failure_rate: 0.05,
            counter: 0,
        }
    }

    /// Sample a fresh genome from the grammar, conditioned on the prompt.
    fn sample_genome(&mut self, prompt: &Prompt) -> Genome {
        let rng = &mut self.rng;
        let info = prompt.space_info.as_ref();

        let skeleton = if rng.chance(0.5) {
            super::genome::Skeleton::SingleSolution
        } else {
            super::genome::Skeleton::Population
        };

        // --- Neighborhood set ---
        // With space info, high-dimensional / tightly-constrained spaces
        // bias towards including Hamming moves (constraint-aware wide
        // moves) and adaptive weighting; without info, uniform choices.
        let mut neighborhoods = Vec::new();
        let p_hamming = match info {
            Some(si) if si.dims >= 10 || si.constraint_tightness < 0.3 => 0.9,
            Some(_) => 0.6,
            None => 0.5,
        };
        if rng.chance(0.8) {
            neighborhoods.push(NeighborKind::Adjacent);
        }
        if rng.chance(p_hamming) {
            neighborhoods.push(NeighborKind::Hamming);
        }
        if rng.chance(0.35) {
            neighborhoods.push(NeighborKind::StrictlyAdjacent);
        }
        if neighborhoods.is_empty() {
            neighborhoods.push(NeighborKind::Hamming);
        }
        let adaptive_weights = rng.chance(if info.is_some() { 0.7 } else { 0.4 });

        // --- Budget-aware control parameters ---
        // Expected evaluations within budget inform restart thresholds,
        // tabu sizes and init sampling; without info, generic guesses.
        let expected_evals = info.map(|si| si.expected_evals).unwrap_or(500.0);
        let restart = if rng.chance(0.7) {
            let stag = if info.is_some() {
                (expected_evals * (0.08 + 0.12 * rng.f64())).max(8.0) as u32
            } else {
                [25u32, 50, 100, 200, 400][rng.below(5)]
            };
            Some(RestartGene {
                stagnation: stag,
                reinit_ratio: if rng.chance(0.5) { 1.0 } else { 0.2 + 0.4 * rng.f64() },
            })
        } else {
            None
        };
        let tabu_size = if rng.chance(0.6) {
            Some(if let Some(si) = info {
                ((si.constrained_size as f64).sqrt() as usize).clamp(16, 512)
            } else {
                [10usize, 50, 100, 300, 1000][rng.below(5)]
            })
        } else {
            None
        };

        // Small budgets reward best-of-sample seeding (with info only —
        // the uninformed generator cannot know the budget scale).
        let init = if info.map(|si| si.expected_evals < 120.0).unwrap_or(false)
            && rng.chance(0.6)
        {
            Init::BestOfSample((expected_evals * 0.15).max(3.0) as usize)
        } else if rng.chance(0.2) {
            Init::BestOfSample([4usize, 8, 16][rng.below(3)])
        } else {
            Init::Random
        };

        let surrogate = if rng.chance(if info.is_some() { 0.55 } else { 0.35 }) {
            Some(SurrogateGene {
                k: [3usize, 5, 7][rng.below(3)],
                window: [128usize, 256, 512][rng.below(3)],
            })
        } else {
            None
        };

        let acceptance = match rng.below(3) {
            0 => Acceptance::Greedy,
            1 => {
                // With info: cool so that T decays substantially within the
                // expected evaluation count; without: canonical 0.995.
                let cooling = if info.is_some() {
                    (0.02f64).powf(1.0 / expected_evals.max(16.0)).clamp(0.5, 0.9999)
                } else {
                    [0.9f64, 0.99, 0.995, 0.999][rng.below(4)]
                };
                Acceptance::Metropolis { t0: 0.3 + 0.9 * rng.f64(), cooling }
            }
            _ => Acceptance::BudgetMetropolis {
                t0: 0.5 + 0.8 * rng.f64(),
                lambda: 3.0 + 4.0 * rng.f64(),
                t_min: 1e-4,
            },
        };

        let elites = if rng.chance(0.45) {
            Some(EliteGene {
                size: [3usize, 5, 8][rng.below(3)],
                crossover_prob: 0.1 + 0.2 * rng.f64(),
            })
        } else {
            None
        };

        let population = PopulationGene {
            size: [6usize, 8, 12, 16][rng.below(4)],
            shake_rate: 0.1 + 0.3 * rng.f64(),
            jump_rate: 0.05 + 0.2 * rng.f64(),
        };

        self.counter += 1;
        let name = format!(
            "{}{}{}",
            ["Adaptive", "Hybrid", "Dynamic", "Guided", "Annealed"][rng.below(5)],
            ["Tabu", "VND", "Wolf", "Elite", "Swarm"][rng.below(5)],
            self.counter
        );
        let mut g = Genome {
            name,
            description: String::new(),
            skeleton,
            init,
            neighborhoods,
            adaptive_weights,
            pool_size: [4usize, 6, 8, 12][rng.below(4)],
            surrogate,
            tabu_size,
            acceptance,
            restart,
            elites,
            population,
        };
        g.description = g.summary();
        g
    }

    fn mutate_genome(&mut self, parent: &Genome, op: MutationPrompt, prompt: &Prompt) -> Genome {
        let mut g = parent.clone();
        let rng = &mut self.rng;
        match op {
            MutationPrompt::Refine => {
                // Perturb 1-2 hyperparameters / toggle one component.
                for _ in 0..1 + rng.below(2) {
                    match rng.below(6) {
                        0 => {
                            g.pool_size =
                                (g.pool_size as i64 + rng.range_inclusive(-2, 3)).clamp(2, 32)
                                    as usize
                        }
                        1 => {
                            if let Some(t) = g.tabu_size.as_mut() {
                                *t = ((*t as f64) * (0.5 + rng.f64())) as usize + 1;
                            } else {
                                g.tabu_size = Some(50);
                            }
                        }
                        2 => {
                            if let Some(r) = g.restart.as_mut() {
                                r.stagnation =
                                    ((r.stagnation as f64) * (0.5 + rng.f64())).max(4.0) as u32;
                            } else {
                                g.restart =
                                    Some(RestartGene { stagnation: 100, reinit_ratio: 1.0 });
                            }
                        }
                        3 => {
                            g.acceptance = match g.acceptance {
                                Acceptance::Metropolis { t0, cooling } => Acceptance::Metropolis {
                                    t0: (t0 * (0.6 + 0.8 * rng.f64())).clamp(0.05, 3.0),
                                    cooling,
                                },
                                other => other,
                            }
                        }
                        4 => g.adaptive_weights = !g.adaptive_weights,
                        _ => {
                            if g.surrogate.is_none() {
                                g.surrogate = Some(SurrogateGene { k: 5, window: 256 });
                            } else if rng.chance(0.3) {
                                g.surrogate = None;
                            }
                        }
                    }
                }
            }
            MutationPrompt::NewDifferent => {
                // A fresh sample (biased away from the parent's skeleton).
                let fresh = self.sample_genome(prompt);
                g = fresh;
                if g.skeleton == parent.skeleton && self.rng.chance(0.6) {
                    g.skeleton = match parent.skeleton {
                        super::genome::Skeleton::SingleSolution => {
                            super::genome::Skeleton::Population
                        }
                        super::genome::Skeleton::Population => {
                            super::genome::Skeleton::SingleSolution
                        }
                    };
                }
            }
            MutationPrompt::Simplify => {
                // Drop the most complex optional component.
                if g.surrogate.is_some() {
                    g.surrogate = None;
                } else if g.elites.is_some() {
                    g.elites = None;
                } else if g.neighborhoods.len() > 1 {
                    g.neighborhoods.pop();
                } else if g.tabu_size.is_some() {
                    g.tabu_size = None;
                } else {
                    g.adaptive_weights = false;
                }
            }
        }
        g.description = g.summary();
        g
    }

    fn completion_tokens(&mut self, g: &Genome) -> u64 {
        // ~35 tokens of code per structural unit, plus preamble, plus noise.
        let base = 120 + 35 * g.complexity() as u64;
        (base as f64 * (0.85 + 0.3 * self.rng.f64())) as u64
    }
}

impl LlmClient for MockLlm {
    fn generate(&mut self, prompt: &Prompt) -> (Generation, TokenUsage) {
        let prompt_tokens = prompt.token_estimate();
        let fail_p = if prompt.repair_trace.is_some() {
            self.repair_failure_rate
        } else {
            self.failure_rate
        };
        if self.rng.chance(fail_p) {
            // Broken generation still consumes completion tokens.
            let completion = 150 + self.rng.below(400) as u64;
            let traces = [
                "AttributeError: 'SearchSpace' object has no attribute 'get_neighbours'",
                "TypeError: repair() missing 1 required positional argument",
                "TimeoutError: candidate exceeded 300 s evaluation limit",
                "IndexError: list index out of range in neighbor sampling",
                "ValueError: configuration violates constraints after mutation",
            ];
            let trace = traces[self.rng.below(traces.len())].to_string();
            return (
                Generation::Broken { stack_trace: trace },
                TokenUsage { prompt_tokens, completion_tokens: completion },
            );
        }
        let genome = match (&prompt.parent, prompt.mutation) {
            (Some(parent), Some(op)) => self.mutate_genome(&parent.clone(), op, prompt),
            _ => self.sample_genome(prompt),
        };
        let completion_tokens = self.completion_tokens(&genome);
        (
            Generation::Code(genome),
            TokenUsage { prompt_tokens, completion_tokens },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llamea::prompt::SpaceInfo;

    fn base_prompt(with_info: bool) -> Prompt {
        let mut p = Prompt::task("dedispersion");
        if with_info {
            p.space_info = Some(SpaceInfo {
                dims: 8,
                cartesian_size: 21504,
                constrained_size: 11340,
                constraint_tightness: 0.53,
                cardinalities: vec![6, 2, 4, 4, 2, 2, 7, 4],
                expected_evals: 40.0,
            });
        }
        p
    }

    #[test]
    fn failure_rate_near_quarter() {
        let mut llm = MockLlm::new(1);
        let p = base_prompt(false);
        let mut fails = 0;
        for _ in 0..2000 {
            if matches!(llm.generate(&p).0, Generation::Broken { .. }) {
                fails += 1;
            }
        }
        let rate = fails as f64 / 2000.0;
        assert!((rate - 0.25).abs() < 0.04, "rate {}", rate);
    }

    #[test]
    fn generated_genomes_are_valid() {
        let mut llm = MockLlm::new(2);
        let p = base_prompt(true);
        for _ in 0..200 {
            if let (Generation::Code(g), _) = llm.generate(&p) {
                assert!(g.is_valid(), "{:?}", g);
            }
        }
    }

    #[test]
    fn repair_prompt_rarely_fails() {
        let mut llm = MockLlm::new(3);
        let mut p = base_prompt(false);
        p.repair_trace = Some("TimeoutError: ...".into());
        let mut fails = 0;
        for _ in 0..1000 {
            if matches!(llm.generate(&p).0, Generation::Broken { .. }) {
                fails += 1;
            }
        }
        assert!(fails < 100, "repair fails {}", fails);
    }

    #[test]
    fn with_info_prompts_cost_more_tokens() {
        let mut llm = MockLlm::new(4);
        let (_, t_with) = llm.generate(&base_prompt(true));
        let (_, t_without) = llm.generate(&base_prompt(false));
        assert!(t_with.prompt_tokens > t_without.prompt_tokens);
    }

    #[test]
    fn mutation_preserves_validity() {
        let mut llm = MockLlm::new(5);
        let mut p = base_prompt(true);
        p.parent = Some(Genome::hybrid_vndx_like());
        for op in [
            MutationPrompt::Refine,
            MutationPrompt::NewDifferent,
            MutationPrompt::Simplify,
        ] {
            p.mutation = Some(op);
            for _ in 0..50 {
                if let (Generation::Code(g), _) = llm.generate(&p) {
                    assert!(g.is_valid(), "{:?} via {:?}", g, op);
                }
            }
        }
    }

    #[test]
    fn simplify_reduces_complexity() {
        let mut llm = MockLlm::new(6);
        llm.failure_rate = 0.0;
        let mut p = base_prompt(false);
        p.parent = Some(Genome::hybrid_vndx_like());
        p.mutation = Some(MutationPrompt::Simplify);
        if let (Generation::Code(g), _) = llm.generate(&p) {
            assert!(g.complexity() < Genome::hybrid_vndx_like().complexity());
        }
    }
}
