//! The LLaMEA evolutionary loop (paper §3.2): a (mu + lambda) elitist ES
//! over algorithm genomes, with mu = 4 parents, lambda = 12 offspring per
//! generation, LLM-driven mutation (Fig. 4 prompts), fitness = the
//! methodology performance score P on the training caches, broken-candidate
//! discarding, and stack-trace repair when a whole generation fails.
//! A run stops after `llm_call_budget` LLM calls (paper: 100).
//!
//! Candidate fitness is evaluated through the L3 executor as **one
//! streamed job batch per generation** across all candidates × training
//! caches × seeds ([`fitness_batch`]), rather than per-cache `run_many`
//! calls per candidate: per-job seeds derive from the same (candidate
//! seed, space id, genome name, run) coordinates the per-cache path used,
//! so results are bit-identical while the worker pool sees the whole
//! generation through its bounded queue.

use std::borrow::Borrow;

use super::genome::Genome;
use super::llm::{Generation, LlmClient, TokenUsage};
use super::prompt::{MutationPrompt, Prompt, SpaceInfo};
use crate::coordinator::{collate_groups, job_seed, Executor, FnSource, TuningJob};
use crate::methodology::{aggregate, OptimizerFactory, SpaceSetup};
use crate::optimizers::OptimizerSpec;
use crate::tuning::Cache;
use crate::util::rng::Rng;

/// Configuration of one evolution run.
pub struct EvolutionConfig {
    /// Parent population size (paper: 4).
    pub mu: usize,
    /// Offspring per generation (paper: 12).
    pub lambda: usize,
    /// Total LLM calls per run (paper: 100).
    pub llm_call_budget: u64,
    /// Tuning runs per candidate evaluation (kept small in the generation
    /// loop — candidates get a full 100-run evaluation afterwards).
    pub eval_runs: usize,
    /// Target application name inserted into the prompt.
    pub application: String,
    /// With/without search-space information (the §4.2 contrast).
    pub space_info: Option<SpaceInfo>,
}

impl EvolutionConfig {
    pub fn paper_defaults(application: &str, space_info: Option<SpaceInfo>) -> EvolutionConfig {
        EvolutionConfig {
            mu: 4,
            lambda: 12,
            llm_call_budget: 100,
            eval_runs: 5,
            application: application.to_string(),
            space_info,
        }
    }
}

/// A scored member of the algorithm population.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub genome: Genome,
    pub fitness: f64,
}

/// Outcome of one evolution run.
pub struct EvolutionResult {
    pub best: Candidate,
    pub population: Vec<Candidate>,
    /// Total LLM token usage (Fig. 5).
    pub tokens: TokenUsage,
    pub llm_calls: u64,
    pub failures: u64,
    /// Best fitness after each generation (convergence reporting).
    pub fitness_history: Vec<f64>,
}

/// Fitness of a whole candidate batch — typically one generation — as a
/// single streamed (candidate × cache × seed) job batch drained by one
/// executor pool. Each entry pairs a genome with its per-candidate base
/// seed; returns one aggregate score per entry, in input order.
///
/// Seed derivation matches what per-candidate `run_many` calls produced
/// (`job_seed(candidate seed, cache id, genome name, run)`), so batching
/// the generation changes scheduling, never results.
pub fn fitness_batch<C: Borrow<Cache>>(
    candidates: &[(Genome, u64)],
    caches: &[C],
    setups: &[SpaceSetup],
    runs: usize,
) -> Vec<f64> {
    if candidates.is_empty() {
        return Vec::new();
    }
    let specs: Vec<OptimizerSpec> =
        candidates.iter().map(|(g, _)| OptimizerSpec::genome(g.clone())).collect();
    let labels: Vec<String> = specs.iter().map(|s| s.label()).collect();
    // Resolve the generic ownership once: the job stream borrows plain
    // `&Cache` refs (so `C` itself needs no extra bounds).
    let cache_refs: Vec<&Cache> = caches.iter().map(Borrow::borrow).collect();
    let space_ids: Vec<String> = cache_refs.iter().map(|c| c.id()).collect();
    // The generation streams lazily (candidate-major, then cache, then
    // seed) through the executor's bounded queue — same job sequence the
    // materialized batch produced, same seeds, same groups.
    let per_candidate = caches.len() * runs;
    let mut source = FnSource::new(candidates.len() * per_candidate, |i| {
        let (gi, rem) = (i / per_candidate, i % per_candidate);
        let (ci, r) = (rem / runs, rem % runs);
        TuningJob {
            source: cache_refs[ci],
            setup: &setups[ci],
            factory: &specs[gi] as &dyn OptimizerFactory,
            seed: job_seed(candidates[gi].1, &space_ids[ci], &labels[gi], r as u64),
            group: gi * caches.len() + ci,
        }
        .into()
    });
    let batch = Executor::auto().fail_fast().run(&mut source);
    let groups = batch.groups();
    let grouped =
        collate_groups(candidates.len() * caches.len(), &groups, batch.expect_curves());
    let mut it = grouped.into_iter();
    candidates
        .iter()
        .map(|_| {
            let per_space: Vec<Vec<Vec<f64>>> = it.by_ref().take(caches.len()).collect();
            aggregate(&per_space).score
        })
        .collect()
}

/// Fitness: aggregate performance score of the genome on the training set.
/// Generic over `Cache` ownership so callers can pass owned caches or the
/// coordinator registry's shared references. Single-candidate view of
/// [`fitness_batch`].
pub fn fitness_of<C: Borrow<Cache>>(
    genome: &Genome,
    caches: &[C],
    setups: &[SpaceSetup],
    runs: usize,
    seed: u64,
) -> f64 {
    fitness_batch(&[(genome.clone(), seed)], caches, setups, runs)[0]
}

/// Run one LLaMEA evolution (one of the paper's 5 independent runs).
pub fn evolve<C: Borrow<Cache>>(
    config: &EvolutionConfig,
    llm: &mut dyn LlmClient,
    caches: &[C],
    seed: u64,
) -> EvolutionResult {
    let mut rng = Rng::new(seed ^ 0x11AEA);
    let setups: Vec<SpaceSetup> =
        caches.iter().map(|c| SpaceSetup::new(Borrow::borrow(c))).collect();
    let mut tokens = TokenUsage::default();
    let mut llm_calls = 0u64;
    let mut failures = 0u64;
    let mut population: Vec<Candidate> = Vec::new();
    let mut fitness_history: Vec<f64> = Vec::new();
    let mut last_trace: Option<String> = None;

    let base_prompt = |parent: Option<(Genome, MutationPrompt)>, trace: Option<String>| {
        let mut p = Prompt::task(&config.application);
        if let Some(info) = &config.space_info {
            p = p.with_info(info.clone());
        }
        if let Some((g, op)) = parent {
            p = p.mutate(g, op);
        }
        p.repair_trace = trace;
        p
    };

    // --- Initial population: mu fresh generations ---
    // Valid genomes are collected (stamped with the fitness seed the
    // eager path used, `seed ^ llm_calls` at acceptance) and evaluated
    // below as one flat scheduler batch across all training caches.
    let mut pending: Vec<(Genome, u64)> = Vec::new();
    while pending.len() < config.mu && llm_calls < config.llm_call_budget {
        let prompt = base_prompt(None, last_trace.take());
        let (gen, usage) = llm.generate(&prompt);
        llm_calls += 1;
        tokens.prompt_tokens += usage.prompt_tokens;
        tokens.completion_tokens += usage.completion_tokens;
        match gen {
            Generation::Code(genome) if genome.is_valid() => {
                pending.push((genome, seed ^ llm_calls));
            }
            Generation::Code(_) => {
                failures += 1;
                last_trace =
                    Some("ValueError: generated algorithm failed validation".into());
            }
            Generation::Broken { stack_trace } => {
                failures += 1;
                last_trace = Some(stack_trace);
            }
        }
    }
    let fits = fitness_batch(&pending, caches, &setups, config.eval_runs);
    for ((genome, _), fitness) in pending.into_iter().zip(fits) {
        population.push(Candidate { genome, fitness });
    }
    assert!(!population.is_empty(), "no valid initial candidate generated");

    // --- Generations ---
    while llm_calls < config.llm_call_budget {
        // Valid offspring accumulate un-scored; the whole generation is
        // then evaluated as one flat job batch across all caches.
        let mut valid: Vec<(Genome, u64)> = Vec::new();
        let mut gen_failures = 0u64;
        let mut gen_trace: Option<String> = None;
        for _ in 0..config.lambda {
            if llm_calls >= config.llm_call_budget {
                break;
            }
            let parent = &population[rng.below(population.len())];
            let op = *rng.choose(&MutationPrompt::ALL);
            // If every candidate so far this generation failed, feed the
            // stack trace back (the paper's self-debugging path).
            let trace = if gen_failures > 0 && valid.is_empty() {
                gen_trace.clone()
            } else {
                None
            };
            let prompt = base_prompt(Some((parent.genome.clone(), op)), trace);
            let (gen, usage) = llm.generate(&prompt);
            llm_calls += 1;
            tokens.prompt_tokens += usage.prompt_tokens;
            tokens.completion_tokens += usage.completion_tokens;
            match gen {
                Generation::Code(genome) if genome.is_valid() => {
                    valid.push((genome, seed ^ llm_calls));
                }
                Generation::Code(_) => {
                    failures += 1;
                    gen_failures += 1;
                    gen_trace =
                        Some("ValueError: generated algorithm failed validation".into());
                }
                Generation::Broken { stack_trace } => {
                    failures += 1;
                    gen_failures += 1;
                    gen_trace = Some(stack_trace);
                }
            }
        }
        let fits = fitness_batch(&valid, caches, &setups, config.eval_runs);
        let offspring = valid
            .into_iter()
            .zip(fits)
            .map(|((genome, _), fitness)| Candidate { genome, fitness });
        // Elitist (mu + lambda) selection.
        population.extend(offspring);
        population.sort_by(|a, b| b.fitness.partial_cmp(&a.fitness).unwrap());
        population.truncate(config.mu);
        fitness_history.push(population[0].fitness);
    }

    let best = population[0].clone();
    EvolutionResult { best, population, tokens, llm_calls, failures, fitness_history }
}

/// The paper's protocol: 5 independent runs, keep the best-performing
/// algorithm. Returns (best result, per-run token totals).
pub fn evolve_best_of_runs<C: Borrow<Cache>>(
    config: &EvolutionConfig,
    make_llm: &mut dyn FnMut(u64) -> Box<dyn LlmClient>,
    caches: &[C],
    n_runs: usize,
    base_seed: u64,
) -> (EvolutionResult, Vec<u64>) {
    let mut best: Option<EvolutionResult> = None;
    let mut token_totals = Vec::with_capacity(n_runs);
    for r in 0..n_runs {
        let seed = base_seed.wrapping_add(r as u64 * 0x9E37);
        let mut llm = make_llm(seed);
        let result = evolve(config, llm.as_mut(), caches, seed);
        token_totals.push(result.tokens.total());
        if best
            .as_ref()
            .map(|b| result.best.fitness > b.best.fitness)
            .unwrap_or(true)
        {
            best = Some(result);
        }
    }
    (best.unwrap(), token_totals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gpu::GpuSpec;
    use crate::llamea::llm::MockLlm;
    use crate::searchspace::Application;

    fn tiny_setup() -> (Vec<Cache>, EvolutionConfig) {
        let caches = vec![Cache::build(
            Application::Convolution,
            GpuSpec::by_name("A4000").unwrap(),
        )];
        let setups: Vec<SpaceSetup> = caches.iter().map(SpaceSetup::new).collect();
        let info = SpaceInfo::from_cache(&caches[0], &setups[0]);
        let mut config = EvolutionConfig::paper_defaults("convolution", Some(info));
        config.llm_call_budget = 20; // keep the test fast
        config.eval_runs = 2;
        (caches, config)
    }

    #[test]
    fn evolution_improves_or_holds_fitness() {
        let (caches, config) = tiny_setup();
        let mut llm = MockLlm::new(42);
        let result = evolve(&config, &mut llm, &caches, 1);
        assert_eq!(result.llm_calls, 20);
        assert!(result.best.genome.is_valid());
        // Elitism: best fitness is non-decreasing across generations.
        assert!(result
            .fitness_history
            .windows(2)
            .all(|w| w[1] >= w[0] - 1e-12));
        assert!(result.tokens.total() > 1000);
    }

    #[test]
    fn failures_counted_and_survivable() {
        let (caches, config) = tiny_setup();
        let mut llm = MockLlm::new(7);
        llm.failure_rate = 0.5; // hostile LLM
        let result = evolve(&config, &mut llm, &caches, 2);
        assert!(result.failures > 0);
        assert!(result.best.genome.is_valid());
    }

    #[test]
    fn best_of_runs_selects_max() {
        let (caches, mut config) = tiny_setup();
        config.llm_call_budget = 8;
        let mut make = |seed: u64| -> Box<dyn LlmClient> { Box::new(MockLlm::new(seed)) };
        let (best, tokens) = evolve_best_of_runs(&config, &mut make, &caches, 3, 11);
        assert_eq!(tokens.len(), 3);
        assert!(best.best.genome.is_valid());
    }

    #[test]
    fn generation_batch_matches_per_candidate_run_many() {
        // The flat generation batch must reproduce the pre-batching
        // per-candidate, per-cache run_many evaluation bit-for-bit.
        let (caches, _) = tiny_setup();
        let setups: Vec<SpaceSetup> = caches.iter().map(SpaceSetup::new).collect();
        let g = Genome::hybrid_vndx_like();
        let batch = fitness_batch(&[(g.clone(), 11), (g.clone(), 22)], &caches, &setups, 2);
        for (i, seed) in [11u64, 22].iter().enumerate() {
            let spec = OptimizerSpec::genome(g.clone());
            let per_space: Vec<Vec<Vec<f64>>> = caches
                .iter()
                .zip(&setups)
                .map(|(c, s)| crate::methodology::run_many(c, s, &spec, 2, *seed))
                .collect();
            assert_eq!(batch[i], aggregate(&per_space).score, "seed {}", seed);
        }
        assert_eq!(batch[0], fitness_of(&g, &caches, &setups, 2, 11));
        assert!(fitness_batch(&[], &caches, &setups, 2).is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let (caches, config) = tiny_setup();
        let r1 = evolve(&config, &mut MockLlm::new(5), &caches, 9);
        let r2 = evolve(&config, &mut MockLlm::new(5), &caches, 9);
        assert_eq!(r1.best.genome, r2.best.genome);
        assert_eq!(r1.best.fitness, r2.best.fitness);
    }
}
