//! The paper's two best LLM-generated optimizers, implemented faithfully
//! from the published pseudocode (Algorithms 1 and 2) with the published
//! default hyperparameters. These are the algorithms shipped back into
//! Kernel Tuner according to the paper's §5.

pub mod adaptive_tabu_grey_wolf;
pub mod hybrid_vndx;

pub use adaptive_tabu_grey_wolf::AdaptiveTabuGreyWolf;
pub use hybrid_vndx::HybridVndx;
