//! HybridVNDX — the paper's best generated optimizer (Algorithm 1; target
//! application dedispersion, generated *with* search-space information).
//!
//! Variable Neighborhood Descent combined with (i) dynamic neighborhood
//! weighting, (ii) a light k-NN surrogate for candidate pre-screening,
//! (iii) elite recombination, and (iv) tabu search + simulated-annealing
//! acceptance. Faithful to the paper's pseudocode and default
//! hyperparameters: k=5, pool size 8, restart after 100 non-improving
//! steps, tabu size 300, elite size 5, T0=1.0, cooling 0.995.

use crate::optimizers::components::{
    metropolis_accept, Cooling, EliteArchive, History, KnnSurrogate, TabuList,
};
use crate::optimizers::{HyperParamDomain, Optimizer};
use crate::searchspace::NeighborKind;
use crate::tuning::TuningContext;

/// Sweepable grid around the paper's published defaults (which stay the
/// registry constructor values — `defaults_match_paper` pins them).
const DOMAINS: &[HyperParamDomain] = &[
    HyperParamDomain::new("k", 5.0, &[3.0, 5.0, 7.0]),
    HyperParamDomain::new("pool_size", 8.0, &[4.0, 8.0, 12.0]),
    HyperParamDomain::new("restart_after", 100.0, &[50.0, 100.0, 200.0]),
    HyperParamDomain::new("tabu_size", 300.0, &[100.0, 300.0, 600.0]),
    HyperParamDomain::new("elite_size", 5.0, &[3.0, 5.0, 8.0]),
    HyperParamDomain::new("t0", 1.0, &[0.5, 1.0, 2.0]),
    HyperParamDomain::new("cooling", 0.995, &[0.99, 0.995, 0.999]),
    HyperParamDomain::new("tabu_penalty", 0.25, &[0.1, 0.25, 0.5]),
];

/// The VND neighborhood set sampled by roulette over adaptive weights.
const NEIGHBORHOODS: [NeighborKind; 3] = [
    NeighborKind::Adjacent,
    NeighborKind::StrictlyAdjacent,
    NeighborKind::Hamming,
];

#[derive(Debug)]
pub struct HybridVndx {
    pub k: usize,
    pub pool_size: usize,
    pub restart_after: u32,
    pub tabu_size: usize,
    pub elite_size: usize,
    pub t0: f64,
    pub cooling: f64,
    /// Score penalty added to tabu candidates during pre-screening.
    pub tabu_penalty: f64,
}

impl Default for HybridVndx {
    fn default() -> Self {
        HybridVndx {
            k: 5,
            pool_size: 8,
            restart_after: 100,
            tabu_size: 300,
            elite_size: 5,
            t0: 1.0,
            cooling: 0.995,
            tabu_penalty: 0.25,
        }
    }
}

impl Optimizer for HybridVndx {
    fn name(&self) -> &str {
        "hybrid_vndx"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "k" => self.k = (value as usize).max(1),
            "pool_size" => self.pool_size = (value as usize).max(2),
            "restart_after" => self.restart_after = value as u32,
            "tabu_size" => self.tabu_size = value as usize,
            "elite_size" => self.elite_size = (value as usize).max(1),
            "t0" => self.t0 = value,
            "cooling" => self.cooling = value,
            "tabu_penalty" => self.tabu_penalty = value,
            _ => return false,
        }
        true
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        DOMAINS
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        // Line 1: initialize x <- random_valid(), evaluate; maintain history
        // H, elite heap E, tabu deque T; weights w[.] <- 1; T <- T0.
        let space = ctx.space_handle();
        let mut history = History::default();
        let mut elites = EliteArchive::new(self.elite_size);
        let mut tabu = TabuList::new(self.tabu_size);
        let surrogate = KnnSurrogate::new(self.k, 512);
        let mut weights = [1.0f64; NEIGHBORHOODS.len()];
        let mut cooling = Cooling::new(self.t0, self.cooling, 1e-6);

        let mut x = space.random_valid(&mut ctx.rng);
        let mut f_x = loop {
            match ctx.evaluate(x) {
                Some(v) => break v,
                None => {
                    if ctx.budget_exhausted() {
                        return;
                    }
                    x = space.random_valid(&mut ctx.rng);
                }
            }
        };
        history.push(x, space.config(x), f_x);
        elites.push(x, f_x);
        let mut stagnation = 0u32;

        // Line 2: while f.budget_spent_fraction < 1.
        while !ctx.budget_exhausted() {
            // Line 3: sample neighbourhood N by roulette over w.
            let n_idx = ctx.rng.roulette(&weights);
            let kind = NEIGHBORHOODS[n_idx];

            // Line 4: build candidate pool: subset of N(x), 1 elite-
            // crossover child, fill with random valid samples; repair.
            let mut pool: Vec<u32> = Vec::with_capacity(self.pool_size);
            // Borrowed CSR row (shared, precomputed) — the enumeration
            // that used to dominate this loop is now a slice lookup.
            let neigh = space.neighbors_of(x, kind);
            let take = (self.pool_size.saturating_sub(2)).min(neigh.len());
            for &j in ctx
                .rng
                .sample_indices(neigh.len(), take)
                .iter()
                .map(|&p| &neigh[p])
            {
                pool.push(j);
            }
            if let Some(child) = elites.crossover_child(&space, &mut ctx.rng) {
                let idx = match space.index_of(&child) {
                    Some(i) => i,
                    None => space.repair(&child, &mut ctx.rng),
                };
                pool.push(idx);
            }
            while pool.len() < self.pool_size {
                pool.push(space.random_valid(&mut ctx.rng));
            }

            // Line 5: score each candidate by k-NN prediction on H
            // (Hamming), add tabu penalty; pick the arg-min score.
            let mut best_c = pool[0];
            let mut best_score = f64::INFINITY;
            for &c in &pool {
                let pred = surrogate
                    .predict(&history, space.config(c))
                    .unwrap_or(f_x);
                let mut score = pred;
                if tabu.contains(c) {
                    score += self.tabu_penalty * f_x.abs().max(pred.abs());
                }
                if score < best_score {
                    best_score = score;
                    best_c = c;
                }
            }

            // Line 6: evaluate; push to H and E.
            let f_c = match ctx.evaluate(best_c) {
                Some(v) => v,
                None => {
                    // Crashing candidate: treat as non-improving step.
                    weights[n_idx] = (weights[n_idx] * 0.9).max(1e-3);
                    stagnation += 1;
                    cooling.step();
                    continue;
                }
            };
            history.push(best_c, space.config(best_c), f_c);
            elites.push(best_c, f_c);

            // Lines 7–9: SA acceptance; weight adaptation.
            if metropolis_accept(f_x, f_c, cooling.temperature(), &mut ctx.rng) {
                if f_c < f_x {
                    stagnation = 0;
                } else {
                    stagnation += 1;
                }
                x = best_c;
                f_x = f_c;
                tabu.push(x);
                weights[n_idx] = (weights[n_idx] * 1.1).min(1e3);
            } else {
                weights[n_idx] = (weights[n_idx] * 0.9).max(1e-3);
                stagnation += 1;
            }

            // Line 10: cooling; restart on stagnation.
            cooling.step();
            if stagnation > self.restart_after {
                x = space.random_valid(&mut ctx.rng);
                if let Some(v) = ctx.evaluate(x) {
                    f_x = v;
                    history.push(x, space.config(x), f_x);
                    elites.push(x, f_x);
                }
                cooling.reset();
                stagnation = 0;
            }
        }
        // Line 11: the best-so-far lives in the context's tracker.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn defaults_match_paper() {
        let h = HybridVndx::default();
        assert_eq!(h.k, 5);
        assert_eq!(h.pool_size, 8);
        assert_eq!(h.restart_after, 100);
        assert_eq!(h.tabu_size, 300);
        assert_eq!(h.elite_size, 5);
        assert_eq!(h.t0, 1.0);
        assert_eq!(h.cooling, 0.995);
    }

    #[test]
    fn strong_on_convolution() {
        let cache = testutil::conv_cache();
        let mut h = HybridVndx::default();
        let (best, _) = testutil::run_on(&mut h, &cache, 600.0, 20);
        // Should land in the top decile of the space.
        let sorted = cache.sorted_times();
        let p10 = sorted[sorted.len() / 10];
        assert!(best < p10, "best {} p10 {}", best, p10);
    }

    #[test]
    fn deterministic_per_seed() {
        let cache = testutil::conv_cache();
        let a = testutil::run_on(&mut HybridVndx::default(), &cache, 200.0, 21);
        let b = testutil::run_on(&mut HybridVndx::default(), &cache, 200.0, 21);
        assert_eq!(a, b);
    }

    #[test]
    fn restart_path_exercised() {
        let cache = testutil::conv_cache();
        let mut h = HybridVndx { restart_after: 3, ..Default::default() };
        let (best, _) = testutil::run_on(&mut h, &cache, 300.0, 22);
        assert!(best.is_finite());
    }
}
