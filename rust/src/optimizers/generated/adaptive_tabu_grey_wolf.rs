//! AdaptiveTabuGreyWolf — the paper's second-best generated optimizer
//! (Algorithm 2; target application GEMM, generated *with* search-space
//! information).
//!
//! A small population of valid configurations; each step, every non-leader
//! proposes a candidate by mixing each parameter independently from the
//! three current best solutions (alpha, beta, delta) or itself; a light
//! "shaking" step perturbs the proposal (random coordinate jump from a
//! fresh valid sample, or a one-step neighborhood move that is coarser
//! early and stricter later); infeasible proposals are repaired; a tabu
//! list blocks repeats; SA acceptance under a budget-decayed temperature
//! (with mild reheating on stagnation); on stalls a fraction of the worst
//! individuals is reinitialized. Defaults per the paper: p=8, L=3p, s=0.2,
//! q=0.15, tau=80, rho=0.3, T0=1.0, lambda=5.0, Tmin=1e-4.

use crate::optimizers::components::{metropolis_accept, Cooling, TabuList};
use crate::optimizers::{HyperParamDomain, Optimizer};
use crate::searchspace::NeighborKind;
use crate::tuning::TuningContext;

/// Sweepable grid around the paper's published defaults (which stay the
/// registry constructor values — `defaults_match_paper` pins them).
const DOMAINS: &[HyperParamDomain] = &[
    HyperParamDomain::new("population", 8.0, &[4.0, 8.0, 16.0]),
    HyperParamDomain::new("tabu_factor", 3.0, &[2.0, 3.0, 5.0]),
    HyperParamDomain::new("shake_rate", 0.2, &[0.1, 0.2, 0.4]),
    HyperParamDomain::new("jump_rate", 0.15, &[0.05, 0.15, 0.3]),
    HyperParamDomain::new("stagnation_limit", 80.0, &[40.0, 80.0, 160.0]),
    HyperParamDomain::new("restart_ratio", 0.3, &[0.2, 0.3, 0.5]),
    HyperParamDomain::new("t0", 1.0, &[0.5, 1.0, 2.0]),
    HyperParamDomain::new("lambda", 5.0, &[2.5, 5.0, 10.0]),
];

#[derive(Debug)]
pub struct AdaptiveTabuGreyWolf {
    pub population: usize,
    pub tabu_factor: usize, // L = tabu_factor * population
    pub shake_rate: f64,    // s
    pub jump_rate: f64,     // q
    pub stagnation_limit: u32, // tau
    pub restart_ratio: f64, // rho
    pub t0: f64,
    pub lambda: f64,
    pub t_min: f64,
}

impl Default for AdaptiveTabuGreyWolf {
    fn default() -> Self {
        AdaptiveTabuGreyWolf {
            population: 8,
            tabu_factor: 3,
            shake_rate: 0.2,
            jump_rate: 0.15,
            stagnation_limit: 80,
            restart_ratio: 0.3,
            t0: 1.0,
            lambda: 5.0,
            t_min: 1e-4,
        }
    }
}

impl AdaptiveTabuGreyWolf {
    /// Budget-coupled neighborhood schedule: coarse (Hamming) moves early,
    /// strict (Adjacent) moves late — the paper's N_{m(b)}.
    fn neighborhood_at(b: f64) -> NeighborKind {
        if b < 0.5 {
            NeighborKind::Hamming
        } else {
            NeighborKind::Adjacent
        }
    }
}

impl Optimizer for AdaptiveTabuGreyWolf {
    fn name(&self) -> &str {
        "atgw"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "population" => self.population = (value as usize).max(4),
            "tabu_factor" => self.tabu_factor = (value as usize).max(1),
            "shake_rate" => self.shake_rate = value,
            "jump_rate" => self.jump_rate = value,
            "stagnation_limit" => self.stagnation_limit = value as u32,
            "restart_ratio" => self.restart_ratio = value,
            "t0" => self.t0 = value,
            "lambda" => self.lambda = value,
            _ => return false,
        }
        true
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        DOMAINS
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let space = ctx.space_handle();
        let p = self.population.max(4);
        let dims = space.dims();
        let mut tabu = TabuList::new(self.tabu_factor * p);

        // P <- p random valid configs; evaluated as one batch (stream-
        // preservation argument: see TuningContext::evaluate_random_sample).
        let mut pop: Vec<u32> = Vec::with_capacity(p);
        let mut fit: Vec<f64> = Vec::with_capacity(p);
        for (i, f) in ctx.evaluate_random_sample(p) {
            pop.push(i);
            fit.push(f.unwrap_or(f64::INFINITY));
            tabu.push(i);
        }
        let mut stagnation = 0u32;
        let mut best_seen = fit.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut reheat = 0.0f64;

        while !ctx.budget_exhausted() {
            let b = ctx.budget_spent_fraction();
            // Sort population; leaders are the best three.
            let mut order: Vec<usize> = (0..pop.len()).collect();
            order.sort_by(|&a, &c| fit[a].partial_cmp(&fit[c]).unwrap());
            let (alpha, beta, delta) = (pop[order[0]], pop[order[1]], pop[order[2]]);
            let leaders = [order[0], order[1], order[2]];

            for oi in 3..order.len() {
                if ctx.budget_exhausted() {
                    return;
                }
                let t_idx = order[oi];
                if leaders.contains(&t_idx) {
                    continue;
                }
                let x = pop[t_idx];
                let xa = space.config(alpha).to_vec();
                let xb = space.config(beta).to_vec();
                let xd = space.config(delta).to_vec();
                let xx = space.config(x).to_vec();

                // Leader-mixed proposal: each dim uniform over
                // {alpha_i, beta_i, delta_i, x_i}.
                let mut y: Vec<u16> = (0..dims)
                    .map(|d| match ctx.rng.below(4) {
                        0 => xa[d],
                        1 => xb[d],
                        2 => xd[d],
                        _ => xx[d],
                    })
                    .collect();

                // Shaking.
                if ctx.rng.chance(self.shake_rate) {
                    if ctx.rng.chance(self.jump_rate) {
                        // Random-dim jump from a fresh valid sample.
                        let fresh = space.random_valid(&mut ctx.rng);
                        let d = ctx.rng.below(dims);
                        y[d] = space.config(fresh)[d];
                    } else {
                        // One-step move in N_{m(b)} applied to y (post-
                        // repair if needed below).
                        let d = ctx.rng.below(dims);
                        let card = space.params.params[d].cardinality() as i32;
                        let delta_step = match Self::neighborhood_at(b) {
                            NeighborKind::Hamming => {
                                ctx.rng.range_inclusive(-(card as i64 - 1), card as i64 - 1) as i32
                            }
                            _ => {
                                if ctx.rng.chance(0.5) {
                                    1
                                } else {
                                    -1
                                }
                            }
                        };
                        let nv = (y[d] as i32 + delta_step).clamp(0, card - 1);
                        y[d] = nv as u16;
                    }
                }

                // Repair, tabu.
                let mut idx = match space.index_of(&y) {
                    Some(i) => i,
                    None => space.repair(&y, &mut ctx.rng),
                };
                if tabu.contains(idx) {
                    // Resample: small Hamming change or fresh sample.
                    idx = if ctx.rng.chance(0.5) {
                        space
                            .random_neighbor(idx, &mut ctx.rng, NeighborKind::Hamming)
                            .unwrap_or_else(|| space.random_valid(&mut ctx.rng))
                    } else {
                        space.random_valid(&mut ctx.rng)
                    };
                }

                // Evaluate and accept (SA under budget-decayed T).
                let f_y = match ctx.evaluate(idx) {
                    Some(v) => v,
                    None => continue,
                };
                let temp = Cooling::at_budget(self.t0 + reheat, self.lambda, self.t_min, b);
                if metropolis_accept(fit[t_idx], f_y, temp, &mut ctx.rng) {
                    pop[t_idx] = idx;
                    fit[t_idx] = f_y;
                    tabu.push(idx);
                }
                if f_y < best_seen {
                    best_seen = f_y;
                    stagnation = 0;
                    reheat = 0.0;
                } else {
                    stagnation += 1;
                }
            }

            // Stagnation: reinit the worst rho*p individuals, mild reheat.
            if stagnation > self.stagnation_limit {
                let k = ((self.restart_ratio * p as f64).ceil() as usize).max(1);
                let mut order: Vec<usize> = (0..pop.len()).collect();
                order.sort_by(|&a, &c| fit[c].partial_cmp(&fit[a]).unwrap()); // worst first
                // Reinit as one batch (stream-preservation argument: see
                // TuningContext::evaluate_random_draws).
                let targets: Vec<usize> = order.iter().take(k).copied().collect();
                for (&t_idx, (f_idx, f)) in
                    targets.iter().zip(ctx.evaluate_random_draws(targets.len()))
                {
                    pop[t_idx] = f_idx;
                    fit[t_idx] = f.unwrap_or(f64::INFINITY);
                }
                reheat = 0.3;
                stagnation = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn defaults_match_paper() {
        let a = AdaptiveTabuGreyWolf::default();
        assert_eq!(a.population, 8);
        assert_eq!(a.tabu_factor * a.population, 24); // L = 3p
        assert!((a.shake_rate - 0.2).abs() < 1e-12);
        assert!((a.jump_rate - 0.15).abs() < 1e-12);
        assert_eq!(a.stagnation_limit, 80);
        assert!((a.restart_ratio - 0.3).abs() < 1e-12);
        assert!((a.lambda - 5.0).abs() < 1e-12);
        assert!((a.t_min - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn strong_on_convolution() {
        let cache = testutil::conv_cache();
        let mut a = AdaptiveTabuGreyWolf::default();
        let (best, _) = testutil::run_on(&mut a, &cache, 600.0, 30);
        let sorted = cache.sorted_times();
        let p10 = sorted[sorted.len() / 10];
        assert!(best < p10, "best {} p10 {}", best, p10);
    }

    #[test]
    fn neighborhood_schedule_coarse_to_strict() {
        assert_eq!(
            AdaptiveTabuGreyWolf::neighborhood_at(0.1),
            NeighborKind::Hamming
        );
        assert_eq!(
            AdaptiveTabuGreyWolf::neighborhood_at(0.9),
            NeighborKind::Adjacent
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let cache = testutil::conv_cache();
        let a = testutil::run_on(&mut AdaptiveTabuGreyWolf::default(), &cache, 200.0, 31);
        let b = testutil::run_on(&mut AdaptiveTabuGreyWolf::default(), &cache, 200.0, 31);
        assert_eq!(a, b);
    }
}
