//! Basin hopping: local descent to a basin floor, Metropolis-accepted jumps
//! between basins (Kernel Tuner ships a scipy-inspired variant).

use super::components::{metropolis_accept, Cooling};
use super::{HyperParamDomain, Optimizer};
use crate::searchspace::NeighborKind;
use crate::tuning::TuningContext;

/// Sweepable hyperparameter grid.
const DOMAINS: &[HyperParamDomain] = &[
    HyperParamDomain::new("t0", 0.4, &[0.2, 0.4, 0.8]),
    HyperParamDomain::new("alpha", 0.99, &[0.98, 0.99, 0.999]),
    HyperParamDomain::new("jump_dims", 2.0, &[1.0, 2.0, 3.0, 4.0]),
];

#[derive(Debug)]
pub struct BasinHopping {
    pub t0: f64,
    pub alpha: f64,
    pub jump_dims: usize,
    pub descent_neighbor: NeighborKind,
}

impl Default for BasinHopping {
    fn default() -> Self {
        BasinHopping {
            t0: 0.4,
            alpha: 0.99,
            jump_dims: 2,
            descent_neighbor: NeighborKind::Adjacent,
        }
    }
}

impl BasinHopping {
    fn descend(&self, ctx: &mut TuningContext, start: u32, f_start: f64) -> (u32, f64) {
        let space = ctx.space_handle();
        let mut cur = start;
        let mut f_cur = f_start;
        loop {
            if ctx.budget_exhausted() {
                return (cur, f_cur);
            }
            let mut improved = false;
            // Borrowed CSR row: no per-step neighbor allocation.
            for &n in space.neighbors_of(cur, self.descent_neighbor) {
                if ctx.budget_exhausted() {
                    return (cur, f_cur);
                }
                if let Some(f) = ctx.evaluate(n) {
                    if f < f_cur {
                        cur = n;
                        f_cur = f;
                        improved = true;
                        break; // first improvement
                    }
                }
            }
            if !improved {
                return (cur, f_cur);
            }
        }
    }
}

impl Optimizer for BasinHopping {
    fn name(&self) -> &str {
        "basin_hopping"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "t0" => self.t0 = value,
            "alpha" => self.alpha = value,
            "jump_dims" => self.jump_dims = (value as usize).max(1),
            _ => return false,
        }
        true
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        DOMAINS
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let space = ctx.space_handle();
        let dims = space.dims();
        let mut cooling = Cooling::new(self.t0, self.alpha, 1e-4);
        let start = space.random_valid(&mut ctx.rng);
        let f_start = ctx.evaluate(start).unwrap_or(f64::INFINITY);
        let (mut basin, mut f_basin) = self.descend(ctx, start, f_start);

        while !ctx.budget_exhausted() {
            // Jump: perturb a few dimensions.
            let mut probe = space.config(basin).to_vec();
            for _ in 0..self.jump_dims {
                let d = ctx.rng.below(dims);
                probe[d] = ctx.rng.below(space.params.params[d].cardinality()) as u16;
            }
            let jumped = match space.index_of(&probe) {
                Some(i) => i,
                None => {
                    let mut rng = ctx.rng.fork(0xBA51);
                    space.repair(&probe, &mut rng)
                }
            };
            let f_jumped = match ctx.evaluate(jumped) {
                Some(v) => v,
                None => continue,
            };
            let (new_basin, f_new) = self.descend(ctx, jumped, f_jumped);
            if metropolis_accept(f_basin, f_new, cooling.temperature(), &mut ctx.rng) {
                basin = new_basin;
                f_basin = f_new;
            }
            cooling.step();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn hops_below_median() {
        let cache = testutil::conv_cache();
        let mut bh = BasinHopping::default();
        let (best, _) = testutil::run_on(&mut bh, &cache, 600.0, 15);
        assert!(best < cache.median_ms);
    }
}
