//! Differential evolution — pyATF's best-performing optimizer (the paper's
//! third human-designed baseline, used with pyATF 0.0.9 defaults).
//!
//! DE/rand/1/bin adapted to the discrete index grid: donor vectors are
//! formed in value-index space, rounded and clamped to each dimension's
//! cardinality, then constraint-repaired. pyATF exposes no hyperparameter
//! tuning (the paper notes this), so the canonical NP=20, F=0.7, CR=0.9
//! are the registry defaults; the knobs are nonetheless declared as
//! [`HyperParamDomain`]s so `hypertune` sweeps can explore what pyATF
//! could not.
//!
//! `run` keeps pyATF's *asynchronous* update rule (each selection feeds
//! the next donor draw), which is inherently sequential — only the initial
//! population is batch-evaluated (bit-identical: sampling happens up front
//! and evaluation draws no randomness). The ask/tell `suggest`/`observe`
//! path additionally offers a *synchronous* generation variant — all
//! trials bred from the frozen population, submitted as one batch — for
//! drivers that fan generations out; it is deterministic but a different
//! (standard) DE flavor, so `run` does not use it.

use super::{HyperParamDomain, Optimizer};
use crate::searchspace::SearchSpace;
use crate::tuning::TuningContext;

/// Sweepable hyperparameter grid around the pyATF 0.0.9 defaults.
const DOMAINS: &[HyperParamDomain] = &[
    HyperParamDomain::new("population_size", 20.0, &[10.0, 20.0, 40.0]),
    HyperParamDomain::new("f", 0.7, &[0.5, 0.7, 0.9]),
    HyperParamDomain::new("cr", 0.9, &[0.7, 0.9, 1.0]),
];

#[derive(Debug)]
pub struct DifferentialEvolution {
    pub population_size: usize,
    pub f: f64,
    pub cr: f64,
    state: State,
}

impl Default for DifferentialEvolution {
    fn default() -> Self {
        DifferentialEvolution { population_size: 20, f: 0.7, cr: 0.9, state: State::Fresh }
    }
}

/// Ask/tell phase (synchronous-generation variant).
#[derive(Debug, Default)]
enum State {
    #[default]
    Fresh,
    AwaitInit,
    Ready {
        pop: Vec<u32>,
        fit: Vec<f64>,
    },
    AwaitGeneration {
        pop: Vec<u32>,
        fit: Vec<f64>,
    },
}

impl DifferentialEvolution {
    /// Breed one trial for target `t` from the given (frozen or live)
    /// population — the shared production step of both execution styles.
    fn trial(&self, space: &SearchSpace, pop: &[u32], t: usize, ctx: &mut TuningContext) -> u32 {
        let dims = space.dims();
        // Three distinct donors != target.
        let (mut a, mut b, mut c) = (t, t, t);
        while a == t {
            a = ctx.rng.below(pop.len());
        }
        while b == t || b == a {
            b = ctx.rng.below(pop.len());
        }
        while c == t || c == a || c == b {
            c = ctx.rng.below(pop.len());
        }
        let (xa, xb, xc) = (
            space.config(pop[a]).to_vec(),
            space.config(pop[b]).to_vec(),
            space.config(pop[c]).to_vec(),
        );
        let xt = space.config(pop[t]).to_vec();
        // Mutation + binomial crossover in index space.
        let j_rand = ctx.rng.below(dims);
        let mut trial: Vec<u16> = Vec::with_capacity(dims);
        for d in 0..dims {
            let card = space.params.params[d].cardinality() as f64;
            let v = if d == j_rand || ctx.rng.chance(self.cr) {
                let donor = xa[d] as f64 + self.f * (xb[d] as f64 - xc[d] as f64);
                donor.round().clamp(0.0, card - 1.0) as u16
            } else {
                xt[d]
            };
            trial.push(v);
        }
        match space.index_of(&trial) {
            Some(i) => i,
            None => {
                let mut rng = ctx.rng.fork(t as u64);
                space.repair(&trial, &mut rng)
            }
        }
    }
}

impl Optimizer for DifferentialEvolution {
    fn name(&self) -> &str {
        "de"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "population_size" => self.population_size = (value as usize).max(4),
            "f" => self.f = value,
            "cr" => self.cr = value,
            _ => return false,
        }
        true
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        DOMAINS
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let space = ctx.space_handle();
        let np = self.population_size.max(4);

        // Initial population in one batch (stream-preservation argument:
        // see TuningContext::evaluate_random_sample).
        let mut pop: Vec<u32> = Vec::with_capacity(np);
        let mut fit: Vec<f64> = Vec::with_capacity(np);
        for (i, f) in ctx.evaluate_random_sample(np) {
            pop.push(i);
            fit.push(f.unwrap_or(f64::INFINITY));
        }

        while !ctx.budget_exhausted() {
            for t in 0..pop.len() {
                if ctx.budget_exhausted() {
                    return;
                }
                let idx = self.trial(&space, &pop, t, ctx);
                let f_trial = ctx.evaluate(idx).unwrap_or(f64::INFINITY);
                if f_trial <= fit[t] {
                    pop[t] = idx;
                    fit[t] = f_trial;
                }
            }
        }
    }

    fn suggest(&mut self, ctx: &mut TuningContext, _limit: usize) -> Option<Vec<u32>> {
        let space = ctx.space_handle();
        match std::mem::take(&mut self.state) {
            State::Fresh => {
                self.state = State::AwaitInit;
                Some(space.random_sample(&mut ctx.rng, self.population_size.max(4)))
            }
            State::Ready { pop, fit } => {
                let trials: Vec<u32> =
                    (0..pop.len()).map(|t| self.trial(&space, &pop, t, ctx)).collect();
                self.state = State::AwaitGeneration { pop, fit };
                Some(trials)
            }
            awaiting => {
                // suggest() twice without an observe(): keep the phase.
                self.state = awaiting;
                Some(Vec::new())
            }
        }
    }

    fn observe(&mut self, _ctx: &mut TuningContext, batch: &[u32], results: &[Option<f64>]) {
        match std::mem::take(&mut self.state) {
            State::AwaitInit => {
                self.state = State::Ready {
                    pop: batch.to_vec(),
                    fit: results.iter().map(|v| v.unwrap_or(f64::INFINITY)).collect(),
                };
            }
            State::AwaitGeneration { mut pop, mut fit } => {
                // Synchronous greedy selection against the frozen parents.
                for (t, (&idx, r)) in batch.iter().zip(results).enumerate() {
                    let f_trial = r.unwrap_or(f64::INFINITY);
                    if f_trial <= fit[t] {
                        pop[t] = idx;
                        fit[t] = f_trial;
                    }
                }
                self.state = State::Ready { pop, fit };
            }
            state => self.state = state,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::{run_ask_tell, testutil};

    #[test]
    fn selection_is_greedy_never_regresses() {
        let cache = testutil::conv_cache();
        let mut ctx = crate::tuning::TuningContext::new(&cache, 400.0, 8);
        DifferentialEvolution::default().run(&mut ctx);
        assert!(ctx
            .trajectory
            .windows(2)
            .all(|w| w[1].1 <= w[0].1));
    }

    #[test]
    fn beats_median_with_budget() {
        let cache = testutil::conv_cache();
        let mut de = DifferentialEvolution::default();
        let (best, _) = testutil::run_on(&mut de, &cache, 600.0, 9);
        assert!(best < cache.median_ms);
    }

    #[test]
    fn init_population_goes_through_batch_path() {
        let cache = testutil::conv_cache();
        let mut ctx = crate::tuning::TuningContext::new(&cache, 300.0, 10);
        DifferentialEvolution::default().run(&mut ctx);
        assert!(ctx.batch_calls() >= 1);
        assert_eq!(ctx.largest_batch(), 20, "NP=20 init in one batch");
    }

    #[test]
    fn synchronous_ask_tell_variant_is_deterministic() {
        let cache = testutil::conv_cache();
        let run = |seed: u64| {
            let mut ctx = crate::tuning::TuningContext::new(&cache, 300.0, seed);
            let mut de = DifferentialEvolution::default();
            assert!(run_ask_tell(&mut de, &mut ctx), "DE must support ask/tell");
            (ctx.trajectory.clone(), ctx.unique_evals())
        };
        assert_eq!(run(3), run(3));
        let (tr, evals) = run(4);
        assert!(!tr.is_empty() && evals > 20);
    }
}
