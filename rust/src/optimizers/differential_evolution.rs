//! Differential evolution — pyATF's best-performing optimizer (the paper's
//! third human-designed baseline, used with pyATF 0.0.9 defaults).
//!
//! DE/rand/1/bin adapted to the discrete index grid: donor vectors are
//! formed in value-index space, rounded and clamped to each dimension's
//! cardinality, then constraint-repaired. pyATF exposes no hyperparameter
//! tuning (the paper notes this), so the canonical NP=20, F=0.7, CR=0.9
//! are used as-is.

use super::Optimizer;
use crate::tuning::TuningContext;

#[derive(Debug)]
pub struct DifferentialEvolution {
    pub population_size: usize,
    pub f: f64,
    pub cr: f64,
}

impl Default for DifferentialEvolution {
    fn default() -> Self {
        DifferentialEvolution { population_size: 20, f: 0.7, cr: 0.9 }
    }
}

impl Optimizer for DifferentialEvolution {
    fn name(&self) -> &str {
        "de"
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let dims = ctx.space().dims();
        let np = self.population_size.max(4);

        let mut pop: Vec<u32> = ctx.space().random_sample(&mut ctx.rng, np);
        let mut fit: Vec<f64> = Vec::with_capacity(np);
        for &i in &pop {
            if ctx.budget_exhausted() {
                return;
            }
            fit.push(ctx.evaluate(i).unwrap_or(f64::INFINITY));
        }

        while !ctx.budget_exhausted() {
            for t in 0..pop.len() {
                if ctx.budget_exhausted() {
                    return;
                }
                // Three distinct donors != target.
                let (mut a, mut b, mut c) = (t, t, t);
                while a == t {
                    a = ctx.rng.below(pop.len());
                }
                while b == t || b == a {
                    b = ctx.rng.below(pop.len());
                }
                while c == t || c == a || c == b {
                    c = ctx.rng.below(pop.len());
                }
                let (xa, xb, xc) = (
                    ctx.space().config(pop[a]).to_vec(),
                    ctx.space().config(pop[b]).to_vec(),
                    ctx.space().config(pop[c]).to_vec(),
                );
                let xt = ctx.space().config(pop[t]).to_vec();
                // Mutation + binomial crossover in index space.
                let j_rand = ctx.rng.below(dims);
                let mut trial: Vec<u16> = Vec::with_capacity(dims);
                for d in 0..dims {
                    let card = ctx.space().params.params[d].cardinality() as f64;
                    let v = if d == j_rand || ctx.rng.chance(self.cr) {
                        let donor =
                            xa[d] as f64 + self.f * (xb[d] as f64 - xc[d] as f64);
                        donor.round().clamp(0.0, card - 1.0) as u16
                    } else {
                        xt[d]
                    };
                    trial.push(v);
                }
                let idx = match ctx.space().index_of(&trial) {
                    Some(i) => i,
                    None => {
                        let mut rng = ctx.rng.fork(t as u64);
                        ctx.space().repair(&trial, &mut rng)
                    }
                };
                let f_trial = ctx.evaluate(idx).unwrap_or(f64::INFINITY);
                if f_trial <= fit[t] {
                    pop[t] = idx;
                    fit[t] = f_trial;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn selection_is_greedy_never_regresses() {
        let cache = testutil::conv_cache();
        let mut ctx = crate::tuning::TuningContext::new(&cache, 400.0, 8);
        DifferentialEvolution::default().run(&mut ctx);
        assert!(ctx
            .trajectory
            .windows(2)
            .all(|w| w[1].1 <= w[0].1));
    }

    #[test]
    fn beats_median_with_budget() {
        let cache = testutil::conv_cache();
        let mut de = DifferentialEvolution::default();
        let (best, _) = testutil::run_on(&mut de, &cache, 600.0, 9);
        assert!(best < cache.median_ms);
    }
}
