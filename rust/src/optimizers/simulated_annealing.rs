//! Simulated annealing — Kernel Tuner's tuned SA baseline.
//!
//! Single-solution local search over the Hamming neighborhood with
//! Metropolis acceptance on relative deltas, geometric cooling tied to
//! restarts, and re-heating restarts on stagnation. Hyperparameters follow
//! the 7-day tuning of Willemsen et al. 2025b in spirit: moderate initial
//! temperature, slow cooling, generous stagnation window.

use super::components::{metropolis_accept, Cooling};
use super::{HyperParamDomain, Optimizer};
use crate::searchspace::NeighborKind;
use crate::tuning::TuningContext;

/// Sweepable hyperparameter grid around the Willemsen-2025b tuned point.
const DOMAINS: &[HyperParamDomain] = &[
    HyperParamDomain::new("t0", 0.6, &[0.2, 0.4, 0.6, 1.0]),
    HyperParamDomain::new("alpha", 0.995, &[0.98, 0.99, 0.995, 0.999]),
    HyperParamDomain::new("t_min", 1e-4, &[1e-5, 1e-4, 1e-3]),
    HyperParamDomain::new("stagnation_limit", 150.0, &[50.0, 100.0, 150.0, 300.0]),
];

#[derive(Debug)]
pub struct SimulatedAnnealing {
    pub t0: f64,
    pub alpha: f64,
    pub t_min: f64,
    pub stagnation_limit: u32,
    pub neighbor: NeighborKind,
}

impl Default for SimulatedAnnealing {
    fn default() -> Self {
        SimulatedAnnealing {
            t0: 0.6,
            alpha: 0.995,
            t_min: 1e-4,
            stagnation_limit: 150,
            neighbor: NeighborKind::Hamming,
        }
    }
}

impl Optimizer for SimulatedAnnealing {
    fn name(&self) -> &str {
        "sa"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "t0" => self.t0 = value,
            "alpha" => self.alpha = value,
            "t_min" => self.t_min = value,
            "stagnation_limit" => self.stagnation_limit = value as u32,
            _ => return false,
        }
        true
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        DOMAINS
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let space = ctx.space_handle();
        let mut cooling = Cooling::new(self.t0, self.alpha, self.t_min);
        let mut current = space.random_valid(&mut ctx.rng);
        let mut f_cur = loop {
            match ctx.evaluate(current) {
                Some(v) => break v,
                None => {
                    if ctx.budget_exhausted() {
                        return;
                    }
                    current = space.random_valid(&mut ctx.rng);
                }
            }
        };
        let mut stagnation = 0u32;

        while !ctx.budget_exhausted() {
            let cand = match space.random_neighbor(current, &mut ctx.rng, self.neighbor) {
                Some(c) => c,
                None => space.random_valid(&mut ctx.rng),
            };
            match ctx.evaluate(cand) {
                Some(f_cand) => {
                    if metropolis_accept(f_cur, f_cand, cooling.temperature(), &mut ctx.rng) {
                        if f_cand < f_cur {
                            stagnation = 0;
                        } else {
                            stagnation += 1;
                        }
                        current = cand;
                        f_cur = f_cand;
                    } else {
                        stagnation += 1;
                    }
                }
                None => stagnation += 1,
            }
            cooling.step();
            if stagnation > self.stagnation_limit {
                // Restart with re-heating.
                current = space.random_valid(&mut ctx.rng);
                if let Some(v) = ctx.evaluate(current) {
                    f_cur = v;
                }
                cooling.reset();
                stagnation = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn improves_over_first_sample() {
        let cache = testutil::conv_cache();
        let mut sa = SimulatedAnnealing::default();
        let (best, evals) = testutil::run_on(&mut sa, &cache, 500.0, 3);
        assert!(best.is_finite());
        assert!(evals > 20);
        assert!(best < cache.median_ms, "best {} median {}", best, cache.median_ms);
    }

    #[test]
    fn restart_path_is_exercised() {
        // Tiny stagnation limit forces restarts within the budget.
        let cache = testutil::conv_cache();
        let mut sa = SimulatedAnnealing {
            stagnation_limit: 2,
            ..Default::default()
        };
        let (best, _) = testutil::run_on(&mut sa, &cache, 300.0, 4);
        assert!(best.is_finite());
    }
}
