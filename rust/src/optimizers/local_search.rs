//! Local-search strategies: greedy iterated local search and multi-start
//! local search — two of Kernel Tuner's classical single-solution methods.

use super::{neighbor_kind_from_code, HyperParamDomain, Optimizer};
use crate::searchspace::NeighborKind;
use crate::tuning::TuningContext;

/// Greedy-ILS sweepable grid (`neighbor` uses the 0/1/2 kind coding of
/// [`neighbor_kind_from_code`]; default Adjacent = 1).
const ILS_DOMAINS: &[HyperParamDomain] = &[
    HyperParamDomain::new("kick_strength", 3.0, &[1.0, 2.0, 3.0, 4.0, 6.0]),
    HyperParamDomain::new("neighbor", 1.0, &[0.0, 1.0, 2.0]),
];

/// MLS sweepable grid (default neighborhood Hamming = 0).
const MLS_DOMAINS: &[HyperParamDomain] =
    &[HyperParamDomain::new("neighbor", 0.0, &[0.0, 1.0, 2.0])];

/// Greedy ILS: best-improvement hill climbing to a local optimum, then a
/// perturbation kick (random multi-dim jump) and repeat.
#[derive(Debug)]
pub struct GreedyIls {
    pub neighbor: NeighborKind,
    /// Dimensions perturbed by a kick.
    pub kick_strength: usize,
}

impl Default for GreedyIls {
    fn default() -> Self {
        GreedyIls { neighbor: NeighborKind::Adjacent, kick_strength: 3 }
    }
}

impl GreedyIls {
    /// Best-improvement descent from `start`; returns the local optimum.
    fn descend(&self, ctx: &mut TuningContext, start: u32, f_start: f64) -> (u32, f64) {
        let space = ctx.space_handle();
        let mut cur = start;
        let mut f_cur = f_start;
        loop {
            if ctx.budget_exhausted() {
                return (cur, f_cur);
            }
            // Borrowed CSR row: no per-step neighbor allocation.
            let neigh = space.neighbors_of(cur, self.neighbor);
            let mut best_n: Option<(u32, f64)> = None;
            for &n in neigh {
                if ctx.budget_exhausted() {
                    return (cur, f_cur);
                }
                if let Some(f) = ctx.evaluate(n) {
                    if f < best_n.map(|(_, v)| v).unwrap_or(f_cur) {
                        best_n = Some((n, f));
                    }
                }
            }
            match best_n {
                Some((n, f)) => {
                    cur = n;
                    f_cur = f;
                }
                None => return (cur, f_cur), // local optimum
            }
        }
    }
}

impl Optimizer for GreedyIls {
    fn name(&self) -> &str {
        "greedy_ils"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "kick_strength" => self.kick_strength = (value as usize).max(1),
            "neighbor" => match neighbor_kind_from_code(value) {
                Some(k) => self.neighbor = k,
                None => return false,
            },
            _ => return false,
        }
        true
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        ILS_DOMAINS
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let space = ctx.space_handle();
        let dims = space.dims();
        let mut cur = space.random_valid(&mut ctx.rng);
        let mut f_cur = match ctx.evaluate(cur) {
            Some(v) => v,
            None => f64::INFINITY,
        };
        while !ctx.budget_exhausted() {
            let (lo, f_lo) = self.descend(ctx, cur, f_cur);
            // Kick: perturb `kick_strength` random dimensions, repair.
            let mut probe = space.config(lo).to_vec();
            for _ in 0..self.kick_strength {
                let d = ctx.rng.below(dims);
                probe[d] = ctx.rng.below(space.params.params[d].cardinality()) as u16;
            }
            let kicked = match space.index_of(&probe) {
                Some(i) => i,
                None => {
                    let mut rng = ctx.rng.fork(0xB00);
                    space.repair(&probe, &mut rng)
                }
            };
            let f_kicked = ctx.evaluate(kicked).unwrap_or(f64::INFINITY);
            // Accept the kicked point as the new start (restart-style ILS);
            // the incumbent best is tracked by the context regardless.
            if f_kicked.is_finite() {
                cur = kicked;
                f_cur = f_kicked;
            } else {
                cur = lo;
                f_cur = f_lo;
            }
        }
    }
}

/// Multi-start local search: repeated first-improvement hill climbing from
/// fresh random configurations.
#[derive(Debug)]
pub struct MultiStartLocalSearch {
    pub neighbor: NeighborKind,
}

impl Default for MultiStartLocalSearch {
    fn default() -> Self {
        MultiStartLocalSearch { neighbor: NeighborKind::Hamming }
    }
}

impl Optimizer for MultiStartLocalSearch {
    fn name(&self) -> &str {
        "mls"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() || key != "neighbor" {
            return false;
        }
        match neighbor_kind_from_code(value) {
            Some(k) => {
                self.neighbor = k;
                true
            }
            None => false,
        }
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        MLS_DOMAINS
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let space = ctx.space_handle();
        while !ctx.budget_exhausted() {
            let start = space.random_valid(&mut ctx.rng);
            let mut cur = start;
            let mut f_cur = match ctx.evaluate(cur) {
                Some(v) => v,
                None => continue,
            };
            // First-improvement descent with randomized neighbor order.
            'descent: loop {
                if ctx.budget_exhausted() {
                    return;
                }
                // Owned copy of the CSR row: the shuffle needs mutation,
                // but the enumeration cost is gone (same row, same order,
                // so the forked-RNG shuffle stream is unchanged).
                let mut neigh = space.neighbors_of(cur, self.neighbor).to_vec();
                let mut rng = ctx.rng.fork(cur as u64);
                rng.shuffle(&mut neigh);
                for n in neigh {
                    if ctx.budget_exhausted() {
                        return;
                    }
                    if let Some(f) = ctx.evaluate(n) {
                        if f < f_cur {
                            cur = n;
                            f_cur = f;
                            continue 'descent;
                        }
                    }
                }
                break; // local optimum reached
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn greedy_ils_descends() {
        let cache = testutil::conv_cache();
        let mut ils = GreedyIls::default();
        let (best, _) = testutil::run_on(&mut ils, &cache, 600.0, 12);
        assert!(best < cache.median_ms);
    }

    #[test]
    fn mls_descends() {
        let cache = testutil::conv_cache();
        let mut mls = MultiStartLocalSearch::default();
        let (best, _) = testutil::run_on(&mut mls, &cache, 600.0, 13);
        assert!(best < cache.median_ms);
    }

    #[test]
    fn local_optimum_is_real() {
        // After a full descent with a huge budget from a fixed start, no
        // Hamming neighbor of the final best should be better (on observed
        // values) — checked via context state.
        let cache = testutil::conv_cache();
        let mut ctx = crate::tuning::TuningContext::new(&cache, 3000.0, 14);
        MultiStartLocalSearch::default().run(&mut ctx);
        let (best_i, best_v) = ctx.best().unwrap();
        for n in ctx.space().neighbors(best_i, NeighborKind::Hamming) {
            if let Some(Some(f)) = ctx.peek(n) {
                assert!(f >= best_v);
            }
        }
    }
}
