//! Shared metaheuristic components used by hand-written and generated
//! optimizers alike: tabu lists, k-NN surrogate pre-screening, cooling
//! schedules, and evaluation history. The LLaMEA genome interpreter
//! (`crate::llamea::interpreter`) composes optimizers from exactly these
//! parts, which is what makes "generated code" executable in Rust.
//!
//! All components are evaluation-agnostic: they never touch the
//! [`TuningContext`](crate::tuning::TuningContext) or its backend, only
//! indices, configs and observed values — so they compose identically
//! under sequential (`evaluate`) and ask/tell batch (`evaluate_batch`)
//! execution.

use std::collections::{HashSet, VecDeque};

/// Fixed-capacity tabu list over configuration indices.
#[derive(Debug, Clone)]
pub struct TabuList {
    order: VecDeque<u32>,
    members: HashSet<u32>,
    capacity: usize,
}

impl TabuList {
    pub fn new(capacity: usize) -> TabuList {
        TabuList {
            order: VecDeque::with_capacity(capacity + 1),
            members: HashSet::with_capacity(capacity * 2),
            capacity: capacity.max(1),
        }
    }

    pub fn push(&mut self, i: u32) {
        if self.members.insert(i) {
            self.order.push_back(i);
            if self.order.len() > self.capacity {
                let old = self.order.pop_front().unwrap();
                self.members.remove(&old);
            }
        }
    }

    #[inline]
    pub fn contains(&self, i: u32) -> bool {
        self.members.contains(&i)
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

/// Evaluation history: (config index, value) pairs plus the raw config
/// vectors for Hamming-space queries.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub entries: Vec<(u32, f64)>,
    configs: Vec<Vec<u16>>,
}

impl History {
    pub fn push(&mut self, idx: u32, cfg: &[u16], value: f64) {
        self.entries.push((idx, value));
        self.configs.push(cfg.to_vec());
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Best (lowest-value) entry.
    pub fn best(&self) -> Option<(u32, f64)> {
        self.entries
            .iter()
            .copied()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }
}

/// Light k-NN surrogate over Hamming distance (HybridVNDX component (ii)).
///
/// Predicts a candidate's value as the mean of its `k` nearest evaluated
/// configurations, scanning only the most recent `window` history entries —
/// "light" in both senses the paper intends: cheap and recency-biased.
#[derive(Debug, Clone)]
pub struct KnnSurrogate {
    pub k: usize,
    pub window: usize,
}

impl Default for KnnSurrogate {
    fn default() -> Self {
        KnnSurrogate { k: 5, window: 512 }
    }
}

impl KnnSurrogate {
    pub fn new(k: usize, window: usize) -> Self {
        KnnSurrogate { k: k.max(1), window: window.max(1) }
    }

    /// Predicted value of `cfg`, or None when the history is empty.
    pub fn predict(&self, history: &History, cfg: &[u16]) -> Option<f64> {
        if history.is_empty() {
            return None;
        }
        let start = history.len().saturating_sub(self.window);
        // (distance, value) of the k nearest in the window.
        let mut nearest: Vec<(usize, f64)> = Vec::with_capacity(self.k + 1);
        for j in start..history.len() {
            let d = hamming(&history.configs[j], cfg);
            let v = history.entries[j].1;
            if nearest.len() < self.k {
                nearest.push((d, v));
                nearest.sort_by_key(|&(d, _)| d);
            } else if d < nearest.last().unwrap().0 {
                nearest.pop();
                nearest.push((d, v));
                nearest.sort_by_key(|&(d, _)| d);
            }
        }
        let sum: f64 = nearest.iter().map(|&(_, v)| v).sum();
        Some(sum / nearest.len() as f64)
    }
}

#[inline]
pub fn hamming(a: &[u16], b: &[u16]) -> usize {
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

/// Exponential cooling schedule with floor (shared by SA-flavoured accept
/// rules): `T(step) = max(T_min, T0 * alpha^step)`.
#[derive(Debug, Clone)]
pub struct Cooling {
    pub t0: f64,
    pub alpha: f64,
    pub t_min: f64,
    t: f64,
}

impl Cooling {
    pub fn new(t0: f64, alpha: f64, t_min: f64) -> Cooling {
        Cooling { t0, alpha, t_min, t: t0 }
    }

    #[inline]
    pub fn temperature(&self) -> f64 {
        self.t.max(self.t_min)
    }

    #[inline]
    pub fn step(&mut self) {
        self.t *= self.alpha;
    }

    pub fn reset(&mut self) {
        self.t = self.t0;
    }

    /// Budget-coupled temperature (AdaptiveTabuGreyWolf):
    /// `max(T_min, T0 * exp(-lambda * b))` for budget fraction `b`.
    pub fn at_budget(t0: f64, lambda: f64, t_min: f64, b: f64) -> f64 {
        (t0 * (-lambda * b).exp()).max(t_min)
    }
}

/// Metropolis acceptance on *relative* deltas: runtimes span orders of
/// magnitude across spaces, so `delta` is normalized by the incumbent.
#[inline]
pub fn metropolis_accept(
    current: f64,
    candidate: f64,
    temperature: f64,
    rng: &mut crate::util::rng::Rng,
) -> bool {
    if candidate <= current {
        return true;
    }
    let delta = (candidate - current) / current.max(1e-12);
    rng.chance((-delta / temperature.max(1e-12)).exp())
}

/// Bounded elite archive (HybridVNDX component (iii)): keeps the best `cap`
/// evaluated configurations for recombination.
#[derive(Debug, Clone)]
pub struct EliteArchive {
    pub cap: usize,
    /// Sorted ascending by value.
    entries: Vec<(u32, f64)>,
}

impl EliteArchive {
    pub fn new(cap: usize) -> EliteArchive {
        EliteArchive { cap: cap.max(1), entries: Vec::new() }
    }

    pub fn push(&mut self, idx: u32, value: f64) {
        if self.entries.iter().any(|&(i, _)| i == idx) {
            return;
        }
        let pos = self
            .entries
            .partition_point(|&(_, v)| v <= value);
        self.entries.insert(pos, (idx, value));
        self.entries.truncate(self.cap);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, rank: usize) -> Option<(u32, f64)> {
        self.entries.get(rank).copied()
    }

    pub fn random(&self, rng: &mut crate::util::rng::Rng) -> Option<(u32, f64)> {
        if self.entries.is_empty() {
            None
        } else {
            Some(self.entries[rng.below(self.entries.len())])
        }
    }

    /// Uniform crossover of two random elites, returning a raw genotype.
    pub fn crossover_child(
        &self,
        space: &crate::searchspace::SearchSpace,
        rng: &mut crate::util::rng::Rng,
    ) -> Option<Vec<u16>> {
        if self.entries.len() < 2 {
            return None;
        }
        let a = self.entries[rng.below(self.entries.len())].0;
        let b = self.entries[rng.below(self.entries.len())].0;
        let (ca, cb) = (space.config(a), space.config(b));
        Some(
            ca.iter()
                .zip(cb)
                .map(|(&x, &y)| if rng.chance(0.5) { x } else { y })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tabu_evicts_fifo() {
        let mut t = TabuList::new(3);
        for i in 0..5 {
            t.push(i);
        }
        assert!(!t.contains(0));
        assert!(!t.contains(1));
        assert!(t.contains(2) && t.contains(3) && t.contains(4));
        assert_eq!(t.len(), 3);
        // Re-push of a member does not duplicate.
        t.push(4);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn knn_predicts_nearest_mean() {
        let mut h = History::default();
        h.push(0, &[0, 0, 0], 10.0);
        h.push(1, &[0, 0, 1], 20.0);
        h.push(2, &[5, 5, 5], 1000.0);
        let s = KnnSurrogate::new(2, 512);
        // Nearest two of [0,0,0] are entries 0 and 1.
        let p = s.predict(&h, &[0, 0, 0]).unwrap();
        assert!((p - 15.0).abs() < 1e-9);
        assert!(s.predict(&History::default(), &[0]).is_none());
    }

    #[test]
    fn knn_window_limits_scan() {
        let mut h = History::default();
        for i in 0..100 {
            h.push(i, &[i as u16], 1.0);
        }
        h.push(100, &[0], 99.0);
        let s = KnnSurrogate::new(1, 1); // only sees the last entry
        assert_eq!(s.predict(&h, &[0]).unwrap(), 99.0);
    }

    #[test]
    fn cooling_monotone_with_floor() {
        let mut c = Cooling::new(1.0, 0.5, 0.1);
        let mut prev = c.temperature();
        for _ in 0..10 {
            c.step();
            assert!(c.temperature() <= prev);
            prev = c.temperature();
        }
        assert_eq!(c.temperature(), 0.1);
        assert!(Cooling::at_budget(1.0, 5.0, 1e-4, 0.0) > Cooling::at_budget(1.0, 5.0, 1e-4, 0.5));
    }

    #[test]
    fn metropolis_always_accepts_improvement() {
        let mut rng = Rng::new(1);
        assert!(metropolis_accept(10.0, 9.0, 1e-9, &mut rng));
        // Huge worsening at tiny temperature: essentially never accepted.
        let accepted = (0..1000)
            .filter(|_| metropolis_accept(10.0, 100.0, 1e-6, &mut rng))
            .count();
        assert_eq!(accepted, 0);
    }

    #[test]
    fn elite_archive_sorted_bounded() {
        let mut e = EliteArchive::new(3);
        for (i, v) in [(0u32, 5.0), (1, 1.0), (2, 3.0), (3, 0.5), (4, 9.0)] {
            e.push(i, v);
        }
        assert_eq!(e.len(), 3);
        assert_eq!(e.get(0).unwrap().0, 3);
        assert_eq!(e.get(1).unwrap().0, 1);
        assert_eq!(e.get(2).unwrap().0, 2);
        // Duplicate pushes ignored.
        e.push(3, 0.5);
        assert_eq!(e.len(), 3);
    }
}
