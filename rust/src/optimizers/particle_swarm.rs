//! Particle swarm optimization over the value-index grid.
//!
//! Continuous-relaxation PSO (one of Kernel Tuner's classical strategies):
//! particles hold float positions/velocities in index space; evaluation
//! rounds, clamps and repairs. Standard constriction-style coefficients.
//!
//! `run` keeps the classic *asynchronous* update (each particle sees
//! neighbors' fresh global best), batching only the initial swarm
//! evaluation (bit-identical: sampling happens up front, evaluation draws
//! no randomness). The ask/tell `suggest`/`observe` path offers the
//! *synchronous* textbook variant — every particle moves against the
//! frozen bests, the whole sweep submitted as one batch — for drivers
//! that fan iterations out.

use super::{HyperParamDomain, Optimizer};
use crate::searchspace::SearchSpace;
use crate::tuning::TuningContext;

/// Sweepable hyperparameter grid around the constriction-style defaults.
const DOMAINS: &[HyperParamDomain] = &[
    HyperParamDomain::new("swarm_size", 16.0, &[8.0, 16.0, 24.0, 32.0]),
    HyperParamDomain::new("inertia", 0.72, &[0.4, 0.6, 0.72, 0.9]),
    HyperParamDomain::new("c_personal", 1.49, &[0.5, 1.0, 1.49, 2.0]),
    HyperParamDomain::new("c_global", 1.49, &[0.5, 1.0, 1.49, 2.0]),
];

#[derive(Debug)]
pub struct ParticleSwarm {
    pub swarm_size: usize,
    pub inertia: f64,
    pub c_personal: f64,
    pub c_global: f64,
    state: State,
}

impl Default for ParticleSwarm {
    fn default() -> Self {
        ParticleSwarm {
            swarm_size: 16,
            inertia: 0.72,
            c_personal: 1.49,
            c_global: 1.49,
            state: State::Fresh,
        }
    }
}

/// The swarm of the synchronous ask/tell variant.
#[derive(Debug)]
struct Swarm {
    cards: Vec<f64>,
    pos: Vec<Vec<f64>>,
    vel: Vec<Vec<f64>>,
    p_best: Vec<(Vec<f64>, f64)>,
    g_best: (Vec<f64>, f64),
}

#[derive(Debug, Default)]
enum State {
    #[default]
    Fresh,
    AwaitInit(Swarm),
    Ready(Swarm),
    AwaitStep(Swarm),
}

impl ParticleSwarm {
    /// One velocity/position update for particle `k` against the given
    /// bests; returns the (repaired) config index to probe.
    fn advance(
        &self,
        space: &SearchSpace,
        swarm: &mut Swarm,
        k: usize,
        ctx: &mut TuningContext,
    ) -> u32 {
        let dims = space.dims();
        for d in 0..dims {
            let r1 = ctx.rng.f64();
            let r2 = ctx.rng.f64();
            swarm.vel[k][d] = self.inertia * swarm.vel[k][d]
                + self.c_personal * r1 * (swarm.p_best[k].0[d] - swarm.pos[k][d])
                + self.c_global * r2 * (swarm.g_best.0[d] - swarm.pos[k][d]);
            // Velocity clamp keeps particles on the grid.
            let vmax = swarm.cards[d] * 0.5;
            swarm.vel[k][d] = swarm.vel[k][d].clamp(-vmax, vmax);
            swarm.pos[k][d] = (swarm.pos[k][d] + swarm.vel[k][d]).clamp(0.0, swarm.cards[d] - 1.0);
        }
        let probe: Vec<u16> = swarm.pos[k].iter().map(|&x| x.round() as u16).collect();
        match space.index_of(&probe) {
            Some(i) => i,
            None => {
                let mut rng = ctx.rng.fork(k as u64);
                space.repair(&probe, &mut rng)
            }
        }
    }

    /// Fresh swarm: sampled starts, random velocities, empty bests.
    fn spawn(&self, space: &SearchSpace, ctx: &mut TuningContext) -> (Swarm, Vec<u32>) {
        let dims = space.dims();
        let cards: Vec<f64> =
            (0..dims).map(|d| space.params.params[d].cardinality() as f64).collect();
        let starts = space.random_sample(&mut ctx.rng, self.swarm_size);
        let pos: Vec<Vec<f64>> = starts
            .iter()
            .map(|&i| space.config(i).iter().map(|&v| v as f64).collect())
            .collect();
        let vel: Vec<Vec<f64>> = (0..pos.len())
            .map(|_| (0..dims).map(|d| (ctx.rng.f64() - 0.5) * cards[d] * 0.2).collect())
            .collect();
        let g_best = (pos[0].clone(), f64::INFINITY);
        let swarm = Swarm { cards, pos, vel, p_best: Vec::new(), g_best };
        (swarm, starts)
    }
}

impl Optimizer for ParticleSwarm {
    fn name(&self) -> &str {
        "pso"
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        DOMAINS
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "swarm_size" => self.swarm_size = (value as usize).max(2),
            "inertia" => self.inertia = value,
            "c_personal" => self.c_personal = value,
            "c_global" => self.c_global = value,
            _ => return false,
        }
        true
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let space = ctx.space_handle();
        let (mut swarm, starts) = self.spawn(&space, ctx);

        // Initial swarm as one batch (bit-identical to the sequential
        // loop; the context cuts at budget exhaustion).
        let fits = ctx.evaluate_batch(&starts);
        for (k, f) in fits.into_iter().enumerate() {
            let f = f.unwrap_or(f64::INFINITY);
            swarm.p_best.push((swarm.pos[k].clone(), f));
            if f < swarm.g_best.1 {
                swarm.g_best = (swarm.pos[k].clone(), f);
            }
        }

        while !ctx.budget_exhausted() {
            for k in 0..swarm.pos.len() {
                if ctx.budget_exhausted() {
                    return;
                }
                let idx = self.advance(&space, &mut swarm, k, ctx);
                let f = ctx.evaluate(idx).unwrap_or(f64::INFINITY);
                let actual: Vec<f64> = space.config(idx).iter().map(|&v| v as f64).collect();
                if f < swarm.p_best[k].1 {
                    swarm.p_best[k] = (actual.clone(), f);
                }
                if f < swarm.g_best.1 {
                    swarm.g_best = (actual, f);
                }
            }
        }
    }

    fn suggest(&mut self, ctx: &mut TuningContext, _limit: usize) -> Option<Vec<u32>> {
        let space = ctx.space_handle();
        match std::mem::take(&mut self.state) {
            State::Fresh => {
                let (swarm, starts) = self.spawn(&space, ctx);
                self.state = State::AwaitInit(swarm);
                Some(starts)
            }
            State::Ready(mut swarm) => {
                let probes: Vec<u32> = (0..swarm.pos.len())
                    .map(|k| self.advance(&space, &mut swarm, k, ctx))
                    .collect();
                self.state = State::AwaitStep(swarm);
                Some(probes)
            }
            awaiting => {
                // suggest() twice without an observe(): keep the phase.
                self.state = awaiting;
                Some(Vec::new())
            }
        }
    }

    fn observe(&mut self, ctx: &mut TuningContext, batch: &[u32], results: &[Option<f64>]) {
        let space = ctx.space_handle();
        match std::mem::take(&mut self.state) {
            State::AwaitInit(mut swarm) => {
                for (k, r) in results.iter().enumerate() {
                    let f = r.unwrap_or(f64::INFINITY);
                    swarm.p_best.push((swarm.pos[k].clone(), f));
                    if f < swarm.g_best.1 {
                        swarm.g_best = (swarm.pos[k].clone(), f);
                    }
                }
                self.state = State::Ready(swarm);
            }
            State::AwaitStep(mut swarm) => {
                // Synchronous update: all particles scored against the
                // bests they moved with.
                for (k, (&idx, r)) in batch.iter().zip(results).enumerate() {
                    let f = r.unwrap_or(f64::INFINITY);
                    let actual: Vec<f64> = space.config(idx).iter().map(|&v| v as f64).collect();
                    if f < swarm.p_best[k].1 {
                        swarm.p_best[k] = (actual.clone(), f);
                    }
                    if f < swarm.g_best.1 {
                        swarm.g_best = (actual, f);
                    }
                }
                self.state = State::Ready(swarm);
            }
            state => self.state = state,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::{run_ask_tell, testutil};

    #[test]
    fn swarm_finds_below_median() {
        let cache = testutil::conv_cache();
        let mut pso = ParticleSwarm::default();
        let (best, _) = testutil::run_on(&mut pso, &cache, 600.0, 10);
        assert!(best < cache.median_ms);
    }

    #[test]
    fn terminates_on_budget() {
        let cache = testutil::conv_cache();
        let mut pso = ParticleSwarm::default();
        let (_, evals) = testutil::run_on(&mut pso, &cache, 30.0, 11);
        assert!(evals >= 1);
    }

    #[test]
    fn init_swarm_goes_through_batch_path() {
        let cache = testutil::conv_cache();
        let mut ctx = crate::tuning::TuningContext::new(&cache, 300.0, 12);
        ParticleSwarm::default().run(&mut ctx);
        assert!(ctx.batch_calls() >= 1);
        assert_eq!(ctx.largest_batch(), 16, "full swarm in one batch");
    }

    #[test]
    fn synchronous_ask_tell_variant_is_deterministic() {
        let cache = testutil::conv_cache();
        let run = |seed: u64| {
            let mut ctx = crate::tuning::TuningContext::new(&cache, 300.0, seed);
            let mut pso = ParticleSwarm::default();
            assert!(run_ask_tell(&mut pso, &mut ctx), "PSO must support ask/tell");
            (ctx.trajectory.clone(), ctx.unique_evals())
        };
        assert_eq!(run(5), run(5));
        let (tr, _) = run(6);
        assert!(!tr.is_empty());
    }
}
