//! Particle swarm optimization over the value-index grid.
//!
//! Continuous-relaxation PSO (one of Kernel Tuner's classical strategies):
//! particles hold float positions/velocities in index space; evaluation
//! rounds, clamps and repairs. Standard constriction-style coefficients.

use super::Optimizer;
use crate::tuning::TuningContext;

#[derive(Debug)]
pub struct ParticleSwarm {
    pub swarm_size: usize,
    pub inertia: f64,
    pub c_personal: f64,
    pub c_global: f64,
}

impl Default for ParticleSwarm {
    fn default() -> Self {
        ParticleSwarm { swarm_size: 16, inertia: 0.72, c_personal: 1.49, c_global: 1.49 }
    }
}

impl Optimizer for ParticleSwarm {
    fn name(&self) -> &str {
        "pso"
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let dims = ctx.space().dims();
        let cards: Vec<f64> = (0..dims)
            .map(|d| ctx.space().params.params[d].cardinality() as f64)
            .collect();

        let starts = ctx.space().random_sample(&mut ctx.rng, self.swarm_size);
        let mut pos: Vec<Vec<f64>> = starts
            .iter()
            .map(|&i| ctx.space().config(i).iter().map(|&v| v as f64).collect())
            .collect();
        let mut vel: Vec<Vec<f64>> = (0..pos.len())
            .map(|_| (0..dims).map(|d| (ctx.rng.f64() - 0.5) * cards[d] * 0.2).collect())
            .collect();
        let mut p_best: Vec<(Vec<f64>, f64)> = Vec::with_capacity(pos.len());
        let mut g_best: (Vec<f64>, f64) = (pos[0].clone(), f64::INFINITY);

        for (p, &start) in pos.iter().zip(&starts) {
            if ctx.budget_exhausted() {
                return;
            }
            let f = ctx.evaluate(start).unwrap_or(f64::INFINITY);
            p_best.push((p.clone(), f));
            if f < g_best.1 {
                g_best = (p.clone(), f);
            }
        }

        while !ctx.budget_exhausted() {
            for k in 0..pos.len() {
                if ctx.budget_exhausted() {
                    return;
                }
                for d in 0..dims {
                    let r1 = ctx.rng.f64();
                    let r2 = ctx.rng.f64();
                    vel[k][d] = self.inertia * vel[k][d]
                        + self.c_personal * r1 * (p_best[k].0[d] - pos[k][d])
                        + self.c_global * r2 * (g_best.0[d] - pos[k][d]);
                    // Velocity clamp keeps particles on the grid.
                    let vmax = cards[d] * 0.5;
                    vel[k][d] = vel[k][d].clamp(-vmax, vmax);
                    pos[k][d] = (pos[k][d] + vel[k][d]).clamp(0.0, cards[d] - 1.0);
                }
                let probe: Vec<u16> = pos[k].iter().map(|&x| x.round() as u16).collect();
                let idx = match ctx.space().index_of(&probe) {
                    Some(i) => i,
                    None => {
                        let mut rng = ctx.rng.fork(k as u64);
                        ctx.space().repair(&probe, &mut rng)
                    }
                };
                let f = ctx.evaluate(idx).unwrap_or(f64::INFINITY);
                let actual: Vec<f64> =
                    ctx.space().config(idx).iter().map(|&v| v as f64).collect();
                if f < p_best[k].1 {
                    p_best[k] = (actual.clone(), f);
                }
                if f < g_best.1 {
                    g_best = (actual, f);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn swarm_finds_below_median() {
        let cache = testutil::conv_cache();
        let mut pso = ParticleSwarm::default();
        let (best, _) = testutil::run_on(&mut pso, &cache, 600.0, 10);
        assert!(best < cache.median_ms);
    }

    #[test]
    fn terminates_on_budget() {
        let cache = testutil::conv_cache();
        let mut pso = ParticleSwarm::default();
        let (_, evals) = testutil::run_on(&mut pso, &cache, 30.0, 11);
        assert!(evals >= 1);
    }
}
