//! Genetic algorithm — Kernel Tuner's strongest tuned baseline (the paper's
//! Fig. 8 shows GA beating SA and DE among the human-designed methods).
//!
//! Generational GA over genotypes of value indices: tournament selection,
//! uniform crossover, per-gene mutation, constraint repair, and elitism.
//!
//! Runs natively on the ask/tell batch path: each `suggest` produces a
//! whole generation (the initial sample, then children bred from the
//! current population), evaluated through `TuningContext::evaluate_batch`
//! in one backend call. This is bit-identical to the classic sequential
//! loop — child production draws randomness only from the parent
//! population and the RNG, never from sibling evaluations, and the
//! context applies budget cuts per config exactly as a checking caller
//! would — while giving batch-capable backends whole generations to fan
//! out.

use super::{HyperParamDomain, Optimizer};
use crate::tuning::TuningContext;

/// Sweepable hyperparameter grid (defaults are Kernel Tuner's tuned GA).
const DOMAINS: &[HyperParamDomain] = &[
    HyperParamDomain::new("population_size", 20.0, &[8.0, 16.0, 20.0, 28.0, 40.0]),
    HyperParamDomain::new("tournament_k", 3.0, &[2.0, 3.0, 4.0, 5.0]),
    HyperParamDomain::new("crossover_rate", 0.9, &[0.6, 0.8, 0.9, 1.0]),
    HyperParamDomain::new("mutation_rate_factor", 1.2, &[0.5, 0.8, 1.2, 2.0]),
    HyperParamDomain::new("elites", 2.0, &[0.0, 1.0, 2.0, 3.0]),
];

#[derive(Debug)]
pub struct GeneticAlgorithm {
    pub population_size: usize,
    pub tournament_k: usize,
    pub crossover_rate: f64,
    pub mutation_rate_factor: f64, // per-gene rate = factor / dims
    pub elites: usize,
    state: State,
}

impl Default for GeneticAlgorithm {
    fn default() -> Self {
        GeneticAlgorithm {
            population_size: 20,
            tournament_k: 3,
            crossover_rate: 0.9,
            mutation_rate_factor: 1.2,
            elites: 2,
            state: State::Fresh,
        }
    }
}

/// Ask/tell phase: what the next `suggest`/`observe` pair means.
#[derive(Debug, Default)]
enum State {
    /// Next suggest samples the initial population.
    #[default]
    Fresh,
    /// Initial sample suggested; observe seeds the population.
    AwaitInit,
    /// Population scored; next suggest breeds a generation of children.
    Ready(Vec<Individual>),
    /// Children suggested; payload is the carried elites.
    AwaitGeneration(Vec<Individual>),
}

#[derive(Debug, Clone)]
struct Individual {
    idx: u32,
    fitness: f64, // +inf for failures
}

impl GeneticAlgorithm {
    fn tournament(&self, pop: &[Individual], ctx: &mut TuningContext) -> u32 {
        let mut best: Option<&Individual> = None;
        for _ in 0..self.tournament_k {
            let cand = &pop[ctx.rng.below(pop.len())];
            if best.map(|b| cand.fitness < b.fitness).unwrap_or(true) {
                best = Some(cand);
            }
        }
        best.unwrap().idx
    }
}

impl Optimizer for GeneticAlgorithm {
    fn name(&self) -> &str {
        "ga"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "population_size" => self.population_size = value as usize,
            "tournament_k" => self.tournament_k = value as usize,
            "crossover_rate" => self.crossover_rate = value,
            "mutation_rate_factor" => self.mutation_rate_factor = value,
            "elites" => self.elites = value as usize,
            _ => return false,
        }
        true
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        DOMAINS
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        self.state = State::Fresh;
        super::run_ask_tell(self, ctx);
    }

    fn suggest(&mut self, ctx: &mut TuningContext, _limit: usize) -> Option<Vec<u32>> {
        let space = ctx.space_handle();
        match std::mem::take(&mut self.state) {
            State::Fresh => {
                // Degenerate hyperparameters (settable via the public
                // fields or spec overrides) must not hang the budget loop —
                // an empty population would spin forever without ever
                // charging the clock.
                self.population_size = self.population_size.max(2);
                self.tournament_k = self.tournament_k.max(1);
                self.elites = self.elites.min(self.population_size - 1);
                self.state = State::AwaitInit;
                Some(space.random_sample(&mut ctx.rng, self.population_size))
            }
            State::Ready(mut pop) => {
                let dims = space.dims();
                let mutation_rate = self.mutation_rate_factor / dims as f64;
                pop.sort_by(|a, b| a.fitness.partial_cmp(&b.fitness).unwrap());
                // Elitism: carry the best through unchanged (no re-eval —
                // they keep their recorded fitness in `observe`).
                let elites: Vec<Individual> = pop.iter().take(self.elites).cloned().collect();
                let mut children: Vec<u32> = Vec::new();
                while elites.len() + children.len() < self.population_size {
                    let p1 = self.tournament(&pop, ctx);
                    let p2 = self.tournament(&pop, ctx);
                    let (c1, c2) = (space.config(p1).to_vec(), space.config(p2).to_vec());
                    // Uniform crossover.
                    let mut child: Vec<u16> = if ctx.rng.chance(self.crossover_rate) {
                        c1.iter()
                            .zip(&c2)
                            .map(|(&a, &b)| if ctx.rng.chance(0.5) { a } else { b })
                            .collect()
                    } else {
                        c1
                    };
                    // Mutation: resample a gene uniformly from its domain.
                    for (d, gene) in child.iter_mut().enumerate() {
                        if ctx.rng.chance(mutation_rate) {
                            *gene = ctx.rng.below(space.params.params[d].cardinality()) as u16;
                        }
                    }
                    let idx = match space.index_of(&child) {
                        Some(i) => i,
                        None => {
                            let mut rng = ctx.rng.fork((elites.len() + children.len()) as u64);
                            space.repair(&child, &mut rng)
                        }
                    };
                    children.push(idx);
                }
                self.state = State::AwaitGeneration(elites);
                Some(children)
            }
            awaiting => {
                // suggest() twice without an observe(): not a legal driver
                // sequence — keep the phase and report convergence.
                self.state = awaiting;
                Some(Vec::new())
            }
        }
    }

    fn observe(&mut self, _ctx: &mut TuningContext, batch: &[u32], results: &[Option<f64>]) {
        let scored = |(&idx, r): (&u32, &Option<f64>)| Individual {
            idx,
            fitness: r.unwrap_or(f64::INFINITY),
        };
        match std::mem::take(&mut self.state) {
            State::AwaitInit => {
                let pop: Vec<Individual> = batch.iter().zip(results).map(scored).collect();
                self.state = State::Ready(pop);
            }
            State::AwaitGeneration(mut next) => {
                next.extend(batch.iter().zip(results).map(scored));
                self.state = State::Ready(next);
            }
            state => self.state = state,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn converges_below_median() {
        let cache = testutil::conv_cache();
        let mut ga = GeneticAlgorithm::default();
        let (best, _) = testutil::run_on(&mut ga, &cache, 600.0, 5);
        assert!(best < cache.median_ms);
    }

    #[test]
    fn elitism_preserves_best_across_generations() {
        // With elites > 0 the best fitness can never regress between
        // generations; validated via the monotone context trajectory.
        let cache = testutil::conv_cache();
        let mut ctx = crate::tuning::TuningContext::new(&cache, 400.0, 6);
        GeneticAlgorithm::default().run(&mut ctx);
        let tr = &ctx.trajectory;
        assert!(tr.windows(2).all(|w| w[1].1 <= w[0].1));
    }

    #[test]
    fn handles_tiny_budget() {
        let cache = testutil::conv_cache();
        let mut ga = GeneticAlgorithm::default();
        let (_, evals) = testutil::run_on(&mut ga, &cache, 15.0, 7);
        assert!(evals >= 1);
    }

    #[test]
    fn generations_go_through_the_batch_path() {
        // The acceptance hook: GA must demonstrably evaluate via
        // evaluate_batch, in generation-sized submissions.
        let cache = testutil::conv_cache();
        let mut ctx = crate::tuning::TuningContext::new(&cache, 400.0, 8);
        GeneticAlgorithm::default().run(&mut ctx);
        assert!(ctx.batch_calls() >= 2, "init + at least one generation");
        assert!(ctx.batched_evals() > 0);
        assert_eq!(ctx.largest_batch(), 20, "the full initial population in one batch");
    }

    /// The pre-redesign sequential GA, verbatim: produce a child, evaluate
    /// it, check the budget, repeat. Used as the golden reference for the
    /// batch-path equivalence below.
    fn reference_sequential_run(ga: &mut GeneticAlgorithm, ctx: &mut TuningContext) {
        ga.population_size = ga.population_size.max(2);
        ga.tournament_k = ga.tournament_k.max(1);
        ga.elites = ga.elites.min(ga.population_size - 1);
        let space = ctx.space_handle();
        let dims = space.dims();
        let mutation_rate = ga.mutation_rate_factor / dims as f64;

        let mut pop: Vec<Individual> = Vec::with_capacity(ga.population_size);
        for i in space.random_sample(&mut ctx.rng, ga.population_size) {
            if ctx.budget_exhausted() {
                return;
            }
            let fitness = ctx.evaluate(i).unwrap_or(f64::INFINITY);
            pop.push(Individual { idx: i, fitness });
        }
        while !ctx.budget_exhausted() {
            pop.sort_by(|a, b| a.fitness.partial_cmp(&b.fitness).unwrap());
            let mut next: Vec<Individual> = Vec::with_capacity(ga.population_size);
            for e in pop.iter().take(ga.elites) {
                next.push(Individual { idx: e.idx, fitness: e.fitness });
            }
            while next.len() < ga.population_size && !ctx.budget_exhausted() {
                let p1 = ga.tournament(&pop, ctx);
                let p2 = ga.tournament(&pop, ctx);
                let (c1, c2) = (space.config(p1).to_vec(), space.config(p2).to_vec());
                let mut child: Vec<u16> = if ctx.rng.chance(ga.crossover_rate) {
                    c1.iter()
                        .zip(&c2)
                        .map(|(&a, &b)| if ctx.rng.chance(0.5) { a } else { b })
                        .collect()
                } else {
                    c1
                };
                for (d, gene) in child.iter_mut().enumerate() {
                    if ctx.rng.chance(mutation_rate) {
                        *gene = ctx.rng.below(space.params.params[d].cardinality()) as u16;
                    }
                }
                let idx = match space.index_of(&child) {
                    Some(i) => i,
                    None => {
                        let mut rng = ctx.rng.fork(next.len() as u64);
                        space.repair(&child, &mut rng)
                    }
                };
                let fitness = ctx.evaluate(idx).unwrap_or(f64::INFINITY);
                next.push(Individual { idx, fitness });
            }
            pop = next;
        }
    }

    #[test]
    fn batch_path_is_bit_identical_to_sequential_reference() {
        let cache = testutil::conv_cache();
        for seed in [1u64, 9, 42] {
            for budget in [120.0, 400.0] {
                let mut seq_ctx = crate::tuning::TuningContext::new(&cache, budget, seed);
                reference_sequential_run(&mut GeneticAlgorithm::default(), &mut seq_ctx);
                let mut bat_ctx = crate::tuning::TuningContext::new(&cache, budget, seed);
                GeneticAlgorithm::default().run(&mut bat_ctx);
                assert_eq!(
                    seq_ctx.trajectory, bat_ctx.trajectory,
                    "seed {} budget {}",
                    seed, budget
                );
                assert_eq!(seq_ctx.elapsed_s(), bat_ctx.elapsed_s());
                assert_eq!(seq_ctx.unique_evals(), bat_ctx.unique_evals());
                assert_eq!(seq_ctx.best(), bat_ctx.best());
            }
        }
    }
}
