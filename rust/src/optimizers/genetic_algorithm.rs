//! Genetic algorithm — Kernel Tuner's strongest tuned baseline (the paper's
//! Fig. 8 shows GA beating SA and DE among the human-designed methods).
//!
//! Generational GA over genotypes of value indices: tournament selection,
//! uniform crossover, per-gene mutation, constraint repair, and elitism.

use super::Optimizer;
use crate::tuning::TuningContext;

#[derive(Debug)]
pub struct GeneticAlgorithm {
    pub population_size: usize,
    pub tournament_k: usize,
    pub crossover_rate: f64,
    pub mutation_rate_factor: f64, // per-gene rate = factor / dims
    pub elites: usize,
}

impl Default for GeneticAlgorithm {
    fn default() -> Self {
        GeneticAlgorithm {
            population_size: 20,
            tournament_k: 3,
            crossover_rate: 0.9,
            mutation_rate_factor: 1.2,
            elites: 2,
        }
    }
}

struct Individual {
    idx: u32,
    fitness: f64, // +inf for failures
}

impl GeneticAlgorithm {
    fn tournament(&self, pop: &[Individual], ctx: &mut TuningContext) -> u32 {
        let mut best: Option<&Individual> = None;
        for _ in 0..self.tournament_k {
            let cand = &pop[ctx.rng.below(pop.len())];
            if best.map(|b| cand.fitness < b.fitness).unwrap_or(true) {
                best = Some(cand);
            }
        }
        best.unwrap().idx
    }
}

impl Optimizer for GeneticAlgorithm {
    fn name(&self) -> &str {
        "ga"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "population_size" => self.population_size = value as usize,
            "tournament_k" => self.tournament_k = value as usize,
            "crossover_rate" => self.crossover_rate = value,
            "mutation_rate_factor" => self.mutation_rate_factor = value,
            "elites" => self.elites = value as usize,
            _ => return false,
        }
        true
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        // Degenerate hyperparameters (settable via the public fields or
        // spec overrides) must not hang the budget loop — an empty
        // population would spin forever without ever charging the clock.
        self.population_size = self.population_size.max(2);
        self.tournament_k = self.tournament_k.max(1);
        self.elites = self.elites.min(self.population_size - 1);
        let dims = ctx.space().dims();
        let mutation_rate = self.mutation_rate_factor / dims as f64;

        // Initial population.
        let mut pop: Vec<Individual> = Vec::with_capacity(self.population_size);
        for i in ctx.space().random_sample(&mut ctx.rng, self.population_size) {
            if ctx.budget_exhausted() {
                return;
            }
            let fitness = ctx.evaluate(i).unwrap_or(f64::INFINITY);
            pop.push(Individual { idx: i, fitness });
        }

        while !ctx.budget_exhausted() {
            pop.sort_by(|a, b| a.fitness.partial_cmp(&b.fitness).unwrap());
            let mut next: Vec<Individual> = Vec::with_capacity(self.population_size);
            // Elitism: carry the best through unchanged (no re-eval cost —
            // the context dedups).
            for e in pop.iter().take(self.elites) {
                next.push(Individual { idx: e.idx, fitness: e.fitness });
            }
            while next.len() < self.population_size && !ctx.budget_exhausted() {
                let p1 = self.tournament(&pop, ctx);
                let p2 = self.tournament(&pop, ctx);
                let (c1, c2) = (ctx.space().config(p1).to_vec(), ctx.space().config(p2).to_vec());
                // Uniform crossover.
                let mut child: Vec<u16> = if ctx.rng.chance(self.crossover_rate) {
                    c1.iter()
                        .zip(&c2)
                        .map(|(&a, &b)| if ctx.rng.chance(0.5) { a } else { b })
                        .collect()
                } else {
                    c1.clone()
                };
                // Mutation: resample a gene uniformly from its domain.
                for d in 0..dims {
                    if ctx.rng.chance(mutation_rate) {
                        child[d] =
                            ctx.rng.below(ctx.space().params.params[d].cardinality()) as u16;
                    }
                }
                let idx = match ctx.space().index_of(&child) {
                    Some(i) => i,
                    None => {
                        let mut rng = ctx.rng.fork(next.len() as u64);
                        ctx.space().repair(&child, &mut rng)
                    }
                };
                let fitness = ctx.evaluate(idx).unwrap_or(f64::INFINITY);
                next.push(Individual { idx, fitness });
            }
            pop = next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn converges_below_median() {
        let cache = testutil::conv_cache();
        let mut ga = GeneticAlgorithm::default();
        let (best, _) = testutil::run_on(&mut ga, &cache, 600.0, 5);
        assert!(best < cache.median_ms);
    }

    #[test]
    fn elitism_preserves_best_across_generations() {
        // With elites > 0 the best fitness can never regress between
        // generations; validated via the monotone context trajectory.
        let cache = testutil::conv_cache();
        let mut ctx = crate::tuning::TuningContext::new(&cache, 400.0, 6);
        GeneticAlgorithm::default().run(&mut ctx);
        let tr = &ctx.trajectory;
        assert!(tr.windows(2).all(|w| w[1].1 <= w[0].1));
    }

    #[test]
    fn handles_tiny_budget() {
        let cache = testutil::conv_cache();
        let mut ga = GeneticAlgorithm::default();
        let (_, evals) = testutil::run_on(&mut ga, &cache, 15.0, 7);
        assert!(evals >= 1);
    }
}
