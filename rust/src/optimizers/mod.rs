//! Optimization algorithms ("strategies" in Kernel Tuner terms).
//!
//! Human-designed baselines: random search, genetic algorithm and simulated
//! annealing (Kernel Tuner's two strongest, hyperparameter-tuned per
//! Willemsen et al. 2025b), differential evolution (pyATF's best), particle
//! swarm, greedy/iterated/multi-start local search, basin hopping, and a
//! dependency-free Bayesian optimizer ([`bayes_opt`]: GP surrogate with
//! expected-improvement acquisition, the main classical rival).
//!
//! Generated algorithms (the paper's §4.3): [`generated::HybridVndx`]
//! (Algorithm 1) and [`generated::AdaptiveTabuGreyWolf`] (Algorithm 2),
//! plus the genome-interpreted optimizers produced by the LLaMEA loop
//! (`crate::llamea`).
//!
//! ## Evaluation interface
//!
//! Every optimizer drives a [`TuningContext`] over a pluggable evaluation
//! backend (`crate::tuning::backend`). Two styles coexist:
//!
//! - **Sequential**: `run` calls `ctx.evaluate(i)` point by point — the
//!   natural shape for single-solution methods (SA, local search, basin
//!   hopping) whose next move depends on the last observation.
//! - **Ask/tell batches**: population methods implement
//!   [`Optimizer::suggest`] / [`Optimizer::observe`] and submit whole
//!   generations through `ctx.evaluate_batch`, which forwards them to the
//!   backend in one call — the seam a fan-out scheduler or a measured
//!   backend exploits. [`run_ask_tell`] is the generic driver loop. The
//!   genetic algorithm runs natively on this path (its generation
//!   production draws no randomness from evaluation results, so batched
//!   and sequential execution are bit-identical); DE and PSO expose
//!   *synchronous* ask/tell variants while their `run` keeps the classic
//!   asynchronous update rule.
//!
//! ## Hyperparameters
//!
//! Every registry optimizer declares its knobs as typed
//! [`HyperParamDomain`]s (key, tuned default, discrete value grid), the
//! single source behind the CLI's `optimizers` listing, parse-time
//! override validation in [`OptimizerSpec::parse`], and the meta search
//! spaces `crate::hypertune` sweeps over.

pub mod basin_hopping;
pub mod bayes_opt;
pub mod components;
pub mod differential_evolution;
pub mod generated;
pub mod genetic_algorithm;
pub mod local_search;
pub mod particle_swarm;
pub mod random_search;
pub mod simulated_annealing;

use crate::tuning::TuningContext;

/// The typed domain of one optimizer hyperparameter: the override key
/// [`Optimizer::set_hyperparam`] accepts, the tuned default, and the
/// discrete candidate values a hyperparameter-tuning grid draws from
/// (`crate::hypertune` builds meta search spaces from these).
///
/// Contract (pinned by the registry test): `default` is a member of
/// `values`, `values` is ascending and duplicate-free, and every value is
/// accepted by `set_hyperparam` on a fresh instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HyperParamDomain {
    /// Override key, e.g. `population_size`.
    pub key: &'static str,
    /// The tuned default the registry constructor uses.
    pub default: f64,
    /// Discrete candidate values, ascending.
    pub values: &'static [f64],
}

impl HyperParamDomain {
    pub const fn new(key: &'static str, default: f64, values: &'static [f64]) -> HyperParamDomain {
        HyperParamDomain { key, default, values }
    }

    /// Whether `v` is (approximately) a member of the value set — the
    /// parse-time validity check for spec overrides.
    pub fn contains(&self, v: f64) -> bool {
        self.values.iter().any(|&d| (d - v).abs() <= 1e-9 * d.abs().max(1.0))
    }
}

/// Numeric coding of [`NeighborKind`](crate::searchspace::NeighborKind)
/// for neighbor-kind hyperparameters
/// (`0` = Hamming, `1` = Adjacent, `2` = StrictlyAdjacent); `None` for any
/// other value, so `set_hyperparam` rejects unknown codes.
pub fn neighbor_kind_from_code(v: f64) -> Option<crate::searchspace::NeighborKind> {
    use crate::searchspace::NeighborKind;
    if v != v.trunc() {
        return None; // a fractional code is a caller bug, not a kind
    }
    match v as i64 {
        0 => Some(NeighborKind::Hamming),
        1 => Some(NeighborKind::Adjacent),
        2 => Some(NeighborKind::StrictlyAdjacent),
        _ => None,
    }
}

/// A budgeted optimization algorithm over a tuning context.
///
/// `run` must loop until `ctx.budget_exhausted()`; the context performs all
/// wall-clock accounting, deduplication and best-tracking.
pub trait Optimizer {
    fn name(&self) -> &str;
    fn run(&mut self, ctx: &mut TuningContext);

    /// Override a named hyperparameter before `run` (the seam
    /// [`OptimizerSpec`] overrides flow through). Returns `false` for keys
    /// the optimizer does not expose; the default exposes none.
    ///
    /// Deliberately permissive about *values*: any finite value for a known
    /// key is applied (optimizers clamp degenerate settings themselves).
    /// Domain membership is enforced one layer up, in
    /// [`OptimizerSpec::parse`], so programmatic callers can explore
    /// off-grid values while CLI input fails fast.
    fn set_hyperparam(&mut self, _key: &str, _value: f64) -> bool {
        false
    }

    /// The typed hyperparameter domains of this optimizer: every key
    /// [`Optimizer::set_hyperparam`] accepts, with its tuned default and
    /// the discrete value grid meta-tuning sweeps over. The default
    /// exposes none; the registry contract test pins agreement with
    /// `set_hyperparam` for every registered optimizer.
    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        &[]
    }

    /// The hyperparameter keys this optimizer exposes, derived from
    /// [`Optimizer::hyperparam_domains`] (single source of truth).
    fn hyperparams(&self) -> Vec<&'static str> {
        self.hyperparam_domains().iter().map(|d| d.key).collect()
    }

    /// Ask/tell: propose the next batch of configurations to evaluate.
    ///
    /// Returns `None` when the optimizer has no batch path (the default),
    /// and an empty batch when it has converged. `limit` is a hint from
    /// the driver; population optimizers may exceed it where generation
    /// atomicity requires (a generation is produced as one unit).
    ///
    /// The contract with [`Optimizer::observe`]: every suggested batch is
    /// evaluated through `ctx.evaluate_batch` and handed back exactly
    /// once, in order. Entries the context skipped on budget exhaustion
    /// come back as `None`.
    fn suggest(&mut self, _ctx: &mut TuningContext, _limit: usize) -> Option<Vec<u32>> {
        None
    }

    /// Ask/tell: receive the evaluation results of a suggested batch.
    fn observe(&mut self, _ctx: &mut TuningContext, _batch: &[u32], _results: &[Option<f64>]) {}
}

/// Generic ask/tell driver: suggest → batch-evaluate → observe until the
/// budget is exhausted or the optimizer converges. Returns `false` when
/// the optimizer has no batch path (callers fall back to `run`).
pub fn run_ask_tell(opt: &mut dyn Optimizer, ctx: &mut TuningContext) -> bool {
    while !ctx.budget_exhausted() {
        let Some(batch) = opt.suggest(ctx, usize::MAX) else {
            return false;
        };
        if batch.is_empty() {
            return true; // converged
        }
        let results = ctx.evaluate_batch(&batch);
        opt.observe(ctx, &batch, &results);
    }
    true
}

/// One registered optimizer: its canonical name and default constructor.
pub struct RegistryEntry {
    pub name: &'static str,
    /// Construct with tuned default hyperparameters.
    pub build: fn() -> Box<dyn Optimizer>,
}

/// The single registration table every optimizer goes through — `by_name`,
/// `all_names` and the CLI are all derived from it, so an optimizer cannot
/// be registered in one place and forgotten in another.
///
/// Names: `random`, `ga`, `sa`, `de` (pyATF), `pso`, `greedy_ils`, `mls`,
/// `basin_hopping`, `hybrid_vndx`, `atgw`, `bayes_opt`.
pub static REGISTRY: [RegistryEntry; 11] = [
    RegistryEntry { name: "random", build: || Box::new(random_search::RandomSearch::default()) },
    RegistryEntry {
        name: "ga",
        build: || Box::new(genetic_algorithm::GeneticAlgorithm::default()),
    },
    RegistryEntry {
        name: "sa",
        build: || Box::new(simulated_annealing::SimulatedAnnealing::default()),
    },
    RegistryEntry {
        name: "de",
        build: || Box::new(differential_evolution::DifferentialEvolution::default()),
    },
    RegistryEntry { name: "pso", build: || Box::new(particle_swarm::ParticleSwarm::default()) },
    RegistryEntry { name: "greedy_ils", build: || Box::new(local_search::GreedyIls::default()) },
    RegistryEntry {
        name: "mls",
        build: || Box::new(local_search::MultiStartLocalSearch::default()),
    },
    RegistryEntry {
        name: "basin_hopping",
        build: || Box::new(basin_hopping::BasinHopping::default()),
    },
    RegistryEntry {
        name: "hybrid_vndx",
        build: || Box::new(generated::hybrid_vndx::HybridVndx::default()),
    },
    RegistryEntry {
        name: "atgw",
        build: || Box::new(generated::adaptive_tabu_grey_wolf::AdaptiveTabuGreyWolf::default()),
    },
    RegistryEntry { name: "bayes_opt", build: || Box::new(bayes_opt::BayesOpt::default()) },
];

/// Instantiate a named optimizer with its tuned default hyperparameters.
pub fn by_name(name: &str) -> Option<Box<dyn Optimizer>> {
    REGISTRY.iter().find(|e| e.name == name).map(|e| (e.build)())
}

/// All registered optimizer names (stable registry order, used by the CLI).
pub fn all_names() -> impl Iterator<Item = &'static str> {
    REGISTRY.iter().map(|e| e.name)
}

/// A serializable description of an optimizer instance: either a registry
/// name plus hyperparameter overrides, or a genome from the LLaMEA loop.
/// This is what tuning jobs carry — it is `Clone`, comparable, printable,
/// and (for the named form) round-trips through [`OptimizerSpec::parse`].
#[derive(Debug, Clone, PartialEq)]
pub enum OptimizerSpec {
    /// A registry optimizer, e.g. `ga` or `ga:population_size=40,elites=3`.
    Named { name: String, overrides: Vec<(String, f64)> },
    /// A genome-interpreted optimizer produced by `crate::llamea`.
    Genome(crate::llamea::Genome),
}

impl OptimizerSpec {
    pub fn named(name: impl Into<String>) -> OptimizerSpec {
        OptimizerSpec::Named { name: name.into(), overrides: Vec::new() }
    }

    pub fn genome(genome: crate::llamea::Genome) -> OptimizerSpec {
        OptimizerSpec::Genome(genome)
    }

    /// Add a hyperparameter override. Genome specs carry their parameters
    /// inside the genome and accept none: the override is rejected.
    /// Spec-building code paths (hyperparameter-tuning grids) use this to
    /// reject instead of crash.
    pub fn try_with_override(
        mut self,
        key: impl Into<String>,
        value: f64,
    ) -> Result<OptimizerSpec, &'static str> {
        match &mut self {
            OptimizerSpec::Named { overrides, .. } => {
                overrides.push((key.into(), value));
                Ok(self)
            }
            OptimizerSpec::Genome(_) => Err("genome specs take no hyperparameter overrides"),
        }
    }

    /// Chaining form of [`Self::try_with_override`] for statically-known
    /// named specs. On a genome spec this is a programming error: it
    /// debug-asserts, and in release builds leaves the spec unchanged.
    pub fn with_override(self, key: impl Into<String>, value: f64) -> OptimizerSpec {
        match self.try_with_override(key, value) {
            Ok(spec) => spec,
            Err(e) => {
                debug_assert!(false, "{}", e);
                self
            }
        }
    }

    /// Parse the CLI form `name` or `name:key=val,key=val`. Returns `None`
    /// for unknown names, malformed overrides, override keys (or
    /// non-finite values) the named optimizer rejects, and values outside
    /// the key's declared [`HyperParamDomain`] — all validated here against
    /// a probe instance so a typo or out-of-range value fails at parse time
    /// instead of panicking inside a scheduler worker at job-build time.
    ///
    /// Explicitly partial with respect to [`std::fmt::Display`]: genome
    /// specs print as `genome:<name>` for reports, but genomes are not
    /// registry members and cannot be reconstructed from a name, so the
    /// genome form does not parse back (pinned by a test). Named specs
    /// round-trip exactly.
    pub fn parse(s: &str) -> Option<OptimizerSpec> {
        let (name, rest) = match s.split_once(':') {
            Some((n, r)) => (n, Some(r)),
            None => (s, None),
        };
        let mut probe = by_name(name)?;
        let mut spec = OptimizerSpec::named(name);
        if let Some(rest) = rest {
            for kv in rest.split(',').filter(|kv| !kv.is_empty()) {
                let (k, v) = kv.split_once('=')?;
                let v = v.parse::<f64>().ok()?;
                if !probe.set_hyperparam(k, v) {
                    return None;
                }
                // The key exists; the value must also lie on the declared
                // grid (keys without a declared domain — none in the
                // registry today — stay unconstrained).
                if let Some(d) = probe.hyperparam_domains().iter().find(|d| d.key == k) {
                    if !d.contains(v) {
                        return None;
                    }
                }
                spec = spec.try_with_override(k, v).ok()?;
            }
        }
        Some(spec)
    }

    /// Parse a comma-separated list of specs (the CLI's `--opts` value).
    /// Override lists also use commas (`ga:a=1,b=2`), so a segment that
    /// contains `=` but no `:` continues the previous spec's overrides:
    /// `ga:a=1,b=2,sa` parses as `[ga:a=1,b=2, sa]`.
    pub fn parse_list(s: &str) -> Option<Vec<OptimizerSpec>> {
        let mut raw: Vec<String> = Vec::new();
        for seg in s.split(',').filter(|seg| !seg.is_empty()) {
            if seg.contains('=') && !seg.contains(':') {
                let prev = raw.last_mut()?;
                prev.push(',');
                prev.push_str(seg);
            } else {
                raw.push(seg.to_string());
            }
        }
        raw.iter().map(|spec| OptimizerSpec::parse(spec)).collect()
    }

    /// Display label (registry name, or the genome's name).
    pub fn label(&self) -> String {
        match self {
            OptimizerSpec::Named { name, .. } => name.clone(),
            OptimizerSpec::Genome(g) => g.name.clone(),
        }
    }

    /// Instantiate a fresh optimizer. Panics on unknown names or override
    /// keys — a spec is validated configuration, not user input.
    pub fn build(&self) -> Box<dyn Optimizer> {
        match self {
            OptimizerSpec::Named { name, overrides } => {
                let mut opt =
                    by_name(name).unwrap_or_else(|| panic!("unknown optimizer '{}'", name));
                for (k, v) in overrides {
                    assert!(
                        opt.set_hyperparam(k, *v),
                        "optimizer '{}' has no hyperparameter '{}'",
                        name,
                        k
                    );
                }
                opt
            }
            OptimizerSpec::Genome(g) => Box::new(crate::llamea::GenomeOptimizer::new(g.clone())),
        }
    }
}

impl std::fmt::Display for OptimizerSpec {
    fn fmt(&self, fmt: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OptimizerSpec::Named { name, overrides } => {
                write!(fmt, "{}", name)?;
                for (i, (k, v)) in overrides.iter().enumerate() {
                    write!(fmt, "{}{}={}", if i == 0 { ':' } else { ',' }, k, v)?;
                }
                Ok(())
            }
            OptimizerSpec::Genome(g) => write!(fmt, "genome:{}", g.name),
        }
    }
}

/// Specs double as thread-safe factories for the runner/scheduler.
impl crate::methodology::OptimizerFactory for OptimizerSpec {
    fn build(&self) -> Box<dyn Optimizer> {
        OptimizerSpec::build(self)
    }
    fn label(&self) -> String {
        OptimizerSpec::label(self)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::kernels::gpu::GpuSpec;
    use crate::searchspace::Application;
    use crate::tuning::Cache;

    /// A small cache every optimizer test can share.
    pub fn conv_cache() -> Cache {
        Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap())
    }

    /// Run an optimizer on the cache and return (best_ms, unique_evals).
    pub fn run_on(
        opt: &mut dyn super::Optimizer,
        cache: &Cache,
        budget_s: f64,
        seed: u64,
    ) -> (f64, u64) {
        let mut ctx = crate::tuning::TuningContext::new(cache, budget_s, seed);
        opt.run(&mut ctx);
        let best = ctx.best().map(|(_, v)| v).unwrap_or(f64::INFINITY);
        (best, ctx.unique_evals())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_roundtrip_is_table_driven() {
        // Every table entry resolves, reports its own registry name, and
        // round-trips through the spec syntax — a new optimizer added to
        // the table is automatically covered.
        for e in REGISTRY.iter() {
            let opt = by_name(e.name).unwrap_or_else(|| panic!("{} missing", e.name));
            assert_eq!(opt.name(), e.name, "constructor/name mismatch");
            let spec = OptimizerSpec::parse(e.name).unwrap();
            assert_eq!(spec.label(), e.name);
            assert_eq!(OptimizerSpec::parse(&spec.to_string()), Some(spec));
        }
        assert_eq!(all_names().count(), REGISTRY.len());
        assert!(by_name("nonexistent").is_none());
        assert!(OptimizerSpec::parse("nonexistent").is_none());
    }

    #[test]
    fn hyperparam_domains_are_the_contract() {
        // The typed domains, the derived key listing and set_hyperparam()
        // must agree for every registry optimizer: every domain value
        // (default included) is accepted on a fresh instance, domains are
        // ascending and duplicate-free, defaults lie on the grid, and
        // made-up keys are rejected.
        for e in REGISTRY.iter() {
            let mut opt = by_name(e.name).unwrap();
            let domains = opt.hyperparam_domains();
            assert_eq!(
                opt.hyperparams(),
                domains.iter().map(|d| d.key).collect::<Vec<_>>(),
                "{}: keys must derive from domains",
                e.name
            );
            for d in domains {
                assert!(!d.values.is_empty(), "{}:{} empty domain", e.name, d.key);
                assert!(
                    d.values.windows(2).all(|w| w[0] < w[1]),
                    "{}:{} domain not strictly ascending",
                    e.name,
                    d.key
                );
                assert!(
                    d.contains(d.default),
                    "{}:{} default {} not in its own domain",
                    e.name,
                    d.key,
                    d.default
                );
                for &v in d.values {
                    let mut fresh = by_name(e.name).unwrap();
                    assert!(
                        fresh.set_hyperparam(d.key, v),
                        "{} declares {}={} but rejects it",
                        e.name,
                        d.key,
                        v
                    );
                }
            }
            assert!(
                !opt.set_hyperparam("definitely_not_a_knob", 1.0),
                "{} accepted an unknown key",
                e.name
            );
        }
        // At least the paper's two tuned baselines expose sweepable grids.
        for tuned in ["ga", "sa"] {
            assert!(!by_name(tuned).unwrap().hyperparam_domains().is_empty());
        }
        // Neighbor-kind codes are integers; fractional codes are rejected,
        // not silently truncated onto a kind.
        assert!(!by_name("mls").unwrap().set_hyperparam("neighbor", 1.5));
        assert!(!by_name("mls").unwrap().set_hyperparam("neighbor", -1.0));
        assert!(neighbor_kind_from_code(2.0).is_some());
        assert!(neighbor_kind_from_code(0.5).is_none());
    }

    #[test]
    fn spec_overrides_parse_display_and_apply() {
        let spec = OptimizerSpec::parse("ga:population_size=40,elites=3").unwrap();
        assert_eq!(spec.to_string(), "ga:population_size=40,elites=3");
        assert_eq!(spec.label(), "ga");
        // Applying the overrides must succeed (set_hyperparam returns true).
        let _ = spec.build();
        assert!(OptimizerSpec::parse("ga:population_size").is_none(), "missing value");
        assert!(OptimizerSpec::parse("ga:population_size=abc").is_none(), "bad value");
        assert!(OptimizerSpec::parse("ga:no_such_knob=1").is_none(), "unknown key");
        assert!(OptimizerSpec::parse("random:x=1").is_none(), "random exposes no knobs");
        assert!(OptimizerSpec::parse("ga:elites=NaN").is_none(), "non-finite value");
        // Values must lie on the declared domain grid at parse time...
        assert!(OptimizerSpec::parse("ga:population_size=41").is_none(), "off-grid value");
        assert!(OptimizerSpec::parse("sa:alpha=0.42").is_none(), "off-grid value");
        assert!(OptimizerSpec::parse("de:f=0.7").is_some(), "DE knobs are sweepable now");
        // ...but set_hyperparam stays permissive for programmatic callers.
        let mut ga2 = genetic_algorithm::GeneticAlgorithm::default();
        assert!(ga2.set_hyperparam("population_size", 41.0));

        let mut ga = genetic_algorithm::GeneticAlgorithm::default();
        assert!(ga.set_hyperparam("population_size", 40.0));
        assert_eq!(ga.population_size, 40);
        assert!(!ga.set_hyperparam("no_such_knob", 1.0));
        assert!(!ga.set_hyperparam("crossover_rate", f64::NAN));
    }

    #[test]
    fn genome_display_is_explicitly_partial() {
        // The Display/parse contract: named specs round-trip; the genome
        // form `genome:<name>` is a report label only and does not parse
        // back (genomes are not registry members).
        let g = OptimizerSpec::genome(crate::llamea::Genome::hybrid_vndx_like());
        let shown = g.to_string();
        assert!(shown.starts_with("genome:"), "{}", shown);
        assert_eq!(OptimizerSpec::parse(&shown), None);
        // And via parse_list, which must reject rather than mis-parse.
        assert!(OptimizerSpec::parse_list(&shown).is_none());
    }

    #[test]
    fn genome_overrides_reject_instead_of_crash() {
        let g = OptimizerSpec::genome(crate::llamea::Genome::hybrid_vndx_like());
        assert!(g.clone().try_with_override("k", 3.0).is_err());
        // Named specs accept.
        let named = OptimizerSpec::named("ga").try_with_override("elites", 3.0).unwrap();
        assert_eq!(named.to_string(), "ga:elites=3");
    }

    #[test]
    fn spec_list_parsing_keeps_override_commas() {
        let specs = OptimizerSpec::parse_list("ga:population_size=40,elites=3,sa,random").unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].to_string(), "ga:population_size=40,elites=3");
        assert_eq!(specs[1].label(), "sa");
        assert_eq!(specs[2].label(), "random");
        assert!(OptimizerSpec::parse_list("population_size=40").is_none(), "dangling override");
        assert!(OptimizerSpec::parse_list("ga,nope").is_none());
        assert_eq!(OptimizerSpec::parse_list("").unwrap(), Vec::new());
    }

    #[test]
    fn degenerate_hyperparams_cannot_hang_ga() {
        // population_size 0 used to spin the generation loop forever
        // without charging the budget clock.
        let cache = testutil::conv_cache();
        let spec = OptimizerSpec::named("ga")
            .with_override("population_size", 0.0)
            .with_override("tournament_k", 0.0);
        let mut opt = spec.build();
        let (best, _) = testutil::run_on(opt.as_mut(), &cache, 200.0, 1);
        assert!(best.is_finite());
    }

    #[test]
    fn every_optimizer_terminates_and_improves_over_nothing() {
        let cache = testutil::conv_cache();
        for n in all_names() {
            let mut opt = by_name(n).unwrap();
            let (best, evals) = testutil::run_on(opt.as_mut(), &cache, 300.0, 42);
            assert!(best.is_finite(), "{} found nothing", n);
            assert!(evals > 3, "{} evaluated too little ({})", n, evals);
        }
    }

    #[test]
    fn optimizers_beat_random_on_average() {
        // Sanity: the strong strategies should beat random search on the
        // same budget for most seeds (not a statistical proof, a smoke bar).
        let cache = testutil::conv_cache();
        let budget = 400.0;
        let mut rand_scores = Vec::new();
        let mut smart_scores = Vec::new();
        for seed in 0..5 {
            let mut r = by_name("random").unwrap();
            rand_scores.push(testutil::run_on(r.as_mut(), &cache, budget, seed).0);
            let mut h = by_name("hybrid_vndx").unwrap();
            smart_scores.push(testutil::run_on(h.as_mut(), &cache, budget, seed).0);
        }
        let rm = crate::util::stats::mean(&rand_scores);
        let sm = crate::util::stats::mean(&smart_scores);
        assert!(sm <= rm * 1.05, "hybrid_vndx {} vs random {}", sm, rm);
    }
}
