//! Optimization algorithms ("strategies" in Kernel Tuner terms).
//!
//! Human-designed baselines: random search, genetic algorithm and simulated
//! annealing (Kernel Tuner's two strongest, hyperparameter-tuned per
//! Willemsen et al. 2025b), differential evolution (pyATF's best), particle
//! swarm, greedy/iterated/multi-start local search and basin hopping.
//!
//! Generated algorithms (the paper's §4.3): [`generated::HybridVndx`]
//! (Algorithm 1) and [`generated::AdaptiveTabuGreyWolf`] (Algorithm 2),
//! plus the genome-interpreted optimizers produced by the LLaMEA loop
//! (`crate::llamea`).

pub mod basin_hopping;
pub mod components;
pub mod differential_evolution;
pub mod generated;
pub mod genetic_algorithm;
pub mod local_search;
pub mod particle_swarm;
pub mod random_search;
pub mod simulated_annealing;

use crate::tuning::TuningContext;

/// A budgeted optimization algorithm over a tuning context.
///
/// `run` must loop until `ctx.budget_exhausted()`; the context performs all
/// wall-clock accounting, deduplication and best-tracking.
pub trait Optimizer {
    fn name(&self) -> &str;
    fn run(&mut self, ctx: &mut TuningContext);
}

/// Instantiate a named optimizer with its tuned default hyperparameters.
///
/// Names: `random`, `ga`, `sa`, `de` (pyATF), `pso`, `greedy_ils`, `mls`,
/// `basin_hopping`, `hybrid_vndx`, `atgw`.
pub fn by_name(name: &str) -> Option<Box<dyn Optimizer>> {
    Some(match name {
        "random" => Box::new(random_search::RandomSearch::default()),
        "ga" => Box::new(genetic_algorithm::GeneticAlgorithm::default()),
        "sa" => Box::new(simulated_annealing::SimulatedAnnealing::default()),
        "de" => Box::new(differential_evolution::DifferentialEvolution::default()),
        "pso" => Box::new(particle_swarm::ParticleSwarm::default()),
        "greedy_ils" => Box::new(local_search::GreedyIls::default()),
        "mls" => Box::new(local_search::MultiStartLocalSearch::default()),
        "basin_hopping" => Box::new(basin_hopping::BasinHopping::default()),
        "hybrid_vndx" => Box::new(generated::hybrid_vndx::HybridVndx::default()),
        "atgw" => Box::new(generated::adaptive_tabu_grey_wolf::AdaptiveTabuGreyWolf::default()),
        _ => return None,
    })
}

/// All registered optimizer names (stable order, used by the CLI).
pub const ALL_NAMES: [&str; 10] = [
    "random",
    "ga",
    "sa",
    "de",
    "pso",
    "greedy_ils",
    "mls",
    "basin_hopping",
    "hybrid_vndx",
    "atgw",
];

#[cfg(test)]
pub(crate) mod testutil {
    use crate::kernels::gpu::GpuSpec;
    use crate::searchspace::Application;
    use crate::tuning::Cache;

    /// A small cache every optimizer test can share.
    pub fn conv_cache() -> Cache {
        Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap())
    }

    /// Run an optimizer on the cache and return (best_ms, unique_evals).
    pub fn run_on(
        opt: &mut dyn super::Optimizer,
        cache: &Cache,
        budget_s: f64,
        seed: u64,
    ) -> (f64, u64) {
        let mut ctx = crate::tuning::TuningContext::new(cache, budget_s, seed);
        opt.run(&mut ctx);
        let best = ctx.best().map(|(_, v)| v).unwrap_or(f64::INFINITY);
        (best, ctx.unique_evals())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for n in ALL_NAMES {
            assert!(by_name(n).is_some(), "{}", n);
        }
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn every_optimizer_terminates_and_improves_over_nothing() {
        let cache = testutil::conv_cache();
        for n in ALL_NAMES {
            let mut opt = by_name(n).unwrap();
            let (best, evals) = testutil::run_on(opt.as_mut(), &cache, 300.0, 42);
            assert!(best.is_finite(), "{} found nothing", n);
            assert!(evals > 3, "{} evaluated too little ({})", n, evals);
        }
    }

    #[test]
    fn optimizers_beat_random_on_average() {
        // Sanity: the strong strategies should beat random search on the
        // same budget for most seeds (not a statistical proof, a smoke bar).
        let cache = testutil::conv_cache();
        let budget = 400.0;
        let mut rand_scores = Vec::new();
        let mut smart_scores = Vec::new();
        for seed in 0..5 {
            let mut r = by_name("random").unwrap();
            rand_scores.push(testutil::run_on(r.as_mut(), &cache, budget, seed).0);
            let mut h = by_name("hybrid_vndx").unwrap();
            smart_scores.push(testutil::run_on(h.as_mut(), &cache, budget, seed).0);
        }
        let rm = crate::util::stats::mean(&rand_scores);
        let sm = crate::util::stats::mean(&smart_scores);
        assert!(sm <= rm * 1.05, "hybrid_vndx {} vs random {}", sm, rm);
    }
}
