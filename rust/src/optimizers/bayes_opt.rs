//! Bayesian optimization — the surrogate-model family the paper
//! benchmarks its generated algorithms against (Kernel Tuner's `bayes_opt`
//! strategy), dependency-free.
//!
//! A Gaussian-process surrogate (squared-exponential kernel, Cholesky
//! factorization, hand-rolled — no linear-algebra crates) is fit on a
//! sliding window of the deduplicated observations; the next point is the
//! expected-improvement argmax over a candidate pool drawn from the CSR
//! neighbor rows of the best configurations found so far, topped up with
//! random valid samples. The GP works in per-dimension-standardized value
//! space (`SearchSpace::values_f64`), so parameter scales don't leak into
//! the kernel metric.
//!
//! Window and pool sizes are hyperparameters: the Cholesky is O(w³) with
//! `train_window` ≤ 96, so surrogate fitting stays microseconds per step
//! (tracked by the `gp_fit_predict` section of `BENCH_hotpath.json`).
//! Degenerate posteriors (too few points, a flat window, a factorization
//! failure after jitter escalation) fall back to the first unevaluated
//! neighbor — a deterministic hill step, never a crash.
//!
//! Ask/tell is supported (init batch, then one EI argmax per suggest);
//! `run` is the same proposal loop driven sequentially. All randomness
//! flows through `ctx.rng`, so runs are a pure function of the seed.

use std::collections::HashSet;

use super::{HyperParamDomain, Optimizer};
use crate::searchspace::space::FxBuildHasher;
use crate::searchspace::{NeighborKind, SearchSpace};
use crate::tuning::TuningContext;

/// Sweepable grid around the tuned defaults.
const DOMAINS: &[HyperParamDomain] = &[
    HyperParamDomain::new("init_samples", 16.0, &[8.0, 16.0, 32.0]),
    HyperParamDomain::new("candidate_pool", 64.0, &[32.0, 64.0, 128.0]),
    HyperParamDomain::new("train_window", 48.0, &[24.0, 48.0, 96.0]),
    HyperParamDomain::new("length_scale", 2.0, &[1.0, 2.0, 4.0]),
    HyperParamDomain::new("xi", 0.01, &[0.0, 0.01, 0.05, 0.1]),
];

/// How many best-so-far configurations seed the neighbor part of the
/// candidate pool.
const POOL_SEEDS: usize = 4;

/// Ask/tell phase.
#[derive(Debug, Default)]
enum State {
    #[default]
    Fresh,
    AwaitInit,
    Ready,
    AwaitPoint,
}

#[derive(Debug)]
pub struct BayesOpt {
    pub init_samples: usize,
    pub candidate_pool: usize,
    pub train_window: usize,
    pub length_scale: f64,
    pub xi: f64,
    /// Deduplicated successful observations, in evaluation order.
    history: Vec<(u32, f64)>,
    /// Every index already proposed/evaluated (successful or not).
    tried: HashSet<u32, FxBuildHasher>,
    state: State,
}

impl Default for BayesOpt {
    fn default() -> Self {
        BayesOpt {
            init_samples: 16,
            candidate_pool: 64,
            train_window: 48,
            length_scale: 2.0,
            xi: 0.01,
            history: Vec::new(),
            tried: HashSet::with_hasher(FxBuildHasher::default()),
            state: State::Fresh,
        }
    }
}

impl BayesOpt {
    /// Record one evaluation outcome. Failed/skipped evaluations mark the
    /// index as tried (never re-proposed) but stay out of the GP window.
    fn record(&mut self, idx: u32, value: Option<f64>) {
        let fresh = self.tried.insert(idx);
        if let Some(v) = value {
            if v.is_finite() && fresh {
                self.history.push((idx, v));
            }
        }
    }

    /// The candidate pool: unevaluated CSR neighbors of the best
    /// configurations seen, topped up with random valid samples. Order is
    /// deterministic (CSR row order, then draw order), which also makes
    /// the EI tie-break (first wins) deterministic.
    fn candidates(&self, space: &SearchSpace, ctx: &mut TuningContext) -> Vec<u32> {
        let pool_cap = self.candidate_pool.max(4);
        let mut pool: Vec<u32> = Vec::with_capacity(pool_cap);
        let mut in_pool: HashSet<u32, FxBuildHasher> =
            HashSet::with_hasher(FxBuildHasher::default());
        let mut seeds: Vec<(f64, u32)> =
            self.history.iter().map(|&(i, v)| (v, i)).collect();
        seeds.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for &(_, s) in seeds.iter().take(POOL_SEEDS) {
            for &n in space.neighbors_of(s, NeighborKind::Hamming) {
                if pool.len() >= pool_cap {
                    break;
                }
                if !self.tried.contains(&n) && in_pool.insert(n) {
                    pool.push(n);
                }
            }
        }
        // Top up with random exploration so the pool never collapses onto
        // one basin; bounded attempts keep small spaces from spinning.
        let mut attempts = 4 * pool_cap;
        while pool.len() < pool_cap && attempts > 0 {
            attempts -= 1;
            let i = space.random_valid(&mut ctx.rng);
            if !self.tried.contains(&i) && in_pool.insert(i) {
                pool.push(i);
            }
        }
        pool
    }

    /// Pick the next configuration: EI argmax over the candidate pool,
    /// with deterministic fallbacks when the pool or the posterior is
    /// degenerate. `None` means the space is exhausted.
    fn propose(&self, space: &SearchSpace, ctx: &mut TuningContext) -> Option<u32> {
        if self.tried.len() >= space.len() {
            return None;
        }
        let pool = self.candidates(space, ctx);
        if pool.is_empty() {
            // Everything near the incumbents is tried and random draws
            // found nothing fresh: take any valid config (re-evaluating a
            // seen one only costs the cached-eval tick, so the budget
            // clock still advances and `run` terminates).
            for _ in 0..64 {
                let i = space.random_valid(&mut ctx.rng);
                if !self.tried.contains(&i) {
                    return Some(i);
                }
            }
            return Some(space.random_valid(&mut ctx.rng));
        }
        let window = self.window();
        let points: Vec<(Vec<f64>, f64)> =
            window.iter().map(|&(i, v)| (space.values_f64(i), v)).collect();
        match fit_gp(&points, self.length_scale) {
            Some(gp) => {
                let mut best = pool[0];
                let mut best_ei = f64::NEG_INFINITY;
                for &c in &pool {
                    let ei = gp.expected_improvement(&space.values_f64(c), self.xi);
                    if ei > best_ei {
                        best_ei = ei;
                        best = c;
                    }
                }
                Some(best)
            }
            // Degenerate posterior: first unevaluated neighbor of the
            // best config — a plain deterministic hill step.
            None => Some(pool[0]),
        }
    }

    /// The GP training window: the best half of the window budget plus
    /// the most recent remainder — incumbent basins modeled precisely,
    /// recent exploration keeping the posterior current.
    fn window(&self) -> Vec<(u32, f64)> {
        let w = self.train_window.max(8);
        if self.history.len() <= w {
            return self.history.clone();
        }
        let mut best: Vec<(u32, f64)> = self.history.clone();
        best.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        let keep_best = w / 2;
        let mut chosen: HashSet<u32, FxBuildHasher> =
            HashSet::with_hasher(FxBuildHasher::default());
        let mut out: Vec<(u32, f64)> = Vec::with_capacity(w);
        for &(i, v) in best.iter().take(keep_best) {
            chosen.insert(i);
            out.push((i, v));
        }
        for &(i, v) in self.history.iter().rev() {
            if out.len() >= w {
                break;
            }
            if chosen.insert(i) {
                out.push((i, v));
            }
        }
        out
    }
}

impl Optimizer for BayesOpt {
    fn name(&self) -> &str {
        "bayes_opt"
    }

    fn set_hyperparam(&mut self, key: &str, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        match key {
            "init_samples" => self.init_samples = (value as usize).max(2),
            "candidate_pool" => self.candidate_pool = (value as usize).max(4),
            "train_window" => self.train_window = (value as usize).max(8),
            "length_scale" => self.length_scale = value.max(1e-3),
            "xi" => self.xi = value.max(0.0),
            _ => return false,
        }
        true
    }

    fn hyperparam_domains(&self) -> &'static [HyperParamDomain] {
        DOMAINS
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let space = ctx.space_handle();
        for (i, v) in ctx.evaluate_random_sample(self.init_samples.max(2)) {
            self.record(i, v);
        }
        while !ctx.budget_exhausted() {
            let Some(pick) = self.propose(&space, ctx) else {
                return; // space exhausted
            };
            let v = ctx.evaluate(pick);
            self.record(pick, v);
        }
    }

    fn suggest(&mut self, ctx: &mut TuningContext, _limit: usize) -> Option<Vec<u32>> {
        let space = ctx.space_handle();
        match std::mem::take(&mut self.state) {
            State::Fresh => {
                self.state = State::AwaitInit;
                Some(space.random_sample(&mut ctx.rng, self.init_samples.max(2)))
            }
            State::Ready => match self.propose(&space, ctx) {
                Some(pick) => {
                    self.state = State::AwaitPoint;
                    Some(vec![pick])
                }
                None => {
                    self.state = State::Ready;
                    Some(Vec::new()) // converged: space exhausted
                }
            },
            awaiting => {
                // suggest() twice without an observe(): keep the phase.
                self.state = awaiting;
                Some(Vec::new())
            }
        }
    }

    fn observe(&mut self, _ctx: &mut TuningContext, batch: &[u32], results: &[Option<f64>]) {
        match std::mem::take(&mut self.state) {
            State::AwaitInit | State::AwaitPoint => {
                for (&i, r) in batch.iter().zip(results) {
                    self.record(i, *r);
                }
                self.state = State::Ready;
            }
            state => self.state = state,
        }
    }
}

/// A fitted Gaussian-process posterior over standardized inputs/outputs.
/// Exposed (with [`fit_gp`]) so the hot-path bench can track fit+query
/// cost without constructing a whole tuning run.
#[derive(Debug)]
pub struct Gp {
    /// Standardized training inputs, row-major `n × dims`.
    xs: Vec<f64>,
    dims: usize,
    n: usize,
    /// Per-dimension input standardizers.
    x_mean: Vec<f64>,
    x_std: Vec<f64>,
    /// Lower-triangular Cholesky factor of the kernel matrix, `n × n`.
    chol: Vec<f64>,
    /// K⁻¹ y (standardized targets).
    alpha: Vec<f64>,
    y_mean: f64,
    y_std: f64,
    /// Best (minimum) standardized target — the EI incumbent.
    y_best: f64,
    /// Kernel length normalizer: 2·ℓ²·dims.
    ell2d: f64,
}

impl Gp {
    fn standardize(&self, x: &[f64], out: &mut Vec<f64>) {
        out.clear();
        for d in 0..self.dims {
            out.push((x[d] - self.x_mean[d]) / self.x_std[d]);
        }
    }

    fn kernel_to_train(&self, z: &[f64], k: &mut Vec<f64>) {
        k.clear();
        for r in 0..self.n {
            let row = &self.xs[r * self.dims..(r + 1) * self.dims];
            let d2: f64 = row.iter().zip(z).map(|(a, b)| (a - b) * (a - b)).sum();
            k.push((-d2 / self.ell2d).exp());
        }
    }

    /// Posterior mean and standard deviation at `x` (raw feature space),
    /// in standardized-target units.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        assert_eq!(x.len(), self.dims, "feature dimensionality mismatch");
        let mut z = Vec::with_capacity(self.dims);
        self.standardize(x, &mut z);
        let mut k = Vec::with_capacity(self.n);
        self.kernel_to_train(&z, &mut k);
        let mu: f64 = k.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
        // σ² = k(x,x) + nugget − vᵀv with v = L⁻¹ k.
        let mut v = k;
        forward_solve(&self.chol, self.n, &mut v);
        let var = 1.0 + NUGGET - v.iter().map(|a| a * a).sum::<f64>();
        (mu, var.max(1e-12).sqrt())
    }

    /// Expected improvement (minimization) of `x` over the incumbent, in
    /// standardized-target units; always ≥ 0.
    pub fn expected_improvement(&self, x: &[f64], xi: f64) -> f64 {
        let (mu, sigma) = self.predict(x);
        let imp = self.y_best - mu - xi;
        let z = imp / sigma;
        (imp * normal_cdf(z) + sigma * normal_pdf(z)).max(0.0)
    }

    /// Posterior mean mapped back to raw target units (ms).
    pub fn mean_ms(&self, x: &[f64]) -> f64 {
        self.predict(x).0 * self.y_std + self.y_mean
    }
}

/// Diagonal jitter: observation noise plus numerical insurance.
const NUGGET: f64 = 1e-6;

/// Fit a GP on `(raw features, raw target)` points. Returns `None` when
/// the posterior would be degenerate: fewer than 3 points, a flat target
/// window, a zero-variance feature set, or a kernel matrix that stays
/// non-positive-definite through jitter escalation.
pub fn fit_gp(points: &[(Vec<f64>, f64)], length_scale: f64) -> Option<Gp> {
    let n = points.len();
    if n < 3 {
        return None;
    }
    let dims = points[0].0.len();
    if dims == 0 || points.iter().any(|(x, _)| x.len() != dims) {
        return None;
    }
    // Target standardization.
    let y_mean = points.iter().map(|(_, y)| y).sum::<f64>() / n as f64;
    let y_var = points.iter().map(|(_, y)| (y - y_mean) * (y - y_mean)).sum::<f64>() / n as f64;
    let y_std = y_var.sqrt();
    if !(y_std.is_finite() && y_std > 1e-12) {
        return None;
    }
    // Per-dimension feature standardization (constant dims get std 1, so
    // they simply contribute distance 0).
    let mut x_mean = vec![0.0; dims];
    let mut x_std = vec![0.0; dims];
    for (x, _) in points {
        for d in 0..dims {
            x_mean[d] += x[d];
        }
    }
    for m in &mut x_mean {
        *m /= n as f64;
    }
    for (x, _) in points {
        for d in 0..dims {
            let c = x[d] - x_mean[d];
            x_std[d] += c * c;
        }
    }
    for s in &mut x_std {
        *s = (*s / n as f64).sqrt();
        if !(*s > 1e-12) {
            *s = 1.0;
        }
    }
    let mut xs = Vec::with_capacity(n * dims);
    for (x, _) in points {
        for d in 0..dims {
            xs.push((x[d] - x_mean[d]) / x_std[d]);
        }
    }
    let ell2d = 2.0 * length_scale * length_scale * dims as f64;
    // Kernel matrix, then Cholesky with escalating jitter.
    let mut base = vec![0.0; n * n];
    for r in 0..n {
        for c in 0..=r {
            let xr = &xs[r * dims..(r + 1) * dims];
            let xc = &xs[c * dims..(c + 1) * dims];
            let d2: f64 = xr.iter().zip(xc).map(|(a, b)| (a - b) * (a - b)).sum();
            let k = (-d2 / ell2d).exp();
            base[r * n + c] = k;
            base[c * n + r] = k;
        }
    }
    let ys: Vec<f64> = points.iter().map(|(_, y)| (y - y_mean) / y_std).collect();
    let mut jitter = NUGGET;
    for _ in 0..5 {
        let mut k = base.clone();
        for i in 0..n {
            k[i * n + i] += jitter;
        }
        if cholesky_in_place(&mut k, n) {
            let mut alpha = ys.clone();
            forward_solve(&k, n, &mut alpha);
            backward_solve(&k, n, &mut alpha);
            let y_best = ys.iter().copied().fold(f64::INFINITY, f64::min);
            return Some(Gp {
                xs,
                dims,
                n,
                x_mean,
                x_std,
                chol: k,
                alpha,
                y_mean,
                y_std,
                y_best,
                ell2d,
            });
        }
        jitter *= 10.0;
    }
    None
}

/// In-place Cholesky factorization (lower triangle; the upper is left
/// stale and never read). Returns `false` when the matrix is not
/// positive-definite at working precision.
fn cholesky_in_place(a: &mut [f64], n: usize) -> bool {
    for r in 0..n {
        for c in 0..=r {
            let mut s = a[r * n + c];
            for k in 0..c {
                s -= a[r * n + k] * a[c * n + k];
            }
            if r == c {
                if s <= 0.0 || !s.is_finite() {
                    return false;
                }
                a[r * n + r] = s.sqrt();
            } else {
                a[r * n + c] = s / a[c * n + c];
            }
        }
    }
    true
}

/// Solve L·x = b in place (L lower-triangular from `cholesky_in_place`).
fn forward_solve(l: &[f64], n: usize, b: &mut [f64]) {
    for r in 0..n {
        let mut s = b[r];
        for c in 0..r {
            s -= l[r * n + c] * b[c];
        }
        b[r] = s / l[r * n + r];
    }
}

/// Solve Lᵀ·x = b in place.
fn backward_solve(l: &[f64], n: usize, b: &mut [f64]) {
    for r in (0..n).rev() {
        let mut s = b[r];
        for c in r + 1..n {
            s -= l[c * n + r] * b[c];
        }
        b[r] = s / l[r * n + r];
    }
}

/// Standard normal CDF via the Abramowitz–Stegun 7.1.26 erf polynomial
/// (|err| < 1.5e-7 — far below the noise floor of the surrogate).
fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    sign * (1.0 - poly * (-x * x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::{run_ask_tell, testutil, OptimizerSpec};

    #[test]
    fn gp_interpolates_and_ranks_by_improvement() {
        // y = (x-3)² on a 1-D grid: the posterior mean must roughly
        // recover held-out values and EI must prefer the basin.
        let pts: Vec<(Vec<f64>, f64)> = [0.0, 1.0, 2.0, 4.0, 5.0, 6.0]
            .iter()
            .map(|&x| (vec![x], (x - 3.0) * (x - 3.0)))
            .collect();
        let gp = fit_gp(&pts, 1.0).expect("well-posed fit");
        let near = gp.mean_ms(&[3.0]);
        assert!(near < 4.0, "posterior at the basin should be low, got {}", near);
        let ei_basin = gp.expected_improvement(&[3.0], 0.0);
        let ei_edge = gp.expected_improvement(&[6.5], 0.0);
        assert!(
            ei_basin > ei_edge,
            "EI must prefer the basin: {} vs {}",
            ei_basin,
            ei_edge
        );
    }

    #[test]
    fn degenerate_windows_refuse_to_fit() {
        assert!(fit_gp(&[], 2.0).is_none(), "empty");
        let two = vec![(vec![0.0], 1.0), (vec![1.0], 2.0)];
        assert!(fit_gp(&two, 2.0).is_none(), "too few points");
        let flat: Vec<(Vec<f64>, f64)> =
            (0..5).map(|i| (vec![i as f64], 7.0)).collect();
        assert!(fit_gp(&flat, 2.0).is_none(), "flat targets");
    }

    #[test]
    fn erf_matches_known_values() {
        assert!((erf(0.0)).abs() < 1e-12);
        assert!((erf(1.0) - 0.842_700_79).abs() < 1e-6);
        assert!((erf(-1.0) + 0.842_700_79).abs() < 1e-6);
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!(normal_cdf(8.0) > 0.999_999);
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let cache = testutil::conv_cache();
        let run = |seed: u64| {
            let mut ctx = crate::tuning::TuningContext::new(&cache, 300.0, seed);
            BayesOpt::default().run(&mut ctx);
            (ctx.trajectory.clone(), ctx.unique_evals())
        };
        assert_eq!(run(3), run(3));
        let (tr, evals) = run(4);
        assert!(!tr.is_empty() && evals > 16);
    }

    #[test]
    fn beats_median_with_budget() {
        let cache = testutil::conv_cache();
        let mut bo = BayesOpt::default();
        let (best, _) = testutil::run_on(&mut bo, &cache, 600.0, 9);
        assert!(best < cache.median_ms);
    }

    #[test]
    fn ask_tell_variant_is_deterministic() {
        let cache = testutil::conv_cache();
        let run = |seed: u64| {
            let mut ctx = crate::tuning::TuningContext::new(&cache, 300.0, seed);
            let mut bo = BayesOpt::default();
            assert!(run_ask_tell(&mut bo, &mut ctx), "bayes_opt must support ask/tell");
            (ctx.trajectory.clone(), ctx.unique_evals())
        };
        assert_eq!(run(5), run(5));
        let (tr, evals) = run(6);
        assert!(!tr.is_empty() && evals > 16);
    }

    #[test]
    fn spec_parsing_enforces_the_domain_grid() {
        // Satellite contract: off-grid overrides are rejected at parse
        // time, exactly like every other registry entry.
        assert!(OptimizerSpec::parse("bayes_opt").is_some());
        assert!(OptimizerSpec::parse("bayes_opt:xi=0.05").is_some());
        assert!(OptimizerSpec::parse("bayes_opt:train_window=96,xi=0.1").is_some());
        assert!(OptimizerSpec::parse("bayes_opt:xi=0.33").is_none(), "off-grid");
        assert!(OptimizerSpec::parse("bayes_opt:length_scale=3").is_none(), "off-grid");
        assert!(OptimizerSpec::parse("bayes_opt:no_such_knob=1").is_none());
    }
}
