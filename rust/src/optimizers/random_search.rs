//! Random search — the methodology's baseline algorithm.
//!
//! Samples valid configurations uniformly without replacement (matching the
//! calculated baseline's with-replacement assumption closely for the first
//! few thousand draws while avoiding wasted duplicate evaluations).
//!
//! `run` keeps the classic draw-evaluate loop (bit-identical to the
//! pre-backend behavior); `suggest`/`observe` additionally expose an
//! ask/tell path that proposes whole blocks of fresh draws for
//! batch-capable backends.

use super::Optimizer;
use crate::tuning::TuningContext;

/// Batch size `suggest` proposes when the driver places no tighter limit.
const DEFAULT_BATCH: usize = 64;

#[derive(Debug, Default)]
pub struct RandomSearch;

impl Optimizer for RandomSearch {
    fn name(&self) -> &str {
        "random"
    }

    // No hyperparam_domains override: uniform random search genuinely has
    // no knobs, so it inherits the empty default — the registry's one
    // domain-less optimizer (hypertune sweeps over it degenerate to a
    // single meta-configuration).

    fn run(&mut self, ctx: &mut TuningContext) {
        let n = ctx.space().len();
        while !ctx.budget_exhausted() {
            // Uniform draw; skip already-seen cheaply (still charged the
            // bookkeeping epsilon via evaluate on a repeat is avoided by a
            // quick membership test).
            let mut i = ctx.rng.below(n) as u32;
            let mut tries = 0;
            while ctx.already_evaluated(i) && tries < 16 {
                i = ctx.rng.below(n) as u32;
                tries += 1;
            }
            ctx.evaluate(i);
        }
    }

    fn suggest(&mut self, ctx: &mut TuningContext, limit: usize) -> Option<Vec<u32>> {
        let n = ctx.space().len();
        let want = limit.min(DEFAULT_BATCH).max(1);
        let mut batch: Vec<u32> = Vec::with_capacity(want);
        while batch.len() < want {
            let mut i = ctx.rng.below(n) as u32;
            let mut tries = 0;
            while (ctx.already_evaluated(i) || batch.contains(&i)) && tries < 16 {
                i = ctx.rng.below(n) as u32;
                tries += 1;
            }
            batch.push(i);
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn covers_many_distinct_configs() {
        let cache = testutil::conv_cache();
        let mut rs = RandomSearch;
        let (best, evals) = testutil::run_on(&mut rs, &cache, 500.0, 1);
        assert!(best.is_finite());
        assert!(evals > 50, "evals {}", evals);
    }

    #[test]
    fn deterministic_given_seed() {
        let cache = testutil::conv_cache();
        let a = testutil::run_on(&mut RandomSearch, &cache, 200.0, 9);
        let b = testutil::run_on(&mut RandomSearch, &cache, 200.0, 9);
        assert_eq!(a, b);
    }
}
