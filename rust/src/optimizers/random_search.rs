//! Random search — the methodology's baseline algorithm.
//!
//! Samples valid configurations uniformly without replacement (matching the
//! calculated baseline's with-replacement assumption closely for the first
//! few thousand draws while avoiding wasted duplicate evaluations).

use super::Optimizer;
use crate::tuning::TuningContext;

#[derive(Debug, Default)]
pub struct RandomSearch;

impl Optimizer for RandomSearch {
    fn name(&self) -> &str {
        "random"
    }

    fn run(&mut self, ctx: &mut TuningContext) {
        let n = ctx.space().len();
        while !ctx.budget_exhausted() {
            // Uniform draw; skip already-seen cheaply (still charged the
            // bookkeeping epsilon via evaluate on a repeat is avoided by a
            // quick membership test).
            let mut i = ctx.rng.below(n) as u32;
            let mut tries = 0;
            while ctx.already_evaluated(i) && tries < 16 {
                i = ctx.rng.below(n) as u32;
                tries += 1;
            }
            ctx.evaluate(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil;

    #[test]
    fn covers_many_distinct_configs() {
        let cache = testutil::conv_cache();
        let mut rs = RandomSearch;
        let (best, evals) = testutil::run_on(&mut rs, &cache, 500.0, 1);
        assert!(best.is_finite());
        assert!(evals > 50, "evals {}", evals);
    }

    #[test]
    fn deterministic_given_seed() {
        let cache = testutil::conv_cache();
        let a = testutil::run_on(&mut RandomSearch, &cache, 200.0, 9);
        let b = testutil::run_on(&mut RandomSearch, &cache, 200.0, 9);
        assert_eq!(a, b);
    }
}
