//! llamea-kt — reproduction of "Automated Algorithm Design for Auto-Tuning
//! Optimizers" (Willemsen, van Stein, van Werkhoven).
pub mod coordinator;
pub mod harness;
pub mod kernels;
pub mod llamea;
pub mod methodology;
pub mod optimizers;
pub mod runtime;
pub mod searchspace;
pub mod tuning;
pub mod util;
