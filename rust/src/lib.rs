//! llamea-kt — reproduction of "Automated Algorithm Design for Auto-Tuning
//! Optimizers" (Willemsen, van Stein, van Werkhoven).

// Deliberate style choices of this codebase (CI runs `clippy -D warnings`):
// index loops over parallel slices, wide-but-flat argument lists in the
// numeric reference kernels, result tuples in the harness, and the
// genome-carrying spec variant are all clearer than their lint-suggested
// rewrites here.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::large_enum_variant
)]

pub mod coordinator;
pub mod harness;
pub mod hypertune;
pub mod kernels;
pub mod llamea;
pub mod methodology;
pub mod obs;
pub mod optimizers;
pub mod persist;
pub mod remote;
pub mod runtime;
pub mod searchspace;
pub mod serve;
pub mod tuning;
pub mod util;
