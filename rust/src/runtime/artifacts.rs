//! AOT artifact manifest parsing.
//!
//! `python/compile/aot.py` lowers every program variant to HLO text and
//! writes `manifest.tsv`; this module is the Rust-side reader. Python never
//! runs at tuning time — the manifest + HLO files are the entire interface
//! between the build path and the serving path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{bail, Context, Result};

/// Input tensor specification, e.g. `float32:256x256`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let (dtype, dims_s) = s
            .split_once(':')
            .with_context(|| format!("bad tensor spec '{}'", s))?;
        let dims = dims_s
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dtype: dtype.to_string(), dims })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-compiled program variant.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub kernel: String,
    pub name: String,
    pub path: PathBuf,
    /// Tunable parameters of this variant, sorted by key.
    pub params: BTreeMap<String, i64>,
    pub inputs: Vec<TensorSpec>,
    pub n_outputs: usize,
}

/// The parsed artifact set of one `make artifacts` run.
#[derive(Debug, Clone, Default)]
pub struct ArtifactSet {
    pub artifacts: Vec<Artifact>,
}

impl ArtifactSet {
    /// Load `<dir>/manifest.tsv` and resolve artifact paths against `dir`.
    pub fn load(dir: &Path) -> Result<ArtifactSet> {
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.starts_with('#') || line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 6 {
                bail!("manifest line {}: expected 6 columns, got {}", lineno + 1, cols.len());
            }
            let mut params = BTreeMap::new();
            if !cols[3].is_empty() {
                for kv in cols[3].split(';') {
                    let (k, v) = kv
                        .split_once('=')
                        .with_context(|| format!("bad param '{}'", kv))?;
                    params.insert(k.to_string(), v.parse::<i64>().context("bad param value")?);
                }
            }
            let inputs = cols[4]
                .split(';')
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(Artifact {
                kernel: cols[0].to_string(),
                name: cols[1].to_string(),
                path: dir.join(cols[2]),
                params,
                inputs,
                n_outputs: cols[5].parse().context("bad n_outputs")?,
            });
        }
        Ok(ArtifactSet { artifacts })
    }

    /// Variants of one kernel, in manifest order.
    pub fn for_kernel(&self, kernel: &str) -> Vec<&Artifact> {
        self.artifacts.iter().filter(|a| a.kernel == kernel).collect()
    }

    /// Distinct kernel names present.
    pub fn kernels(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .iter()
            .map(|a| a.kernel.clone())
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_roundtrip() {
        let t = TensorSpec::parse("float32:256x256").unwrap();
        assert_eq!(t.dtype, "float32");
        assert_eq!(t.dims, vec![256, 256]);
        assert_eq!(t.element_count(), 65536);
        assert!(TensorSpec::parse("garbage").is_err());
    }

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join("llamea_kt_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# header\n\
             gemm\tgemm__block_m-64\tgemm__block_m-64.hlo.txt\tblock_k=32;block_m=64\tfloat32:256x256;float32:256x256\t1\n\
             conv2d\tc1\tc1.hlo.txt\ttile_h=8\tfloat32:262x262;float32:7x7\t1\n",
        )
        .unwrap();
        let set = ArtifactSet::load(&dir).unwrap();
        assert_eq!(set.artifacts.len(), 2);
        assert_eq!(set.kernels(), vec!["conv2d".to_string(), "gemm".to_string()]);
        let g = &set.for_kernel("gemm")[0];
        assert_eq!(g.params["block_m"], 64);
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.n_outputs, 1);
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.tsv").exists() {
            let set = ArtifactSet::load(&dir).unwrap();
            assert!(set.artifacts.len() >= 50, "{}", set.artifacts.len());
            for a in &set.artifacts {
                assert!(a.path.exists(), "{}", a.path.display());
            }
            assert!(set.kernels().contains(&"gemm".to_string()));
        }
    }
}
