//! PJRT runtime: load AOT HLO-text artifacts, compile on the CPU client,
//! execute with timing. This is the *measured* evaluation path — the rust
//! coordinator's equivalent of Kernel Tuner's compile-and-benchmark
//! backends, with Python fully out of the loop.
//!
//! Interchange is HLO text (not serialized protos): jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use std::path::Path;
use std::time::Instant;

use super::artifacts::{Artifact, TensorSpec};
use crate::util::error::{Context, Result};
use crate::util::rng::Rng;
use crate::util::stats;

// Without the `pjrt` feature the API-compatible stub stands in for the
// real bindings (the offline environment has no `xla` crate); execution
// entry points then fail at runtime with a clear message. Enabling `pjrt`
// resolves `xla::` against the vendored bindings instead.
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// A PJRT CPU client wrapper.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled program variant ready to execute.
pub struct CompiledVariant {
    exe: xla::PjRtLoadedExecutable,
    /// Wall-clock seconds spent loading + compiling (the "compile cost" the
    /// auto-tuner pays per configuration).
    pub compile_s: f64,
}

/// Steady-state timing statistics of one variant.
#[derive(Debug, Clone)]
pub struct Timing {
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub reps: usize,
}

impl PjrtRuntime {
    pub fn new() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load HLO text from `path` and compile it.
    pub fn compile_file(&self, path: &Path) -> Result<CompiledVariant> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(CompiledVariant { exe, compile_s: t0.elapsed().as_secs_f64() })
    }

    /// Compile an artifact and prepare its (deterministic) input literals.
    pub fn prepare(&self, artifact: &Artifact, seed: u64) -> Result<(CompiledVariant, Vec<xla::Literal>)> {
        let variant = self.compile_file(&artifact.path)?;
        let inputs = make_inputs(&artifact.inputs, seed)?;
        Ok((variant, inputs))
    }
}

impl CompiledVariant {
    /// Execute once; returns the flattened f32 contents of the first output
    /// (tuple-unwrapped — aot.py lowers with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute once without converting the output (timing path).
    pub fn run_once(&self, inputs: &[xla::Literal]) -> Result<()> {
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        // Force completion by materializing the (tuple) output.
        let _ = bufs[0][0].to_literal_sync()?;
        Ok(())
    }

    /// Warmed-up repeated timing: `warmup` unmeasured runs, then `reps`
    /// measured ones.
    pub fn time(&self, inputs: &[xla::Literal], warmup: usize, reps: usize) -> Result<Timing> {
        for _ in 0..warmup {
            self.run_once(inputs)?;
        }
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            self.run_once(inputs)?;
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        Ok(Timing {
            mean_ms: stats::mean(&samples),
            std_ms: stats::std_dev(&samples),
            min_ms: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            reps,
        })
    }
}

/// Deterministic input literals for a tensor-spec list.
///
/// f32 tensors get standard-normal-ish values; i32 tensors get small
/// non-negative values (safe for the dedispersion delay operand, whose
/// dynamic slices HLO clamps in-range regardless).
pub fn make_inputs(specs: &[TensorSpec], seed: u64) -> Result<Vec<xla::Literal>> {
    let mut rng = Rng::new(seed);
    specs
        .iter()
        .map(|spec| {
            let n = spec.element_count();
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            let lit = match spec.dtype.as_str() {
                "float32" => {
                    let data: Vec<f32> =
                        (0..n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
                    xla::Literal::vec1(&data).reshape(&dims)?
                }
                "int32" => {
                    let data: Vec<i32> = (0..n).map(|_| rng.below(32) as i32).collect();
                    xla::Literal::vec1(&data).reshape(&dims)?
                }
                other => crate::bail!("unsupported artifact dtype '{}'", other),
            };
            Ok(lit)
        })
        .collect()
}

/// Rust-side GEMM reference for the correctness gate of the measured path:
/// `alpha * A @ B + beta * C` over row-major f32 (matches ref.py).
pub fn gemm_reference(
    a: &[f32],
    b: &[f32],
    c: &[f32],
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    beta: f32,
) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for l in 0..k {
                acc += a[i * k + l] * b[l * n + j];
            }
            out[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inputs_are_deterministic_and_shaped() {
        let specs = vec![
            TensorSpec::parse("float32:4x4").unwrap(),
            TensorSpec::parse("int32:2x3").unwrap(),
        ];
        let a = make_inputs(&specs, 7).unwrap();
        let b = make_inputs(&specs, 7).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(
            a[0].to_vec::<f32>().unwrap(),
            b[0].to_vec::<f32>().unwrap()
        );
        assert!(make_inputs(&[TensorSpec::parse("bf16:2").unwrap()], 0).is_err());
    }

    #[test]
    fn gemm_reference_identity() {
        // A @ I = A.
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let eye = vec![1.0f32, 0.0, 0.0, 1.0];
        let c = vec![0.0f32; 4];
        let out = gemm_reference(&a, &eye, &c, 2, 2, 2, 1.0, 0.0);
        assert_eq!(out, a);
        // beta path.
        let out2 = gemm_reference(&a, &eye, &a, 2, 2, 2, 1.0, 1.0);
        assert_eq!(out2, vec![2.0, 4.0, 6.0, 8.0]);
    }

    // PJRT-backed tests live in rust/tests/integration_runtime.rs (they
    // need the artifacts directory built by `make artifacts`).
}
