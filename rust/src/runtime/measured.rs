//! The measured tuning path: turn a set of AOT-compiled program variants
//! into a *real* pre-explored search space (a [`Cache`] whose entries are
//! PJRT wall-clock measurements instead of model outputs), so the entire
//! methodology and every optimizer run unchanged on real data — exactly
//! how the paper replays its exhaustively-benchmarked cachefiles.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use super::artifacts::{Artifact, ArtifactSet};
use super::pjrt::PjrtRuntime;
use crate::searchspace::{Param, ParamSet, SearchSpace};
use crate::tuning::Cache;

/// Build the variant search space of one kernel from its artifacts: one
/// tunable parameter per manifest param key, values = distinct values seen.
/// Combinations not present in the manifest are hidden failures.
pub fn variant_space(kernel: &str, set: &ArtifactSet) -> Result<SearchSpace> {
    let artifacts = set.for_kernel(kernel);
    if artifacts.is_empty() {
        bail!("no artifacts for kernel '{}'", kernel);
    }
    let keys: Vec<String> = artifacts[0].params.keys().cloned().collect();
    let mut params = Vec::new();
    for key in &keys {
        let values: BTreeSet<i64> = artifacts
            .iter()
            .map(|a| *a.params.get(key).expect("inconsistent manifest params"))
            .collect();
        params.push(Param::ints(key, &values.into_iter().collect::<Vec<_>>()));
    }
    SearchSpace::build(&format!("{}-measured", kernel), ParamSet::new(params), &[])
        .map_err(|e| anyhow::anyhow!(e))
}

/// Result of exhaustively measuring a kernel's variants.
pub struct MeasuredSpace {
    pub cache: Cache,
    /// (artifact name, mean ms, compile s) per measured variant.
    pub measurements: Vec<(String, f64, f64)>,
}

/// Exhaustively measure all variants of `kernel` and assemble a measured
/// [`Cache`]. `warmup`/`reps` control per-variant timing.
pub fn measure_kernel(
    runtime: &PjrtRuntime,
    set: &ArtifactSet,
    kernel: &str,
    warmup: usize,
    reps: usize,
    seed: u64,
) -> Result<MeasuredSpace> {
    let space = std::sync::Arc::new(variant_space(kernel, set)?);
    let artifacts = set.for_kernel(kernel);

    // Map each artifact to its config index in the variant space.
    let mut mean_ms = vec![f32::INFINITY; space.len()];
    let mut compile_s = vec![0.2f32; space.len()]; // nominal for absent combos
    let mut measurements = Vec::with_capacity(artifacts.len());
    for artifact in &artifacts {
        let cfg: Vec<u16> = config_of(artifact, &space);
        let idx = space
            .index_of(&cfg)
            .expect("artifact config missing from variant space");
        let (variant, inputs) = runtime.prepare(artifact, seed)?;
        let timing = variant.time(&inputs, warmup, reps)?;
        mean_ms[idx as usize] = timing.mean_ms as f32;
        compile_s[idx as usize] = variant.compile_s as f32;
        measurements.push((artifact.name.clone(), timing.mean_ms, variant.compile_s));
    }

    let cache = Cache::from_measured(space, mean_ms, compile_s, seed);
    Ok(MeasuredSpace { cache, measurements })
}

/// The value-index configuration of an artifact within the variant space.
pub fn config_of(artifact: &Artifact, space: &SearchSpace) -> Vec<u16> {
    space
        .params
        .params
        .iter()
        .map(|p| {
            let v = artifact.params[&p.name];
            p.values
                .iter()
                .position(|pv| pv.as_i64() == v)
                .expect("value missing from param domain") as u16
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn fake_artifact(kernel: &str, params: &[(&str, i64)]) -> Artifact {
        Artifact {
            kernel: kernel.into(),
            name: format!("{}-v", kernel),
            path: PathBuf::from("/nonexistent"),
            params: params.iter().map(|(k, v)| (k.to_string(), *v)).collect::<BTreeMap<_, _>>(),
            inputs: vec![],
            n_outputs: 1,
        }
    }

    #[test]
    fn variant_space_from_manifest_params() {
        let set = ArtifactSet {
            artifacts: vec![
                fake_artifact("gemm", &[("block_m", 32), ("block_n", 32)]),
                fake_artifact("gemm", &[("block_m", 64), ("block_n", 32)]),
                fake_artifact("gemm", &[("block_m", 64), ("block_n", 64)]),
            ],
        };
        let space = variant_space("gemm", &set).unwrap();
        assert_eq!(space.dims(), 2);
        assert_eq!(space.len(), 4); // full cartesian; (32,64) will be a failure entry
        let cfg = config_of(&set.artifacts[1], &space);
        assert_eq!(space.params.describe(&cfg), "block_m=64, block_n=32");
        assert!(variant_space("missing", &set).is_err());
    }
}
